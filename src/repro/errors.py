"""Structured integrity errors for the container stack.

Every corrupt-input failure mode raises one of these instead of a raw
``struct.error`` / ``AssertionError``, so callers can tell *what* broke and
*where* without parsing message strings:

* :class:`CorruptContainerError` — the envelope itself is damaged
  (truncated file, bad magic, out-of-range footer offsets, index/lane
  extent mismatch, metadata checksum failure).  Carries the byte offset of
  the failed check and what was expected there.
* :class:`CorruptLaneError` — one entropy lane's checksum does not match
  its footer-index CRC (bit rot inside an otherwise well-formed
  container).  Carries the tile id, the lane's byte offset, and the
  expected/actual CRC, so a damaged region can be reported — or
  quarantined — tile by tile (docs/ROBUSTNESS.md).

Both subclass :class:`ValueError`: pre-existing callers that caught
``ValueError`` for corrupt input keep working unchanged.
"""
from __future__ import annotations


class IntegrityError(ValueError):
    """Base for all detected-corruption failures."""


class CorruptContainerError(IntegrityError):
    """A container envelope failed a structural or checksum validation.

    ``offset`` is the container-relative byte offset of the failed check
    (None when unknown); ``expected``/``actual`` describe it when a
    concrete comparison failed."""

    def __init__(self, message: str, *, offset: int | None = None,
                 expected=None, actual=None):
        self.offset = offset
        self.expected = expected
        self.actual = actual
        detail = []
        if offset is not None:
            detail.append(f"at byte {offset}")
        if expected is not None:
            detail.append(f"expected {expected!r}")
        if actual is not None:
            detail.append(f"got {actual!r}")
        super().__init__(message + (f" ({', '.join(detail)})" if detail else ""))


class CorruptLaneError(IntegrityError):
    """An entropy lane's bytes do not match the container's CRC for it."""

    def __init__(self, tile_id: int, *, lane_offset: int | None = None,
                 expected_crc: int | None = None, actual_crc: int | None = None):
        self.tile_id = int(tile_id)
        self.lane_offset = lane_offset
        self.expected_crc = expected_crc
        self.actual_crc = actual_crc
        loc = f" at byte {lane_offset}" if lane_offset is not None else ""
        crc = ""
        if expected_crc is not None or actual_crc is not None:
            crc = (f" (crc expected 0x{(expected_crc or 0):08x}, "
                   f"got 0x{(actual_crc or 0):08x})")
        super().__init__(
            f"corrupt entropy lane for tile {tile_id}{loc}{crc}; "
            "open with on_corrupt='quarantine' to degrade instead of failing")
