"""Step-function builders: train_step / prefill_step / decode_step per arch.

These are the functions the dry-run lowers and the drivers execute.  All are
pure (params, opt_state, batch) -> outputs, jit/pjit-friendly, with sharding
expressed through in_shardings at the jit boundary plus internal constraints.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from repro.launch.mesh import batch_axes_of
from repro.optim import AdamWConfig, adamw  # noqa: F401  (adamw via package)
from repro.optim import schedule as sched
import repro.optim.adamw as adamw_mod


@dataclass(frozen=True)
class TrainOptions:
    lr: float = 3e-4
    warmup: int = 2000
    total_steps: int = 100_000
    moment_dtype: str = "bf16"
    fsdp: bool = False
    microbatch: int = 1          # gradient-accumulation chunks
    param_dtype: str = "fp32"    # master params


def _positions_for(cfg, B, S):
    if cfg.attn is not None and cfg.attn.mrope_sections is not None:
        return None  # provided in the batch (3-stream M-RoPE)
    return jnp.arange(S)


def make_train_step(model, cfg, opts: TrainOptions, mesh=None, grad_pspecs=None):
    """Returns train_step(params, opt_state, batch, rng) -> (params, opt, metrics).

    ``grad_pspecs``: PartitionSpec tree for gradients. When microbatching,
    the accumulator is constrained to these specs so each microbatch's
    contribution is reduce-scattered into the (FSDP-sharded) accumulator
    instead of all-reduced to a replicated tree — see EXPERIMENTS.md §Perf
    (llama3-405b cell: the dominant collective term).
    """
    adam_cfg = AdamWConfig(weight_decay=0.1, moment_dtype=opts.moment_dtype)
    lr_fn = sched.warmup_cosine(opts.lr, opts.warmup, opts.total_steps)
    baxes = batch_axes_of(mesh) if mesh is not None else None
    is_encdec = getattr(model, "cfg", cfg).enc_layers > 0

    def lossfn(params, batch):
        if is_encdec:
            return model.loss(params, batch["enc_feats"], batch["tokens"], batch["targets"], batch_axes=baxes)
        pos = batch.get("positions")
        if pos is None:
            pos = _positions_for(cfg, *batch["tokens"].shape)
        return model.loss(params, batch["tokens"], batch["targets"], pos, batch_axes=baxes)

    def train_step(params, opt_state, batch, rng):
        M = opts.microbatch
        if M <= 1:
            (loss, aux), grads = jax.value_and_grad(lossfn, has_aux=True)(params, batch)
        else:
            def mb(carry, mbatch):
                g_acc, l_acc = carry
                (l, _), g = jax.value_and_grad(lossfn, has_aux=True)(params, mbatch)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                if grad_pspecs is not None:
                    from repro.models.common import shard_constraint

                    g_acc = jax.tree.map(
                        lambda t, sp: shard_constraint(t, sp), g_acc, grad_pspecs,
                        is_leaf=lambda t: hasattr(t, "shape"),
                    )
                return (g_acc, l_acc + l), None

            def resplit(t):
                # [B, ...] -> [M, B/M, ...] with the *inner* batch dim sharded
                # over data (each microbatch spans all devices).
                t = t.reshape(M, t.shape[0] // M, *t.shape[1:])
                if baxes is not None:
                    from repro.models.common import shard_constraint
                    from jax.sharding import PartitionSpec as P

                    t = shard_constraint(t, P(None, baxes, *([None] * (t.ndim - 2))))
                return t

            split = jax.tree.map(resplit, batch)
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(mb, (zeros, jnp.zeros(())), split)
            grads = jax.tree.map(lambda g: g / M, grads)
            loss = loss / M
        lr = lr_fn(opt_state["step"])
        params, opt_state = adamw_mod.update(params, opt_state, grads, lr, adam_cfg, rng)
        return params, opt_state, {"loss": loss, "lr": lr}

    return train_step, adam_cfg


def make_prefill_step(model, cfg, mesh=None):
    """Forward over the full prompt; returns last-position logits.

    (Cache materialization is omitted in the dry-run cell — identical FLOPs,
    see EXPERIMENTS.md §Dry-run notes.)"""
    baxes = batch_axes_of(mesh) if mesh is not None else None
    is_encdec = cfg.enc_layers > 0

    def prefill_step(params, batch):
        if is_encdec:
            enc_out = model.encode(params, batch["enc_feats"], batch_axes=baxes)
            pos = jnp.arange(batch["tokens"].shape[1])
            logits, _ = model.decode(params, enc_out, batch["tokens"], pos, batch_axes=baxes)
            return logits[:, -1]
        pos = batch.get("positions")
        if pos is None:
            pos = jnp.arange(batch["tokens"].shape[1])
        logits, _, _ = model.apply(params, batch["tokens"], pos, batch_axes=baxes)
        return logits[:, -1]

    return prefill_step


def make_decode_step(model, cfg, mesh=None):
    """One new token against a pre-filled KV/state cache."""
    baxes = batch_axes_of(mesh) if mesh is not None else None
    is_encdec = cfg.enc_layers > 0

    if is_encdec:
        def decode_step(params, cache, batch):
            logits, cache = model.decode_step(
                params, cache, batch["enc_out"], batch["token"], batch["pos"], batch_axes=baxes
            )
            return logits, cache
    else:
        def decode_step(params, cache, batch):
            logits, cache = model.decode_step(
                params, cache, batch["token"], batch["pos"], batch_axes=baxes
            )
            return logits, cache

    return decode_step
