"""Input-shape cells (assigned per-arch) and ShapeDtypeStruct builders.

Every cell resolves to (step_kind, ShapeDtypeStruct pytree) — weak-type
correct, shardable, zero allocation (the pattern the dry-run lowers from).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_family, long_context_ok


@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}


def cell_applicable(arch: str, shape: str) -> tuple[bool, str]:
    """long_500k only for sub-quadratic archs (DESIGN.md §4)."""
    if shape == "long_500k" and not long_context_ok(arch):
        return False, "pure full attention at 500k context — skipped per brief"
    return True, ""


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def input_specs(arch: str, shape: str, *, reduced: bool = False) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    cell = SHAPES[shape]
    cfg = get_config(arch, reduced=reduced)
    fam = get_family(arch)
    B, S = cell.global_batch, cell.seq_len
    if reduced:
        B, S = max(B // 64, 1), min(S, 64)

    mrope = cfg.attn is not None and cfg.attn.mrope_sections is not None

    if cell.kind in ("train", "prefill"):
        batch = {
            "tokens": sds((B, S), jnp.int32),
        }
        if cell.kind == "train":
            batch["targets"] = sds((B, S), jnp.int32)
        if mrope:
            batch["positions"] = sds((B, 3, S), jnp.int32)
        if fam == "encdec":
            batch["enc_feats"] = sds((B, cfg.enc_seq, cfg.d_model), cfg.compute_dtype)
        return {"batch": batch, "cell": cell, "cfg": cfg}

    # decode: one new token against a ctx-length cache
    batch = {"token": sds((B, 1), jnp.int32), "pos": sds((), jnp.int32)}
    if fam == "encdec":
        batch["enc_out"] = sds((B, cfg.enc_seq, cfg.d_model), cfg.compute_dtype)
    return {"batch": batch, "cell": cell, "cfg": cfg, "ctx": S}
