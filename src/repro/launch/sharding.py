"""Parameter / activation / cache sharding rules (DESIGN.md §5).

Rules are keyed by the parameter's leaf name (the last dict key on its tree
path) and give the PartitionSpec of the *base* (unstacked) tensor; leading
layer-stacking axes are padded with None automatically.  ``fsdp`` is a
placeholder resolved to the data axis when ZeRO-3-style parameter sharding is
on (the 405B/671B training cells), else to None.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

FSDP = "__fsdp__"
MODEL = "model"

# leaf name -> base spec (tail-aligned to the leaf's trailing dims)
PARAM_RULES: dict[str, tuple] = {
    # embeddings / heads
    "embed": (MODEL, FSDP),         # [V, d] vocab-sharded
    "lm_head": (FSDP, MODEL),       # [d, V]
    "pos_dec": (None, FSDP),
    # attention (GQA)
    "wq": (FSDP, MODEL, None),      # [d, H, hd]
    "wk": (FSDP, MODEL, None),
    "wv": (FSDP, MODEL, None),
    "wo": (MODEL, None, FSDP),      # [H, hd, d]
    "q_norm": (None,),
    "k_norm": (None,),
    # MLA
    "w_dq": (FSDP, None),
    "w_uq": (None, MODEL, None),
    "w_dkv": (FSDP, None),
    "w_kr": (FSDP, None),
    "w_uk": (None, MODEL, None),
    "w_uv": (None, MODEL, None),
    "q_ln": (None,),
    "kv_ln": (None,),
    # dense mlp
    "w_up": (FSDP, MODEL),          # [d, F]; moe [E, d, F] handled by pad rule
    "w_gate": (FSDP, MODEL),
    "w_down": (MODEL, FSDP),        # [F, d]
    # moe
    "router": (None, None),
    # rwkv6
    "wr": (FSDP, MODEL),
    "wg": (FSDP, MODEL),
    "mix_w1": (FSDP, None),
    "mix_w2": (None, None, FSDP),
    "decay_w1": (FSDP, None),
    "decay_w2": (None, FSDP),
    "u": (MODEL, None),
    "cm_wr": (FSDP, MODEL),
    "cm_wk": (FSDP, MODEL),
    "cm_wv": (MODEL, FSDP),
    # mamba2
    "w_in": (FSDP, MODEL),
    "conv_w": (None, MODEL),
    "conv_b": (MODEL,),
    "A_log": (MODEL,),
    "D": (MODEL,),
    "dt_bias": (MODEL,),
    "norm": (MODEL,),
    "w_out": (MODEL, FSDP),
}

# MoE expert-stacked tensors (distinct "we_*" names): expert axis gets the
# model axis and the rest stays unsharded (expert-parallel dispatch).
PARAM_RULES.update({
    "we_up": (MODEL, FSDP, None),    # [E, d, F]
    "we_gate": (MODEL, FSDP, None),
    "we_down": (MODEL, None, FSDP),  # [E, F, d]
})


@dataclass(frozen=True)
class ShardingOptions:
    fsdp: bool = False              # ZeRO-3 parameter sharding over "data"
    seq_axis: str | None = None     # "model"/"data" for sequence-parallel caches
    fsdp_axis: str = "data"


def _resolve(spec: tuple, shape: tuple, opts: ShardingOptions, axis_sizes: dict) -> P:
    tail = list(
        (opts.fsdp_axis if (s == FSDP and opts.fsdp) else (None if s == FSDP else s))
        for s in spec
    )
    # drop axes missing from the mesh or not dividing the dimension
    off = len(shape) - len(tail)
    for i, s in enumerate(tail):
        if s is None:
            continue
        size = axis_sizes.get(s)
        if size is None or shape[off + i] % size != 0:
            tail[i] = None
    pad = (None,) * off
    return P(*(pad + tuple(tail)))


def param_pspecs(params, opts: ShardingOptions, mesh) -> object:
    """PartitionSpec pytree matching ``params`` (works on ShapeDtypeStructs)."""
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def spec_for(path, leaf) -> P:
        names = [k.key for k in path if isinstance(k, jax.tree_util.DictKey)]
        name = names[-1] if names else ""
        base = PARAM_RULES.get(name, ())
        if len(base) > leaf.ndim:
            base = base[-leaf.ndim:]
        return _resolve(base, leaf.shape, opts, axis_sizes)

    return jax.tree_util.tree_map_with_path(spec_for, params)


def named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda s: isinstance(s, P),
    )


def opt_pspecs(opt_state, pspecs, opts: ShardingOptions, mesh):
    """Optimizer moments inherit the parameter specs (int8 packs add a scalar
    scale, which stays replicated)."""

    def match(ps, leaf_state):
        if isinstance(leaf_state, dict) and set(leaf_state) == {"q", "s"}:
            return {"q": ps, "s": P()}
        return ps

    m = jax.tree.map(match, pspecs, opt_state["m"], is_leaf=lambda x: isinstance(x, P))
    v = jax.tree.map(match, pspecs, opt_state["v"], is_leaf=lambda x: isinstance(x, P))
    return {"step": P(), "m": m, "v": v}


def batch_pspec(mesh, *, seq_axis=None) -> P:
    from repro.launch.mesh import batch_axes_of

    return P(batch_axes_of(mesh), seq_axis)


def tile_mesh(devices=None):
    """1D mesh over all local devices for tile-grid fan-out (axis ``tiles``).

    The tiled compression engine (repro.sz.tiled) treats the tile batch as a
    pure data axis: every tile is an independent prediction+quantization
    domain, so compress/decompress shard with no collectives at all."""
    import numpy as np

    devs = np.asarray(jax.devices() if devices is None else devices)
    return jax.sharding.Mesh(devs, ("tiles",))


def device_round(n: int, devices: int | None = None) -> int:
    """Round a tile-batch width DOWN to a device-count multiple (≥ 1).

    The streaming planner (repro.exec.plan) sizes device batches with this
    so ``map_tiles`` fan-out pads nothing in steady state; widths smaller
    than the device count stay as-is (the pad-with-repeats path handles
    them, and shrinking to 0 would be worse)."""
    d = len(jax.devices()) if devices is None else int(devices)
    if d <= 1 or n <= d:
        return max(1, int(n))
    return (int(n) // d) * d


def map_tiles(fn, tiles, *extra, mesh=None):
    """Fan a tile-batched op across the device mesh via ``shard_map``.

    ``tiles`` may be one array or a pytree of arrays sharing the tile batch
    on axis 0 (e.g. the interp predictor's ``(codes, omask, ovals)``), and
    ``fn(tiles, *extra)`` may likewise return any pytree of batch-carrying
    arrays — both sides use ``P("tiles")`` as a pytree-prefix spec.  ``fn``
    must map axis 0 elementwise (tile-independent) and preserve the batch
    axis; ``extra`` operands are replicated.  The batch is padded to a device
    multiple with repeats of tile 0 (cheap, discarded).  On a single device
    this is a plain call — no dispatch overhead."""
    mesh = tile_mesh() if mesh is None else mesh
    n = int(mesh.devices.size)
    if n <= 1:
        return fn(tiles, *extra)
    import jax.numpy as jnp
    from jax.experimental.shard_map import shard_map

    B = jax.tree.leaves(tiles)[0].shape[0]
    pad = (-B) % n
    if pad:
        tiles = jax.tree.map(
            lambda t: jnp.concatenate([t, jnp.repeat(t[:1], pad, axis=0)]), tiles)
    in_specs = (P("tiles"),) + (P(),) * len(extra)
    out = shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=P("tiles"),
                    check_rep=False)(tiles, *extra)
    return jax.tree.map(lambda o: o[:B], out) if pad else out


def cache_pspecs(cache, mesh, opts: ShardingOptions) -> object:
    """KV/SSM cache sharding: batch over data axes; the sequence axis of
    "global" caches over ``opts.seq_axis`` (flash-decode style); kv tensors'
    head axes unsharded (kv heads are often < mesh model size)."""
    from repro.launch.mesh import batch_axes_of

    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    baxes = batch_axes_of(mesh)

    def fit(shape, tail):
        """Drop axes that don't divide; pad leading dims with None."""
        off = len(shape) - len(tail)
        out = []
        for i, s in enumerate(tail):
            if s is None:
                out.append(None)
                continue
            axes = s if isinstance(s, tuple) else (s,)
            size = 1
            for a in axes:
                size *= axis_sizes.get(a, 1)
            out.append(s if shape[off + i] % size == 0 else None)
        return P(*(((None,) * off) + tuple(out)))

    def spec_for(path, leaf):
        names = [k.key for k in path if isinstance(k, jax.tree_util.DictKey)]
        name = names[-1] if names else ""
        nd = leaf.ndim
        if name == "pos" or nd == 0:
            return P()
        if name in ("k", "v", "c_kv", "k_rope"):
            tail_rank = 4 if name in ("k", "v") else 3
            return fit(leaf.shape, (baxes, opts.seq_axis) + (None,) * (tail_rank - 2))
        if name in ("wkv", "ssm"):  # [stack..., B, H, p, n]
            return fit(leaf.shape, (baxes, "model", None, None))
        if name in ("shift", "cm"):  # [stack..., B, d]
            return fit(leaf.shape, (baxes, None))
        if name == "conv":  # [stack..., B, W-1, C]
            return fit(leaf.shape, (baxes, None, "model"))
        return P(*((None,) * nd))

    return jax.tree_util.tree_map_with_path(spec_for, cache)
