"""Distributed GWLZ: the paper's group-wise enhancer training as an SPMD
program on the production mesh (DESIGN.md §3.3/§5).

Mapping: volume slices -> ``data`` axis (DP over the batch of slices),
enhancer group axis -> ``model`` axis (EP-style: each model shard owns
G/|model| groups — groups are independent, so no cross-group collectives
exist at all).  Gradients reduce over ``data``+``pod`` only, optionally with
the paper-derived error-bounded int8 compression (optim.grad_compress).

This module also provides the dry-run cell "gwlz-nyx / vol512" — the cell
most representative of the paper's own technique in EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import enhancer, grouping
from repro.core.trainer import GWLZTrainConfig, _group_inputs, _loss_one_group
from repro.optim import AdamWConfig, adamw
from repro.optim.grad_compress import GradCompressConfig, apply as gc_apply, init_ef


@dataclass(frozen=True)
class DistGWLZConfig:
    n_groups: int = 32          # pad to a multiple of the model-axis size
    channels: int = 9
    volume: int = 512           # Nyx: 512^3
    batch_slices: int = 64      # global slice batch per step
    lr: float = 1e-3
    grad_compress: bool = False
    gc_rel_eb: float = 1e-2


def build_state(cfg: DistGWLZConfig, key=None):
    key = jax.random.PRNGKey(0) if key is None else key
    G = cfg.n_groups
    pkeys = jax.random.split(key, G)
    params = jax.vmap(lambda k: enhancer.init_params(k, cfg.channels))(pkeys)
    bn = jax.vmap(lambda _: enhancer.init_state(cfg.channels))(jnp.arange(G))
    opt = adamw.init(params, AdamWConfig())
    ef = init_ef(params) if cfg.grad_compress else None
    return {"params": params, "bn": bn, "opt": opt, "ef": ef}


def make_dist_train_step(cfg: DistGWLZConfig, mesh):
    """Returns (train_step, in_shardings builder).

    train_step(state, batch) where batch = {"x": [B,H,W] decompressed slices,
    "r": [B,H,W] residuals, "edges": [G+1], "rscale": [G]}.
    """
    G = cfg.n_groups
    gc_cfg = GradCompressConfig(rel_eb=cfg.gc_rel_eb, enabled=cfg.grad_compress)
    adam_cfg = AdamWConfig()

    def train_step(state, batch):
        xb, rb = batch["x"], batch["r"]
        edges, rscale = batch["edges"], batch["rscale"]
        ids = grouping.assign_groups(xb, edges)
        xn, masks = _group_inputs(xb, ids, edges, G)
        safe = jnp.where(rscale > 0, rscale, 1.0)
        target = rb[None] / safe[:, None, None, None] * masks

        def lossfn(p):
            losses, states = jax.vmap(_loss_one_group)(p, state["bn"], xn, masks, target)
            return losses.sum(), (losses, states)

        grads, (losses, new_bn) = jax.grad(lossfn, has_aux=True)(state["params"])
        ef = state["ef"]
        if cfg.grad_compress:
            grads, ef = gc_apply(grads, ef, gc_cfg)
        params, opt = adamw.update(state["params"], state["opt"], grads, cfg.lr, adam_cfg)
        return {"params": params, "bn": new_bn, "opt": opt, "ef": ef}, losses

    # shardings: group-stacked leaves on "model"; slice batch on data axes
    from repro.launch.mesh import batch_axes_of

    baxes = batch_axes_of(mesh)

    def group_spec(leaf):
        if hasattr(leaf, "ndim") and leaf.ndim >= 1 and leaf.shape[0] == G:
            return P("model", *([None] * (leaf.ndim - 1)))
        return P()

    def state_shardings(state):
        return jax.tree.map(
            lambda l: NamedSharding(mesh, group_spec(l)), state,
            is_leaf=lambda l: hasattr(l, "shape"),
        )

    def batch_shardings(batch):
        return {
            "x": NamedSharding(mesh, P(baxes, None, None)),
            "r": NamedSharding(mesh, P(baxes, None, None)),
            "edges": NamedSharding(mesh, P(None)),
            "rscale": NamedSharding(mesh, P(None)),
        }

    return train_step, state_shardings, batch_shardings


def input_specs(cfg: DistGWLZConfig):
    """ShapeDtypeStructs for the dry-run cell (512^3 Nyx volume)."""
    V, B = cfg.volume, cfg.batch_slices
    f32 = jnp.float32
    return {
        "x": jax.ShapeDtypeStruct((B, V, V), f32),
        "r": jax.ShapeDtypeStruct((B, V, V), f32),
        "edges": jax.ShapeDtypeStruct((cfg.n_groups + 1,), f32),
        "rscale": jax.ShapeDtypeStruct((cfg.n_groups,), f32),
    }
