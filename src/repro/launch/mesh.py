"""Production mesh construction (DESIGN.md §5).

Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run sets
``--xla_force_host_platform_device_count=512`` before any jax import and only
then calls these.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """(16, 16) = one v5e pod of 256 chips; (2, 16, 16) = two pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh for smoke tests / examples on the CPU container."""
    return jax.make_mesh((1, 1), ("data", "model"))


def batch_axes_of(mesh) -> tuple[str, ...]:
    """Mesh axes that carry data parallelism."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
