"""Post-SPMD HLO analysis: trip-count-aware FLOP and collective accounting.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, so any
scan-over-layers / microbatch-accumulation model is undercounted by the trip
count (126x for llama3-405b).  This walker parses the optimized HLO text,
builds the computation call graph, extracts while-loop trip counts from the
loop-condition constants, and accumulates:

  * dot FLOPs (2 * prod(result) * contracted size) — exact for the matmul-
    dominated models here,
  * per-collective wire bytes with ring formulas, multiplied along the loop
    nest.

Heuristics (documented in EXPERIMENTS.md §Dry-run): the trip count of a while
is the largest integer constant in its condition computation; conditionals
take the max across branches.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
                "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1}

# computation headers sit at column 0: ``%name (params...) -> type {`` —
# params may nest parentheses (tuples), so match only the name prefix.
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+)$")
_SHAPE = re.compile(r"^(\()?\s*(?:(f64|f32|bf16|f16|s32|u32|s16|u16|s8|u8|pred|s64|u64)\[([\d,]*)\])")
_ALL_SHAPES = re.compile(r"(f64|f32|bf16|f16|s32|u32|s16|u16|s8|u8|pred|s64|u64)\[([\d,]*)\]")
_COLL = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")


def _shape_elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _result_bytes(defn: str) -> int:
    """Bytes of the (possibly tuple) result type at the start of a definition."""
    total = 0
    depth_txt = defn.split("=", 1)[0] if "=" in defn and defn.index("=") < defn.find("(") else defn
    # take shapes before the op name (i.e. in the result type segment)
    m = re.match(r"^\(?((?:\s*(?:f64|f32|bf16|f16|s32|u32|s16|u16|s8|u8|pred|s64|u64)\[[\d,]*\]\{?[\d,]*\}?,?)+)\)?\s*[\w-]+\(", defn)
    seg = m.group(1) if m else defn.split("(", 1)[0]
    for dt, dims in _ALL_SHAPES.findall(seg):
        total += _shape_elems(dims) * _DTYPE_BYTES[dt]
    return total


@dataclass
class Computation:
    name: str
    is_entry: bool = False
    dots_flops: float = 0.0
    collectives: list = field(default_factory=list)  # (kind, bytes, group)
    whiles: list = field(default_factory=list)       # (body, condition)
    calls: list = field(default_factory=list)        # called computation names
    constants: list = field(default_factory=list)    # integer constants seen


def parse_hlo(hlo: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    shapes: dict[str, str] = {}  # instr name -> dims of first shape
    for raw in hlo.splitlines():
        hdr = _COMP_HDR.match(raw)
        if hdr and "{" in raw:
            cur = Computation(hdr.group(1), is_entry=raw.lstrip().startswith("ENTRY"))
            comps[cur.name] = cur
            shapes = {}
            # register computation parameters declared in the header so dots
            # consuming them resolve their contracting sizes
            for pname, pdims in re.findall(r"([\w\.\-]+):\s*(?:f64|f32|bf16|f16|s32|u32|s16|u16|s8|u8|pred|s64|u64)\[([\d,]*)\]", raw):
                shapes[pname] = pdims
            continue
        if cur is None:
            continue
        m = _INSTR.match(raw)
        if not m:
            continue
        name, defn = m.groups()
        sh = _SHAPE.match(defn)
        if sh:
            shapes[name] = sh.group(3) if sh.group(3) is not None else ""
        for c in re.finditer(r"constant\((\d+)\)", defn):
            cur.constants.append(int(c.group(1)))
        opm = re.search(r"\s([\w\-]+)\(", defn)
        op = opm.group(1) if opm else ""
        if op == "dot":
            res = _SHAPE.match(defn)
            res_elems = _shape_elems(res.group(3)) if res else 0
            args = re.search(r"dot\(\s*%?([\w\.\-]+),\s*%?([\w\.\-]+)", defn)
            lhs_dims = shapes.get(args.group(1), "") if args else ""
            cdim = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", defn)
            contracted = 1
            if cdim and lhs_dims:
                ld = [int(d) for d in lhs_dims.split(",") if d]
                for ci in cdim.group(1).split(","):
                    if ci and int(ci) < len(ld):
                        contracted *= ld[int(ci)]
            cur.dots_flops += 2.0 * res_elems * contracted
        elif op == "convolution":
            res = _SHAPE.match(defn)
            res_elems = _shape_elems(res.group(3)) if res else 0
            args = re.search(r"convolution\(\s*%?([\w\.\-]+),\s*%?([\w\.\-]+)", defn)
            kdims = shapes.get(args.group(2), "") if args else ""
            kelems = _shape_elems(kdims) if kdims else 0
            # contracted size per output element = kernel elems / output features
            dl = re.search(r"dim_labels=[\w]+_([\w]+)->", defn)
            o_size = 1
            if dl and kdims:
                kd = [int(d) for d in kdims.split(",") if d]
                o_pos = dl.group(1).find("o")
                if 0 <= o_pos < len(kd):
                    o_size = kd[o_pos]
            cur.dots_flops += 2.0 * res_elems * (kelems / max(o_size, 1))
        elif any(op.startswith(k) for k in _COLL):
            kind = next(k for k in _COLL if op.startswith(k))
            if op.endswith("-done"):
                continue  # paired with -start; count once
            nbytes = _result_bytes(defn)
            g = 1
            gm = re.search(r"replica_groups=\[(\d+),(\d+)\]", defn)
            if gm:
                g = int(gm.group(2))
            else:
                gm = re.search(r"replica_groups=\{\{([^}]*)\}", defn)
                if gm:
                    g = len([t for t in gm.group(1).split(",") if t.strip() != ""])
            cur.collectives.append((kind, nbytes, g))
        elif op == "while":
            b = re.search(r"body=%?([\w\.\-]+)", defn)
            c = re.search(r"condition=%?([\w\.\-]+)", defn)
            if b and c:
                cur.whiles.append((b.group(1), c.group(1)))
        else:
            for callee in re.finditer(r"(?:calls|to_apply)=%?([\w\.\-]+)", defn):
                cur.calls.append(callee.group(1))
            bm = re.search(r"branch_computations=\{([^}]*)\}", defn)
            if bm:
                for name in bm.group(1).split(","):
                    cur.calls.append(name.strip().lstrip("%"))
    return comps


def _trip_count(comps: dict[str, Computation], cond: str) -> int:
    c = comps.get(cond)
    if c is None or not c.constants:
        return 1
    return max(1, max(c.constants))


def walk(hlo: str, entry_hint: str | None = None) -> dict:
    """Returns {"flops", "wire_bytes", "collectives": {kind: {count, bytes}}}
    with while-bodies multiplied by trip counts."""
    comps = parse_hlo(hlo)
    entry = entry_hint
    if entry is None:
        entries = [n for n, c in comps.items() if c.is_entry]
        if entries:
            entry = entries[-1]
        else:
            called = set()
            for c in comps.values():
                called.update(x for x, _ in c.whiles)
                called.update(c.calls)
                called.update(x for _, x in c.whiles)
            candidates = [n for n in comps if n not in called]
            entry = max(candidates, key=lambda n: len(comps[n].collectives) + comps[n].dots_flops + 1) if candidates else next(iter(comps))

    memo: dict[str, tuple] = {}

    def visit(name: str, depth=0) -> tuple:
        if name in memo:
            return memo[name]
        c = comps.get(name)
        if c is None or depth > 50:
            return (0.0, 0.0, {})
        memo[name] = (0.0, 0.0, {})  # cycle guard
        flops = c.dots_flops
        wire = 0.0
        agg: dict[str, dict] = {}
        for kind, b, g in c.collectives:
            a = agg.setdefault(kind, {"count": 0, "bytes": 0.0})
            a["count"] += 1
            a["bytes"] += b
            if g > 1:
                if kind == "all-reduce":
                    wire += 2.0 * (g - 1) / g * b
                elif kind == "all-gather":
                    wire += (g - 1) / g * b
                elif kind == "reduce-scatter":
                    wire += (g - 1) * b
                elif kind == "all-to-all":
                    wire += (g - 1) / g * b
                else:
                    wire += b
        for callee in c.calls:
            f2, w2, a2 = visit(callee, depth + 1)
            flops += f2
            wire += w2
            for k, v in a2.items():
                a = agg.setdefault(k, {"count": 0, "bytes": 0.0})
                a["count"] += v["count"]
                a["bytes"] += v["bytes"]
        for body, cond in c.whiles:
            trips = _trip_count(comps, cond)
            f2, w2, a2 = visit(body, depth + 1)
            flops += trips * f2
            wire += trips * w2
            for k, v in a2.items():
                a = agg.setdefault(k, {"count": 0, "bytes": 0.0})
                a["count"] += trips * v["count"]
                a["bytes"] += trips * v["bytes"]
        memo[name] = (flops, wire, agg)
        return memo[name]

    flops, wire, agg = visit(entry)
    return {"flops": flops, "wire_bytes": wire, "collectives": agg, "entry": entry}
