"""Training driver: ``python -m repro.launch.train --arch <id> [--reduced]``.

Production path: builds the assigned architecture, a deterministic token
pipeline, the jitted train step, and runs it under the ResilientLoop
(heartbeats + async checkpoints + restore-on-failure).  On this CPU container
use ``--reduced`` (the full configs only lower via dryrun.py).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import ARCH_IDS, build_model, get_family
from repro.data.tokens import TokenPipeline, TokenPipelineConfig
from repro.launch.steps import TrainOptions, make_train_step
from repro.optim import adamw
from repro.runtime.fault import FailureInjector, HeartbeatMonitor, ResilientLoop


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b", choices=list(ARCH_IDS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--moment-dtype", default="fp32", choices=["fp32", "bf16", "int8"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--gwlz-ckpt-eb", type=float, default=None,
                    help="rel error bound for GWLZ-compressed checkpoints")
    ap.add_argument("--inject-failure-at", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    model, cfg = build_model(args.arch, reduced=args.reduced)
    fam = get_family(args.arch)
    params = model.init(jax.random.PRNGKey(args.seed))
    n_params = sum(int(p.size) for p in jax.tree_util.tree_leaves(params))
    print(f"arch={cfg.name} params={n_params:,}")

    opts = TrainOptions(lr=args.lr, warmup=max(args.steps // 10, 1),
                        total_steps=args.steps, moment_dtype=args.moment_dtype)
    step_fn, adam_cfg = make_train_step(model, cfg, opts, mesh=None)
    opt_state = adamw.init(params, adam_cfg)

    pipe = TokenPipeline(TokenPipelineConfig(cfg.vocab, args.batch, args.seq, seed=args.seed))
    mrope = cfg.attn is not None and cfg.attn.mrope_sections is not None

    def batch_fn(step):
        b = pipe.batch_at(step)
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        if mrope:
            pos = jnp.broadcast_to(jnp.arange(args.seq)[None, None, :],
                                   (args.batch, 3, args.seq)).astype(jnp.int32)
            batch["positions"] = pos
        if fam == "encdec":
            rng = np.random.default_rng(step)
            batch["enc_feats"] = jnp.asarray(
                rng.normal(size=(args.batch, cfg.enc_seq, cfg.d_model)).astype(np.float32),
                cfg.compute_dtype)
        return batch

    jstep = jax.jit(step_fn, donate_argnums=(0, 1))

    def loop_step(state, batch):
        params, opt_state, rng = state
        rng, sub = jax.random.split(rng)
        params, opt_state, metrics = jstep(params, opt_state, batch, sub)
        return (params, opt_state, rng), metrics

    manager = CheckpointManager(args.ckpt_dir, gwlz_rel_eb=args.gwlz_ckpt_eb)
    monitor = HeartbeatMonitor(n_workers=1)
    injector = (FailureInjector({args.inject_failure_at})
                if args.inject_failure_at is not None else None)
    loop = ResilientLoop(loop_step, batch_fn, manager, ckpt_every=args.ckpt_every)

    state = (params, opt_state, jax.random.PRNGKey(args.seed + 1))
    t0 = time.time()
    state, metrics_log, restarts = loop.run(state, args.steps, injector=injector, monitor=monitor)
    dt = time.time() - t0
    losses = [float(m["loss"]) for m in metrics_log]
    toks = args.steps * args.batch * args.seq
    print(f"steps={args.steps} restarts={restarts} loss[0]={losses[0]:.3f} "
          f"loss[-1]={losses[-1]:.3f} tokens/s={toks/dt:,.0f} stragglers={monitor.stragglers()}")
    return losses


if __name__ == "__main__":
    main()
