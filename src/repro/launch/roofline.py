"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Three terms per (arch x shape x mesh) cell, all in seconds per step:

  compute    = FLOPs_per_device / PEAK_FLOPS
  memory     = HBM_bytes_per_device / HBM_BW
  collective = wire_bytes_per_device / ICI_BW

FLOPs come from the trip-count-aware HLO walk (launch/hlowalk — XLA's
cost_analysis counts scan bodies once); wire bytes likewise.  HBM bytes are
the analytic traffic model below (params/opt-state/cache/activation streams),
since XLA CPU gives no per-device HBM model.  MODEL_FLOPS = 6·N·D (active N
for MoE) is reported against walked FLOPs to expose remat/redundancy waste.

Hardware model (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (brief-specified constants).
"""
from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def analytic_hbm_bytes(info: dict) -> float:
    """Per-device HBM traffic per step (streaming model).

    train:   3x param stream (fwd read, bwd read, update write) + opt state
             read+write + activation boundary traffic (scan+remat: one bf16
             activation per layer boundary written fwd and read bwd).
    decode:  params once + full cache read + cache write (1 token).
    prefill: params once + activation stream.
    """
    static = info.get("static_bytes_per_dev", 0)
    shape = info["shape"]
    if shape.startswith("train") or shape.startswith("vol"):
        return 5.0 * static  # 3x params + ~2x opt state, activations folded in
    return 1.2 * static  # params + cache streamed ~once


def model_flops(info: dict) -> float:
    """6·N·D with active-N for MoE; decode D = new tokens only."""
    n = info["n_params"] * info.get("active_fraction", 1.0)
    d = info["ntokens"]
    mult = 6.0 if (info["shape"].startswith("train") or info["shape"].startswith("vol")) else 2.0
    return mult * n * d


def load_cells(out_dir: str, tag: str = "", rewalk: bool = True) -> list[dict]:
    """Load cell JSONs; recompute the HLO walk from the .hlo.z sidecar when
    present so walker improvements apply without recompiling."""
    cells = []
    for path in sorted(glob.glob(os.path.join(out_dir, f"*{tag}.json"))):
        stem = os.path.basename(path)[: -len(".json")]
        if not tag and not (stem.endswith("_single") or stem.endswith("_multi")):
            continue  # tagged perf-experiment file; not part of the baseline table
        info = json.load(open(path))
        info["_file"] = os.path.basename(path)
        sidecar = path.replace(".json", ".hlo.z")
        if rewalk and os.path.exists(sidecar) and "error" not in info and "skipped" not in info:
            import zlib

            from repro.launch import hlowalk

            try:
                hlo = zlib.decompress(open(sidecar, "rb").read()).decode()
                info["walked"] = hlowalk.walk(hlo)
            except Exception as e:  # pragma: no cover
                info.setdefault("walked", {})["rewalk_error"] = str(e)
        cells.append(info)
    return cells


def analyse(info: dict) -> dict | None:
    if "skipped" in info or "error" in info:
        return None
    dev = info["devices"]
    walked = info.get("walked", {})
    # the optimized HLO module IS the per-device program: walked numbers are
    # already per-device.
    flops_dev = walked.get("flops", float("nan"))
    wire_dev = walked.get("wire_bytes", info.get("wire_bytes_per_dev", 0.0))
    hbm = analytic_hbm_bytes(info)
    t_comp = flops_dev / PEAK_FLOPS
    t_mem = hbm / HBM_BW
    t_coll = wire_dev / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dom = max(terms, key=lambda k: terms[k] if terms[k] == terms[k] else -1)
    mf = model_flops(info)  # global 6ND
    useful = (mf / dev) / flops_dev if flops_dev else float("nan")
    bound = max(terms.values())
    frac = (mf / dev / PEAK_FLOPS) / bound if bound > 0 else float("nan")
    return {
        "arch": info["arch"], "shape": info["shape"], "mesh": info["mesh"],
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "dominant": dom,
        "model_flops": mf, "walked_flops": walked.get("flops"),
        "useful_fraction": useful,
        "roofline_fraction": frac,  # useful work / dominant-term time
        "static_GiB": info.get("static_bytes_per_dev", 0) / 2**30,
        "fits_16GiB": info.get("static_bytes_per_dev", 0) < 14 * 2**30,
        "settings": info.get("settings", {}),
    }


def markdown_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | dominant "
           "| 6ND/HLO | roofline-frac | static GiB | fits |\n"
           "|---|---|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['t_compute_s']:.3e} "
            f"| {r['t_memory_s']:.3e} | {r['t_collective_s']:.3e} | **{r['dominant']}** "
            f"| {r['useful_fraction']:.2f} | {r['roofline_fraction']:.2f} "
            f"| {r['static_GiB']:.2f} | {'Y' if r['fits_16GiB'] else 'N'} |\n"
        )
    return "".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--mesh", default="16x16", help="16x16 | 2x16x16 | all")
    ap.add_argument("--json-out", default="")
    args = ap.parse_args()
    rows = []
    skips = []
    for info in load_cells(args.dir, args.tag):
        if "skipped" in info:
            skips.append((info["arch"], info["shape"], info["mesh"], info["skipped"]))
            continue
        if "error" in info:
            skips.append((info["arch"], info["shape"], info.get("mesh", "?"), "ERROR " + info["error"]))
            continue
        r = analyse(info)
        if r and (args.mesh == "all" or r["mesh"] == args.mesh):
            rows.append(r)
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    print(markdown_table(rows))
    if skips:
        print("\nSkipped/failed cells:")
        for s in sorted(set(skips)):
            print(f"- {s[0]} / {s[1]} / {s[2]}: {s[3]}")
    if args.json_out:
        json.dump(rows, open(args.json_out, "w"), indent=1)


if __name__ == "__main__":
    main()
