import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
# ^ MUST precede any jax import: jax locks the device count at first init.
# The dry-run (and only the dry-run) builds the 512-chip production meshes
# out of host placeholder devices; smoke tests and benches see 1 device.

import argparse  # noqa: E402
import json  # noqa: E402
import math  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ARCH_IDS, build_model, get_family  # noqa: E402
from repro.launch import hlowalk  # noqa: E402
from repro.launch import sharding as SH  # noqa: E402
from repro.launch.mesh import batch_axes_of, make_production_mesh  # noqa: E402
from repro.launch.shapes import SHAPES, cell_applicable, input_specs  # noqa: E402
from repro.launch.steps import TrainOptions, make_decode_step, make_prefill_step, make_train_step  # noqa: E402
from repro.optim import adamw  # noqa: E402

# Per-arch defaults chosen for the memory envelope (16 GB HBM / v5e chip).
# These are the *baseline* settings; §Perf hillclimbs override via --set.
ARCH_TRAIN_DEFAULTS: dict[str, dict] = {
    "llama3-405b": dict(fsdp=True, microbatch=16, moment_dtype="bf16"),
    "deepseek-v3-671b": dict(fsdp=True, microbatch=16, moment_dtype="int8"),
    "llama4-scout-17b-a16e": dict(fsdp=True, microbatch=4, moment_dtype="bf16"),
    "granite-3-8b": dict(fsdp=True, microbatch=1),
    "yi-9b": dict(fsdp=True, microbatch=1),
    "qwen2-vl-7b": dict(fsdp=True, microbatch=1),
    "rwkv6-7b": dict(fsdp=True, microbatch=1),
}
# decode cells: sequence-shard global KV caches over "model" (flash-decode);
# long_500k batch=1 shards sequence over "data" too.
ARCH_DECODE_SEQ_AXIS = {"decode_32k": "model", "long_500k": "data"}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\S+))\s+(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.M)
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s32|u32|s16|u16|s8|u8|pred|s64|u64)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{(.*?)\}\}|replica_groups=\[(\d+),(\d+)\]")
_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
                "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo: str) -> list[dict]:
    """Sum result-shape bytes of every collective op (post-SPMD HLO)."""
    out = []
    for line in hlo.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_txt, kind = m.groups()
        nbytes = _shape_bytes(shape_txt)
        g = 1
        gm = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
        if gm:
            g = int(gm.group(2))
        else:
            gm = re.search(r"replica_groups=\{\{(.*?)\}", line)
            if gm:
                g = len(gm.group(1).split(","))
        out.append({"kind": kind, "bytes": nbytes, "group": g})
    return out


def wire_bytes(colls: list[dict]) -> float:
    """Per-device ICI bytes using ring formulas."""
    total = 0.0
    for c in colls:
        g, b = max(c["group"], 1), c["bytes"]
        if g <= 1:
            continue
        if c["kind"] == "all-reduce":
            total += 2.0 * (g - 1) / g * b
        elif c["kind"] == "all-gather":
            total += (g - 1) / g * b
        elif c["kind"] == "reduce-scatter":
            total += (g - 1) * b  # result bytes are already 1/g of the input
        elif c["kind"] == "all-to-all":
            total += (g - 1) / g * b
        else:  # collective-permute
            total += b
    return total


def count_params(tree) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(tree))


def active_param_fraction(cfg) -> float:
    """MoE: fraction of routed-expert params active per token."""
    if cfg.moe is None:
        return 1.0
    return cfg.moe.top_k / cfg.moe.n_experts


def sharded_bytes(tree, spec_tree, mesh) -> int:
    """Static per-device bytes given the sharding specs."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def one(leaf, spec):
        b = int(np.prod(leaf.shape)) * jnp.dtype(leaf.dtype).itemsize
        denom = 1
        for ax in spec:
            if ax is None:
                continue
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                denom *= sizes.get(a, 1)
        return b // max(denom, 1)

    leaves = jax.tree_util.tree_leaves(tree)
    specs = jax.tree_util.tree_leaves(spec_tree, is_leaf=lambda s: isinstance(s, P))
    assert len(leaves) == len(specs), (len(leaves), len(specs))
    return sum(one(l, s) for l, s in zip(leaves, specs))


def lower_gwlz_cell(multi_pod: bool, *, overrides: dict | None = None) -> dict:
    """The paper's own technique on the production mesh: group-wise enhancer
    training over a 512^3 Nyx volume (groups -> model axis, slices -> data)."""
    from repro.launch import gwlz_dist as GD

    mesh = make_production_mesh(multi_pod=multi_pod)
    kw = dict(grad_compress=False)
    if overrides:
        kw.update({k: v for k, v in overrides.items() if k in ("grad_compress", "n_groups", "batch_slices")})
    dcfg = GD.DistGWLZConfig(**kw)
    step, state_sh, batch_sh = GD.make_dist_train_step(dcfg, mesh)
    state_sds = jax.eval_shape(lambda: GD.build_state(dcfg))
    batch_sds = GD.input_specs(dcfg)

    t0 = time.time()
    jitted = jax.jit(step, in_shardings=(state_sh(state_sds), batch_sh(batch_sds)))
    with mesh:
        lowered = jitted.lower(state_sds, batch_sds)
    info = {
        "arch": "gwlz-nyx", "shape": "vol512_g32", "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": int(np.prod(mesh.devices.shape)),
        "settings": kw,
        "n_params": count_params(state_sds["params"]),
        "active_fraction": 1.0,
        "lower_s": round(time.time() - t0, 2),
    }
    t1 = time.time()
    compiled = lowered.compile()
    info["compile_s"] = round(time.time() - t1, 2)
    try:
        ca = compiled.cost_analysis()
        info["cost_analysis"] = {k: float(v) for k, v in ca.items()
                                 if isinstance(v, (int, float)) and k in ("flops", "bytes accessed")}
    except Exception as e:
        info["cost_analysis"] = {"error": str(e)}
    hlo = compiled.as_text()
    colls = parse_collectives(hlo)
    agg: dict[str, dict] = {}
    for c in colls:
        a = agg.setdefault(c["kind"], {"count": 0, "bytes": 0})
        a["count"] += 1
        a["bytes"] += c["bytes"]
    info["collectives"] = agg
    info["wire_bytes_per_dev"] = wire_bytes(colls)
    try:
        info["walked"] = hlowalk.walk(hlo)
    except Exception as e:  # pragma: no cover
        info["walked"] = {"error": f"{type(e).__name__}: {e}"}
    info["ntokens"] = dcfg.batch_slices * dcfg.volume * dcfg.volume  # voxels/step
    info["static_bytes_per_dev"] = 0
    info["hlo_bytes"] = len(hlo)
    info["_hlo"] = hlo
    return info


def lower_cell(arch: str, shape: str, multi_pod: bool, *, overrides: dict | None = None,
               reduced: bool = False) -> dict:
    if arch == "gwlz-nyx":
        return lower_gwlz_cell(multi_pod, overrides=overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    baxes = batch_axes_of(mesh)
    spec = input_specs(arch, shape, reduced=reduced)
    cfg, cell = spec["cfg"], spec["cell"]
    model, _ = build_model(arch, reduced=reduced)
    fam = get_family(arch)

    t0 = time.time()
    params_sds = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))

    train_kw = dict(ARCH_TRAIN_DEFAULTS.get(arch, {}))
    if overrides:
        train_kw.update({k: v for k, v in overrides.items() if k in ("fsdp", "microbatch", "moment_dtype")})
    seq_axis = ARCH_DECODE_SEQ_AXIS.get(shape)
    if overrides and "seq_axis" in overrides:
        seq_axis = overrides["seq_axis"]
    sh_opts = SH.ShardingOptions(fsdp=bool(train_kw.get("fsdp", False)), seq_axis=seq_axis)

    pspecs = SH.param_pspecs(params_sds, sh_opts, mesh)
    p_shard = SH.named(mesh, pspecs)

    ax_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_b = 1
    for a in baxes:
        n_b *= ax_sizes[a]

    def bspec(leaf_name, leaf):
        if leaf.ndim == 0 or leaf.shape[0] % n_b != 0:
            return P(*([None] * leaf.ndim))
        return P(baxes, *([None] * (leaf.ndim - 1)))

    batch_sds = spec["batch"]
    batch_specs = {k: bspec(k, v) for k, v in batch_sds.items()}
    b_shard = {k: NamedSharding(mesh, s) for k, s in batch_specs.items()}

    info: dict = {
        "arch": arch, "shape": shape, "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": int(np.prod(mesh.devices.shape)),
        "settings": {**train_kw, "seq_axis": seq_axis},
        "n_params": count_params(params_sds),
        "active_fraction": active_param_fraction(cfg),
    }

    if cell.kind == "train":
        opts = TrainOptions(**{k: v for k, v in train_kw.items() if k in ("moment_dtype", "fsdp", "microbatch")})
        gp = pspecs if (overrides or {}).get("grad_rs") else None
        step, adam_cfg = make_train_step(model, cfg, opts, mesh, grad_pspecs=gp)
        opt_sds = jax.eval_shape(lambda: adamw.init(params_sds, adam_cfg))
        o_specs = SH.opt_pspecs(opt_sds, pspecs, sh_opts, mesh)
        o_shard = SH.named(mesh, o_specs)
        rng_sds = jax.ShapeDtypeStruct((2,), jnp.uint32)
        jitted = jax.jit(
            lambda p, o, b, r: step(p, o, b, r),
            in_shardings=(p_shard, o_shard, b_shard, NamedSharding(mesh, P())),
            out_shardings=(p_shard, o_shard, NamedSharding(mesh, P())),
        )
        with mesh:  # ambient mesh: bare-P activation constraints resolve
            lowered = jitted.lower(params_sds, opt_sds, batch_sds, rng_sds)
        info["static_bytes_per_dev"] = (
            sharded_bytes(params_sds, pspecs, mesh)
            + sharded_bytes(opt_sds["m"], jax.tree.map(lambda s: s, o_specs["m"], is_leaf=lambda s: isinstance(s, P)), mesh)
            + sharded_bytes(opt_sds["v"], o_specs["v"], mesh)
        )
        ntokens = cell.global_batch * cell.seq_len
    elif cell.kind == "prefill":
        step = make_prefill_step(model, cfg, mesh)
        jitted = jax.jit(step, in_shardings=(p_shard, b_shard))
        with mesh:
            lowered = jitted.lower(params_sds, batch_sds)
        info["static_bytes_per_dev"] = sharded_bytes(params_sds, pspecs, mesh)
        ntokens = cell.global_batch * cell.seq_len
    else:  # decode
        ctx = spec["ctx"]
        B = batch_sds["token"].shape[0]
        cache_sds = jax.eval_shape(lambda: model.init_cache(B, ctx))
        c_specs = SH.cache_pspecs(cache_sds, mesh, sh_opts)
        c_shard = SH.named(mesh, c_specs)
        step = make_decode_step(model, cfg, mesh)
        jitted = jax.jit(
            step,
            in_shardings=(p_shard, c_shard, b_shard),
            out_shardings=(NamedSharding(mesh, P()), c_shard),
        )
        with mesh:
            lowered = jitted.lower(params_sds, cache_sds, batch_sds)
        info["static_bytes_per_dev"] = (
            sharded_bytes(params_sds, pspecs, mesh) + sharded_bytes(cache_sds, c_specs, mesh)
        )
        ntokens = cell.global_batch  # one token per sequence
    info["lower_s"] = round(time.time() - t0, 2)

    t1 = time.time()
    compiled = lowered.compile()
    info["compile_s"] = round(time.time() - t1, 2)

    try:
        ca = compiled.cost_analysis()
        info["cost_analysis"] = {k: float(v) for k, v in ca.items()
                                 if isinstance(v, (int, float)) and k in ("flops", "bytes accessed", "transcendentals")}
    except Exception as e:  # pragma: no cover
        info["cost_analysis"] = {"error": str(e)}
    try:
        ma = compiled.memory_analysis()
        info["memory_analysis"] = {
            k: int(getattr(ma, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(ma, k)
        }
    except Exception as e:  # pragma: no cover
        info["memory_analysis"] = {"error": str(e)}

    hlo = compiled.as_text()
    colls = parse_collectives(hlo)
    agg: dict[str, dict] = {}
    for c in colls:
        a = agg.setdefault(c["kind"], {"count": 0, "bytes": 0})
        a["count"] += 1
        a["bytes"] += c["bytes"]
    info["collectives"] = agg
    info["wire_bytes_per_dev"] = wire_bytes(colls)
    try:
        info["walked"] = hlowalk.walk(hlo)  # trip-count-aware flops/collectives
    except Exception as e:  # pragma: no cover
        info["walked"] = {"error": f"{type(e).__name__}: {e}"}
    info["ntokens"] = ntokens
    info["hlo_bytes"] = len(hlo)
    info["_hlo"] = hlo
    return info


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run: lower+compile every cell")
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", help="shape cell or 'all'")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--reduced", action="store_true", help="reduced configs (CI smoke)")
    ap.add_argument("--set", nargs="*", default=[], help="override k=v (fsdp/microbatch/moment_dtype/seq_axis)")
    ap.add_argument("--tag", default="", help="suffix for output files (perf experiments)")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = {"true": True, "false": False}.get(v.lower(), v)
        if isinstance(overrides[k], str) and overrides[k].isdigit():
            overrides[k] = int(overrides[k])

    archs = list(ARCH_IDS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    if args.arch == "gwlz-nyx":
        shapes = ["vol512_g32"]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            ok, why = (True, "") if arch == "gwlz-nyx" else cell_applicable(arch, shape)
            for multi in meshes:
                mesh_tag = "multi" if multi else "single"
                name = f"{arch}_{shape}_{mesh_tag}{args.tag}"
                path = os.path.join(args.out, name + ".json")
                if not ok:
                    json.dump({"arch": arch, "shape": shape, "mesh": mesh_tag,
                               "skipped": why}, open(path, "w"), indent=1)
                    print(f"SKIP {name}: {why}", flush=True)
                    continue
                if os.path.exists(path) and not overrides and not args.tag:
                    print(f"CACHED {name}", flush=True)
                    continue
                try:
                    info = lower_cell(arch, shape, multi, overrides=overrides, reduced=args.reduced)
                    hlo = info.pop("_hlo", None)
                    if hlo is not None:
                        import zlib as _z
                        with open(path.replace(".json", ".hlo.z"), "wb") as f:
                            f.write(_z.compress(hlo.encode(), 6))
                    json.dump(info, open(path, "w"), indent=1)
                    ca = info.get("cost_analysis", {})
                    print(
                        f"OK {name}: compile={info['compile_s']}s "
                        f"flops={ca.get('flops', float('nan')):.3g} "
                        f"static={info['static_bytes_per_dev']/2**30:.2f}GiB "
                        f"wire={info['wire_bytes_per_dev']/2**30:.3f}GiB",
                        flush=True,
                    )
                except Exception as e:
                    failures += 1
                    json.dump({"arch": arch, "shape": shape, "mesh": mesh_tag,
                               "error": f"{type(e).__name__}: {e}",
                               "traceback": traceback.format_exc()}, open(path, "w"), indent=1)
                    print(f"FAIL {name}: {type(e).__name__}: {e}", flush=True)
    print(f"done; failures={failures}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
