"""Serving driver: ``python -m repro.launch.serve --arch <id> --reduced``.

Batched greedy decoding against the ring-buffer/latent KV caches — the same
decode_step the dry-run lowers for decode_32k / long_500k.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, build_model, get_family
from repro.launch.steps import make_decode_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b", choices=list(ARCH_IDS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--ctx", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    model, cfg = build_model(args.arch, reduced=args.reduced)
    fam = get_family(args.arch)
    params = model.init(jax.random.PRNGKey(args.seed))
    cache = model.init_cache(args.batch, args.ctx, dtype=cfg.compute_dtype)
    step = jax.jit(make_decode_step(model, cfg, mesh=None))

    rng = np.random.default_rng(args.seed)
    prompt = rng.integers(0, cfg.vocab, size=(args.batch, args.prompt_len)).astype(np.int32)
    enc_out = None
    if fam == "encdec":
        enc_feats = jnp.asarray(rng.normal(size=(args.batch, cfg.enc_seq, cfg.d_model)), cfg.compute_dtype)
        enc_out = model.encode(params, enc_feats)

    tok_log = []
    t0 = time.time()
    tok = jnp.asarray(prompt[:, :1])
    for pos in range(args.prompt_len + args.gen_len - 1):
        batch = {"token": tok, "pos": jnp.asarray(pos, jnp.int32)}
        if enc_out is not None:
            batch["enc_out"] = enc_out
        logits, cache = step(params, cache, batch)
        if pos + 1 < args.prompt_len:
            tok = jnp.asarray(prompt[:, pos + 1 : pos + 2])  # teacher-forced prompt
        else:
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            tok_log.append(np.asarray(tok)[:, 0])
    dt = time.time() - t0
    gen = np.stack(tok_log, axis=1)
    n_tok = args.batch * (args.prompt_len + args.gen_len - 1)
    print(f"arch={cfg.name} generated shape={gen.shape} tokens/s={n_tok/dt:,.1f}")
    print("sample:", gen[0][:16].tolist())
    assert np.isfinite(np.asarray(logits, np.float32)).all(), "non-finite logits"
    return gen


if __name__ == "__main__":
    main()
