"""Shared model building blocks: norms, RoPE (incl. M-RoPE), sharding helpers.

Sharding is expressed through *logical axis names* resolved against the active
mesh by :class:`ShardingRules` — the same model code runs on a single CPU
device (rules resolve to no-ops) and on the (pod, data, model) production mesh.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


# ---------------------------------------------------------------------------
# Logical-axis sharding
# ---------------------------------------------------------------------------

# Logical axes used throughout the model zoo.
BATCH, SEQ, HEADS, KV_HEADS, D_MODEL, D_FF, VOCAB, EXPERT, STATE = (
    "batch", "seq", "heads", "kv_heads", "d_model", "d_ff", "vocab", "expert", "state",
)


@dataclass(frozen=True)
class ShardingRules:
    """logical axis -> mesh axis (or None). ``fsdp_axis`` additionally shards
    the non-TP dimension of parameters (ZeRO-3) when set."""

    batch: tuple | str | None = ("pod", "data")
    seq: str | None = None           # set to "data" for sequence-parallel decode
    heads: str | None = "model"
    kv_heads: str | None = "model"
    d_model: str | None = None
    d_ff: str | None = "model"
    vocab: str | None = "model"
    expert: str | None = "model"
    state: str | None = None
    fsdp_axis: str | None = None     # e.g. "data" to shard params over DP too

    def spec(self, *logical: Optional[str]) -> P:
        return P(*(getattr(self, ax) if ax is not None else None for ax in logical))


def logical_shard(x: jax.Array, rules: ShardingRules, *logical: Optional[str]) -> jax.Array:
    """with_sharding_constraint if a mesh is active, else identity."""
    env_mesh = jax.sharding.get_abstract_mesh()
    if env_mesh is None or env_mesh.empty:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, jax.NamedSharding(jax.sharding.get_mesh(), rules.spec(*logical)))
    except (ValueError, TypeError, RuntimeError):
        # no concrete mesh / spec rank mismatch: constraint is best-effort
        return x


def shard_constraint(x: jax.Array, spec: P) -> jax.Array:
    """Constraint against the ambient mesh (jit in-context mesh)."""
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, TypeError, RuntimeError):
        # outside jit or mesh-less context: constraint is best-effort
        return x


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x: [..., S, H, D]; positions: broadcastable to [..., S]."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # [D/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, D/2]
    cos = jnp.cos(ang)[..., None, :]  # [..., S, 1, D/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jax.Array, positions3: jax.Array, sections: tuple[int, ...], theta: float = 1e6
) -> jax.Array:
    """Qwen2-VL multimodal RoPE. positions3: [B, 3, S] (t, h, w streams);
    ``sections`` are half-dim sizes per stream (e.g. (16, 24, 24)).

    The stream-selection is a one-hot einsum (a tiny [3 x d/2] matmul) rather
    than a gather: under GSPMD a gather against batch-sharded positions forced
    involuntary resharding of every q/k tensor (285 GiB/step of wire on the
    qwen2-vl train cell — EXPERIMENTS.md §Perf)."""
    d = x.shape[-1]
    assert sum(sections) == d // 2, (sections, d)
    freqs = rope_freqs(d, theta)  # [D/2]
    stream = jnp.repeat(jnp.arange(3), jnp.asarray(sections), total_repeat_length=d // 2)
    onehot = jax.nn.one_hot(stream, 3, dtype=jnp.float32)  # [d/2, 3]
    pos = jnp.einsum("bks,fk->bsf", positions3.astype(jnp.float32), onehot)  # [B,S,D/2]
    ang = pos * freqs[None, None, :]
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n: int, d: int) -> jax.Array:
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (dim / d))
    out = jnp.zeros((n, d), jnp.float32)
    out = out.at[:, 0::2].set(jnp.sin(ang))
    out = out.at[:, 1::2].set(jnp.cos(ang))
    return out
