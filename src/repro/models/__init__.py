from repro.models.decoder import DecoderLM, LayerSpec, ModelConfig
from repro.models.encdec import EncDecLM

__all__ = ["DecoderLM", "EncDecLM", "LayerSpec", "ModelConfig"]
