"""Unified decoder LM covering dense GQA, MLA, MoE, sliding/chunked attention,
RWKV6, Mamba2 and the Zamba2 hybrid — assembled from a per-layer ``LayerSpec``
pattern.

Layers are grouped into *stages*: maximal runs of a repeating spec period, so
parameters stack as [count, period, ...] and the whole run is one
``lax.scan`` (compact HLO at 126 layers, fast multi-pod compiles).  Caches
stack the same way, and scan threads them through decode.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import attention as A
from repro.models import mlp as M
from repro.models import ssm as S
from repro.models.common import layer_norm, rms_norm, shard_constraint


@dataclass(frozen=True)
class LayerSpec:
    kind: str = "attn"          # attn | mla | rwkv6 | mamba2 | shared_attn
    mask_mode: int = A.MASK_CAUSAL
    window: int = 0             # sliding/chunked extent
    rope_on: bool = True
    rope_theta: float = 1e4
    moe: bool = False           # MoE feed-forward instead of dense
    has_ffn: bool = True        # rwkv6/mamba2 blocks carry their own mixer


@dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    d_ff: int
    vocab: int
    attn: Optional[A.AttnConfig] = None
    moe: Optional[M.MoEConfig] = None
    rwkv: Optional[S.RWKV6Config] = None
    mamba: Optional[S.Mamba2Config] = None
    act: str = "silu"
    norm: str = "rms"
    pattern: tuple[LayerSpec, ...] = ()   # len == n_layers (built by configs/)
    tie_embeddings: bool = False
    logit_softcap: float = 0.0
    emb_scale: bool = False               # gemma-style sqrt(d) embedding scale
    dtype: str = "bf16"
    remat: bool = True
    # encoder-decoder extras (whisper)
    enc_layers: int = 0
    enc_seq: int = 0
    # zamba2: one shared transformer block reused at 'shared_attn' layers
    shared_block: bool = False
    shared_d_ff: int = 0

    @property
    def compute_dtype(self):
        return jnp.bfloat16 if self.dtype == "bf16" else jnp.float32


def default_pattern(n_layers: int, **kw) -> tuple[LayerSpec, ...]:
    return tuple(LayerSpec(**kw) for _ in range(n_layers))


# ---------------------------------------------------------------------------
# stages: group the pattern into (period, count) runs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Stage:
    specs: tuple[LayerSpec, ...]  # one period
    count: int                    # repeats


def build_stages(pattern: tuple[LayerSpec, ...], max_period: int = 8) -> tuple[Stage, ...]:
    """Greedy periodic run-length grouping of the layer pattern."""
    stages: list[Stage] = []
    i = 0
    n = len(pattern)
    while i < n:
        best = (1, 1)  # (period, count)
        for p in range(1, max_period + 1):
            if i + p > n:
                break
            period = pattern[i : i + p]
            count = 1
            while i + (count + 1) * p <= n and pattern[i + count * p : i + (count + 1) * p] == period:
                count += 1
            if p * count > best[0] * best[1] or (p * count == best[0] * best[1] and p < best[0]):
                best = (p, count)
        p, c = best
        stages.append(Stage(specs=pattern[i : i + p], count=c))
        i += p * c
    return tuple(stages)


# ---------------------------------------------------------------------------
# per-layer init/apply
# ---------------------------------------------------------------------------


def _init_layer(key, cfg: ModelConfig, spec: LayerSpec, dtype) -> dict:
    keys = jax.random.split(key, 4)
    p: dict = {}
    if spec.kind in ("attn", "shared_attn"):
        p["ln1"] = jnp.zeros((cfg.d_model,), dtype)
        p["attn"] = A.init_gqa_params(keys[0], cfg.attn, dtype)
    elif spec.kind == "mla":
        p["ln1"] = jnp.zeros((cfg.d_model,), dtype)
        p["attn"] = A.init_mla_params(keys[0], cfg.attn, dtype)
    elif spec.kind == "rwkv6":
        p["ln1"] = jnp.zeros((cfg.d_model,), dtype)
        p["mix"] = S.init_rwkv6_params(keys[0], cfg.rwkv, dtype)
    elif spec.kind == "mamba2":
        p["ln1"] = jnp.zeros((cfg.d_model,), dtype)
        p["mix"] = S.init_mamba2_params(keys[0], cfg.mamba, dtype)
    else:
        raise ValueError(spec.kind)
    if spec.has_ffn:
        p["ln2"] = jnp.zeros((cfg.d_model,), dtype)
        if spec.moe:
            p["ffn"] = M.init_moe_params(keys[1], cfg.d_model, cfg.moe, cfg.act, dtype)
        else:
            d_ff = cfg.shared_d_ff if spec.kind == "shared_attn" and cfg.shared_d_ff else cfg.d_ff
            p["ffn"] = M.init_mlp_params(keys[1], cfg.d_model, d_ff, cfg.act, dtype)
    return p


def _norm(cfg: ModelConfig, x, scale):
    if cfg.norm == "rms":
        return rms_norm(x, scale)
    return layer_norm(x, 1.0 + scale, jnp.zeros_like(scale))


def _apply_layer(params, cfg: ModelConfig, spec: LayerSpec, x, positions, cache):
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = _norm(cfg, x, params["ln1"])
    if spec.kind in ("attn", "shared_attn"):
        y, cache = A.gqa_attention(
            params["attn"], cfg.attn, h, positions,
            mask_mode=spec.mask_mode, window=spec.window,
            rope_on=spec.rope_on, rope_theta=spec.rope_theta, cache=cache,
        )
    elif spec.kind == "mla":
        y, cache = A.mla_attention(params["attn"], cfg.attn, h, positions, cache=cache)
    elif spec.kind == "rwkv6":
        y, tm_state = S.rwkv6_time_mix(params["mix"], cfg.rwkv, h, None if cache is None else cache.get("tm"))
        cache = {"tm": tm_state, **({} if cache is None else {k: v for k, v in cache.items() if k not in ("tm",)})}
    else:  # mamba2
        y, mstate = S.mamba2_mix(params["mix"], cfg.mamba, h, cache)
        cache = mstate
    x = x + y
    if spec.has_ffn:
        h = _norm(cfg, x, params["ln2"])
        if spec.kind == "rwkv6":
            y, cm_state = S.rwkv6_channel_mix(params["mix"], cfg.rwkv, h, None if cache is None or "cm" not in cache else cache["cm"])
            cache = {**cache, "cm": cm_state}
        elif spec.moe:
            y, aux = M.apply_moe(params["ffn"], h, cfg.moe, cfg.act)
        else:
            y = M.apply_mlp(params["ffn"], h, cfg.act)
        x = x + y
    return x, cache, aux


def _init_layer_cache(cfg: ModelConfig, spec: LayerSpec, batch: int, ctx: int, dtype):
    if spec.kind in ("attn", "shared_attn"):
        return A.init_gqa_cache(batch, ctx, cfg.attn, window=spec.window, dtype=dtype)
    if spec.kind == "mla":
        return A.init_mla_cache(batch, ctx, cfg.attn, dtype=dtype)
    if spec.kind == "rwkv6":
        r = cfg.rwkv
        return {
            "tm": {
                "shift": jnp.zeros((batch, cfg.d_model), dtype),
                "wkv": jnp.zeros((batch, r.n_heads, r.head_dim, r.head_dim), jnp.float32),
            },
            "cm": jnp.zeros((batch, cfg.d_model), dtype),
        }
    if spec.kind == "mamba2":
        m = cfg.mamba
        return {
            "conv": jnp.zeros((batch, m.conv_width - 1, m.d_inner + 2 * m.d_state), dtype),
            "ssm": jnp.zeros((batch, m.n_heads, m.head_dim, m.d_state), jnp.float32),
        }
    raise ValueError(spec.kind)


# ---------------------------------------------------------------------------
# the model
# ---------------------------------------------------------------------------


class DecoderLM:
    """init/apply-style module (explicit params pytree, fully jit-friendly)."""

    def __init__(self, cfg: ModelConfig):
        assert len(cfg.pattern) == cfg.n_layers, (cfg.name, len(cfg.pattern), cfg.n_layers)
        self.cfg = cfg
        self.stages = build_stages(cfg.pattern)

    # -- params ------------------------------------------------------------
    def init(self, key: jax.Array) -> dict:
        cfg = self.cfg
        dtype = cfg.compute_dtype
        n_stage = len(self.stages)
        keys = jax.random.split(key, n_stage + 3)
        params: dict = {
            "embed": (jax.random.normal(keys[0], (cfg.vocab, cfg.d_model)) * cfg.d_model ** -0.5).astype(dtype),
            "final_norm": jnp.zeros((cfg.d_model,), dtype),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = (
                jax.random.normal(keys[1], (cfg.d_model, cfg.vocab)) * cfg.d_model ** -0.5
            ).astype(dtype)
        if cfg.shared_block:
            params["shared"] = _init_layer(keys[2], cfg, LayerSpec(kind="shared_attn"), dtype)
        for si, stage in enumerate(self.stages):
            def init_one(k):
                ks = jax.random.split(k, len(stage.specs))
                return [
                    None if sp.kind == "shared_attn" and cfg.shared_block else _init_layer(kk, cfg, sp, dtype)
                    for kk, sp in zip(ks, stage.specs)
                ]

            stage_keys = jax.random.split(keys[3 + si], stage.count)
            per = [init_one(k) for k in stage_keys]  # [count][period] of dict|None
            stacked = []
            for pi in range(len(stage.specs)):
                items = [per[c][pi] for c in range(stage.count)]
                if items[0] is None:
                    stacked.append(None)
                else:
                    stacked.append(jax.tree.map(lambda *xs: jnp.stack(xs), *items))
            params[f"stage{si}"] = stacked
        return params

    # -- caches ------------------------------------------------------------
    def init_cache(self, batch: int, ctx: int, dtype=jnp.bfloat16) -> list:
        caches = []
        for stage in self.stages:
            percache = []
            for sp in stage.specs:
                one = _init_layer_cache(self.cfg, sp, batch, ctx, dtype)
                percache.append(jax.tree.map(lambda x: jnp.broadcast_to(x, (stage.count, *x.shape)).copy() if stage.count else x, one))
            caches.append(percache)
        return caches

    # -- forward -----------------------------------------------------------
    def apply(
        self,
        params: dict,
        tokens: jax.Array,           # [B, S] int32
        positions: jax.Array,        # [S] or [B,3,S] (mrope)
        cache: list | None = None,
        batch_axes=None,
    ) -> tuple[jax.Array, list | None, jax.Array]:
        """Returns (logits [B,S,V], new_cache, aux_loss)."""
        cfg = self.cfg
        x = params["embed"][tokens]  # gather; vocab-sharded -> all-reduce
        if cfg.emb_scale:
            x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
        if batch_axes is not None:
            x = shard_constraint(x, P(batch_axes, None, None))
        aux_total = jnp.zeros((), jnp.float32)
        new_cache: list | None = [] if cache is not None else None

        for si, stage in enumerate(self.stages):
            stage_params = params[f"stage{si}"]
            stage_cache = cache[si] if cache is not None else None

            def body(carry, xs):
                x, aux = carry
                lp_list, lc_list = xs
                new_lcs = []
                for pi, sp in enumerate(stage.specs):
                    lp = lp_list[pi] if lp_list[pi] is not None else params["shared"]
                    lc = lc_list[pi] if lc_list is not None else None
                    x, nlc, a = _apply_layer(lp, cfg, sp, x, positions, lc)
                    if batch_axes is not None:
                        x = shard_constraint(x, P(batch_axes, None, None))
                    new_lcs.append(nlc)
                return (x, aux + a), new_lcs

            body_fn = jax.checkpoint(body) if (cfg.remat and cache is None) else body

            xs = (stage_params, stage_cache)
            if stage.count == 1:
                # unrolled single repeat: strip the leading stack axis
                lp = jax.tree.map(lambda t: t[0], stage_params)
                lc = jax.tree.map(lambda t: t[0], stage_cache) if stage_cache is not None else None
                (x, aux_total), ncs = body_fn((x, aux_total), (lp, lc))
                if new_cache is not None:
                    new_cache.append(jax.tree.map(lambda t: t[None], ncs))
            else:
                (x, aux_total), ncs = jax.lax.scan(body_fn, (x, aux_total), xs)
                if new_cache is not None:
                    new_cache.append(ncs)

        x = _norm(cfg, x, params["final_norm"])
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = jnp.einsum("bsd,dv->bsv", x, head)
        if cfg.logit_softcap:
            logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
        return logits, new_cache, aux_total

    # -- steps --------------------------------------------------------------
    def loss(self, params, tokens, targets, positions, batch_axes=None):
        logits, _, aux = self.apply(params, tokens, positions, batch_axes=batch_axes)
        logits = logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
        nll = (logz - gold).mean()
        zloss = 1e-4 * (logz ** 2).mean()
        return nll + zloss + aux, {"nll": nll, "aux": aux}

    def decode_step(self, params, cache, token, pos, batch_axes=None):
        """token: [B,1]; pos: scalar int32 absolute position."""
        if self.cfg.attn is not None and self.cfg.attn.mrope_sections is not None and pos.ndim == 0:
            positions = jnp.full((token.shape[0], 3, 1), pos, jnp.int32)
        else:
            positions = pos[None] if pos.ndim == 0 else pos
        logits, cache, _ = self.apply(params, token, positions, cache=cache, batch_axes=batch_axes)
        return logits[:, -1], cache


