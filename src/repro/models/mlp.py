"""Feed-forward blocks: SwiGLU / GELU MLPs and capacity-based MoE.

The MoE dispatch is the GShard/Switch TPU formulation: top-k routing with a
per-expert capacity, position-in-expert via cumsum, dense [E, C, d] einsums
(expert axis shardable over "model"), combine weighted by router probs.
FLOPs therefore scale with *active* tokens x capacity_factor — roofline-honest,
unlike a dense one-hot-over-all-experts formulation.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                       # per-expert hidden dim
    n_shared: int = 0               # shared (always-on) experts
    shared_d_ff: int = 0
    router: str = "softmax"         # softmax | sigmoid (deepseek-v3)
    capacity_factor: float = 1.25
    first_dense: int = 0            # leading dense layers (deepseek: 3)
    aux_loss_coef: float = 0.001


def init_mlp_params(key, d_model: int, d_ff: int, act: str, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 3)
    s_in, s_out = d_model ** -0.5, d_ff ** -0.5
    p = {
        "w_up": (jax.random.normal(ks[0], (d_model, d_ff)) * s_in).astype(dtype),
        "w_down": (jax.random.normal(ks[1], (d_ff, d_model)) * s_out).astype(dtype),
    }
    if act == "silu":  # SwiGLU gate
        p["w_gate"] = (jax.random.normal(ks[2], (d_model, d_ff)) * s_in).astype(dtype)
    return p


def apply_mlp(params: dict, x: jax.Array, act: str) -> jax.Array:
    if act == "silu":
        h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, params["w_gate"]))
        h = h * jnp.einsum("bsd,df->bsf", x, params["w_up"])
    elif act == "gelu":
        h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, params["w_up"]))
    else:
        raise ValueError(act)
    return jnp.einsum("bsf,fd->bsd", h, params["w_down"])


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------


def init_moe_params(key, d_model: int, cfg: MoEConfig, act: str, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 5)
    E, F = cfg.n_experts, cfg.d_ff
    s_in, s_out = d_model ** -0.5, F ** -0.5
    p = {
        "router": (jax.random.normal(ks[0], (d_model, E)) * s_in).astype(jnp.float32),
        "we_up": (jax.random.normal(ks[1], (E, d_model, F)) * s_in).astype(dtype),
        "we_down": (jax.random.normal(ks[2], (E, F, d_model)) * s_out).astype(dtype),
    }
    if act == "silu":
        p["we_gate"] = (jax.random.normal(ks[3], (E, d_model, F)) * s_in).astype(dtype)
    if cfg.n_shared:
        p["shared"] = init_mlp_params(ks[4], d_model, cfg.shared_d_ff or cfg.d_ff, act, dtype)
    return p


def _route(logits: jax.Array, cfg: MoEConfig) -> tuple[jax.Array, jax.Array]:
    """logits [T, E] -> (weights [T, k], expert ids [T, k])."""
    if cfg.router == "sigmoid":  # deepseek-v3: sigmoid scores, normalized top-k
        scores = jax.nn.sigmoid(logits)
        w, idx = jax.lax.top_k(scores, cfg.top_k)
        w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        w, idx = jax.lax.top_k(probs, cfg.top_k)
    return w, idx


def apply_moe(params: dict, x: jax.Array, cfg: MoEConfig, act: str) -> tuple[jax.Array, jax.Array]:
    """x: [B, S, d] -> (out [B, S, d], aux load-balance loss scalar)."""
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)
    E, k = cfg.n_experts, cfg.top_k
    cap = int(max(1, round(T * k * cfg.capacity_factor / E)))

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), params["router"])
    w, idx = _route(logits, cfg)  # [T,k]

    # Load-balance aux loss (Switch): E * sum_e f_e * p_e
    probs = jax.nn.softmax(logits, axis=-1)
    f = jnp.zeros(E).at[idx.reshape(-1)].add(1.0) / (T * k)
    aux = E * jnp.sum(f * probs.mean(0)) * cfg.aux_loss_coef

    # position-in-expert via cumsum over the flattened (T*k) dispatch order
    flat_e = idx.reshape(-1)  # [T*k]
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # [T*k, E]
    cum = jnp.cumsum(onehot, axis=0) - onehot
    pos_in_e = jnp.take_along_axis(cum, flat_e[:, None], axis=1)[:, 0]
    keep = pos_in_e < cap
    wflat = w.reshape(-1) * keep  # dropped tokens contribute nothing

    # scatter tokens into [E, C, d]
    slot = jnp.where(keep, flat_e * cap + pos_in_e, E * cap)  # overflow -> trash row
    xtk = jnp.repeat(xt, k, axis=0)  # token row per (t, k) dispatch
    buf = jnp.zeros((E * cap + 1, d), x.dtype).at[slot].add(xtk)
    xe = buf[:-1].reshape(E, cap, d)

    if act == "silu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, params["we_gate"]))
        h = h * jnp.einsum("ecd,edf->ecf", xe, params["we_up"])
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", xe, params["we_up"]))
    ye = jnp.einsum("ecf,efd->ecd", h, params["we_down"])  # [E, C, d]

    # gather back: each (t, k) reads its slot
    yflat = ye.reshape(E * cap, d)
    ytk = jnp.where(keep[:, None], yflat[jnp.clip(slot, 0, E * cap - 1)], 0.0)
    out = (ytk * wflat[:, None]).reshape(T, k, d).sum(1).reshape(B, S, d)

    if "shared" in params:
        out = out + apply_mlp(params["shared"], x, act)
    return out.astype(x.dtype), aux
