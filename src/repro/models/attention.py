"""Attention variants for the assigned architectures.

One code path covers: full causal (granite/yi/llama3/qwen2-vl/whisper-dec),
sliding-window (gemma3 5:1 local:global), chunked-local + NoPE-global
(llama4 iRoPE), cross-attention (whisper), and MLA latent attention
(deepseek-v3) with the absorbed decode form.

Masks are built lazily from position iotas inside each query block — never a
materialized [S, S] tensor — so prefill_32k fits and FLOPs stay honest.
Mask modes: 0 = full causal, 1 = sliding window, 2 = chunked local,
3 = bidirectional (encoder / cross).
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import apply_mrope, apply_rope, shard_constraint

MASK_CAUSAL, MASK_SLIDING, MASK_CHUNKED, MASK_BIDIR = 0, 1, 2, 3

# O(S*w) banded attention for sliding/chunked layers (vs lazily-masked O(S^2)).
# Default ON; REPRO_BANDED_ATTN=0 reproduces the pre-optimization baseline
# recorded in EXPERIMENTS.md §Perf.
BANDED_DEFAULT = os.environ.get("REPRO_BANDED_ATTN", "1") == "1"


@dataclass(frozen=True)
class MLAConfig:
    q_lora: int = 1536
    kv_lora: int = 512
    rope_dim: int = 64
    nope_dim: int = 128
    v_dim: int = 128


@dataclass(frozen=True)
class AttnConfig:
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_model: int
    rope_theta: float = 1e4
    qk_norm: bool = False
    mla: Optional[MLAConfig] = None
    mrope_sections: Optional[tuple[int, ...]] = None
    mrope_theta: float = 1e6
    softcap: float = 0.0  # gemma-style logit softcapping (0 = off)


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------


def init_gqa_params(key, cfg: AttnConfig, dtype=jnp.float32) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    s = d ** -0.5
    p = {
        "wq": (jax.random.normal(k1, (d, H, hd)) * s).astype(dtype),
        "wk": (jax.random.normal(k2, (d, K, hd)) * s).astype(dtype),
        "wv": (jax.random.normal(k3, (d, K, hd)) * s).astype(dtype),
        "wo": (jax.random.normal(k4, (H, hd, d)) * (H * hd) ** -0.5).astype(dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def init_mla_params(key, cfg: AttnConfig, dtype=jnp.float32) -> dict:
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 7)
    s = d ** -0.5
    return {
        "w_dq": (jax.random.normal(ks[0], (d, m.q_lora)) * s).astype(dtype),
        "w_uq": (jax.random.normal(ks[1], (m.q_lora, H, m.nope_dim + m.rope_dim)) * m.q_lora ** -0.5).astype(dtype),
        "w_dkv": (jax.random.normal(ks[2], (d, m.kv_lora)) * s).astype(dtype),
        "w_kr": (jax.random.normal(ks[3], (d, m.rope_dim)) * s).astype(dtype),
        "w_uk": (jax.random.normal(ks[4], (m.kv_lora, H, m.nope_dim)) * m.kv_lora ** -0.5).astype(dtype),
        "w_uv": (jax.random.normal(ks[5], (m.kv_lora, H, m.v_dim)) * m.kv_lora ** -0.5).astype(dtype),
        "wo": (jax.random.normal(ks[6], (H, m.v_dim, d)) * (H * m.v_dim) ** -0.5).astype(dtype),
        "q_ln": jnp.zeros((m.q_lora,), dtype),
        "kv_ln": jnp.zeros((m.kv_lora,), dtype),
    }


# ---------------------------------------------------------------------------
# masked blockwise attention core
# ---------------------------------------------------------------------------


def _mask_logits(scores, q_pos, k_pos, mask_mode, window):
    """scores: [..., Lq, Lk]; q_pos: [Lq]; k_pos: [Lk]."""
    dq = q_pos[:, None]
    dk = k_pos[None, :]
    causal = dk <= dq
    if mask_mode == MASK_BIDIR:
        allow = jnp.ones_like(causal)
    elif mask_mode == MASK_CAUSAL:
        allow = causal
    elif mask_mode == MASK_SLIDING:
        allow = causal & (dk > dq - window)
    elif mask_mode == MASK_CHUNKED:
        allow = causal & (dk // window == dq // window)
    else:
        raise ValueError(mask_mode)
    return jnp.where(allow, scores, -1e30)


def _softcap(x, cap):
    return cap * jnp.tanh(x / cap) if cap else x


@partial(jax.jit, static_argnames=("mask_mode", "window", "q_block", "softcap", "banded"))
def attend(
    q: jax.Array,          # [B, Lq, H, D]
    k: jax.Array,          # [B, Lk, K, D]
    v: jax.Array,          # [B, Lk, K, Dv]
    q_positions: jax.Array,  # [Lq]
    k_positions: jax.Array,  # [Lk]
    *,
    mask_mode: int = MASK_CAUSAL,
    window: int = 0,
    q_block: int = 512,
    softcap: float = 0.0,
    banded: bool = False,
) -> jax.Array:
    """GQA attention, blockwise over queries (lazy masks, fp32 softmax).

    ``banded=True`` (sliding/chunked modes with contiguous positions, i.e.
    prefill/train): each query block attends only to the [window + block]
    key slice it can actually see, instead of lazily masking all Lk keys —
    an O(S·w) algorithm instead of O(S²) (EXPERIMENTS.md §Perf, gemma3 cell).
    """
    B, Lq, H, D = q.shape
    Lk = k.shape[1]
    K = k.shape[2]
    Dv = v.shape[3]
    G = H // K  # query heads per kv head
    scale = D ** -0.5
    qg = q.reshape(B, Lq, K, G, D)

    bq = min(q_block, Lq)
    if Lq % bq != 0:
        bq = Lq  # irregular sizes: single block
    nb = Lq // bq

    use_band = (
        banded and nb > 1 and window > 0
        and mask_mode in (MASK_SLIDING, MASK_CHUNKED) and window % bq == 0
    )

    def block(qb, qpos_b):
        # qb: [B, bq, K, G, D] against the full key set
        s = jnp.einsum("bqkgd,bskd->bkgqs", qb.astype(jnp.float32), k.astype(jnp.float32)) * scale
        s = _softcap(s, softcap)
        s = _mask_logits(s, qpos_b, k_positions, mask_mode, window)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))

    def block_banded(qb, qpos_b):
        # visible keys: [qpos0 - window + bq .. qpos0 + bq) for sliding
        # (chunked: the containing chunk) -> a static-size kw slice.
        kw = min(window + bq, Lk)
        q0 = qpos_b[0]
        if mask_mode == MASK_SLIDING:
            start = jnp.clip(q0 + bq - kw, 0, Lk - kw)
        else:  # chunked: containing chunk start (window % bq == 0)
            start = jnp.clip((q0 // window) * window, 0, Lk - kw)
        kb = jax.lax.dynamic_slice_in_dim(k, start, kw, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(v, start, kw, axis=1)
        kpos_b = start + jnp.arange(kw)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qb.astype(jnp.float32), kb.astype(jnp.float32)) * scale
        s = _softcap(s, softcap)
        s = _mask_logits(s, qpos_b, kpos_b, mask_mode, window)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bkgqs,bskd->bqkgd", p, vb.astype(jnp.float32))

    body = block_banded if use_band else block
    if nb <= 1:
        out = block(qg, q_positions)
    else:
        qs = qg.reshape(B, nb, bq, K, G, D).transpose(1, 0, 2, 3, 4, 5)
        ps = q_positions.reshape(nb, bq)
        out = jax.lax.map(lambda args: body(*args), (qs, ps))
        out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, Lq, K, G, Dv)
    return out.reshape(B, Lq, H, Dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA layer (covers full/sliding/chunked/bidir + M-RoPE + ring-buffer cache)
# ---------------------------------------------------------------------------


def _maybe_qknorm(x, scale):
    from repro.models.common import rms_norm

    return rms_norm(x, scale) if scale is not None else x


def gqa_attention(
    params: dict,
    cfg: AttnConfig,
    x: jax.Array,            # [B, S, d]
    positions: jax.Array,    # [S] (or [B,3,S] for M-RoPE)
    *,
    mask_mode: int = MASK_CAUSAL,
    window: int = 0,
    rope_on: bool = True,
    rope_theta: float | None = None,
    cache: dict | None = None,
    kv_source: jax.Array | None = None,  # cross-attention (whisper)
) -> tuple[jax.Array, dict | None]:
    """Returns (output [B,S,d], updated cache).

    Cache layout: {"k": [B, C, K, D], "v": [B, C, K, D], "pos": int32 scalar}
    where C = full context for global layers or the ring-buffer size
    (= window) for sliding/chunked layers.
    """
    theta = cfg.rope_theta if rope_theta is None else rope_theta
    src = x if kv_source is None else kv_source
    q = jnp.einsum("bsd,dhe->bshe", x, params["wq"])
    k = jnp.einsum("bsd,dke->bske", src, params["wk"])
    v = jnp.einsum("bsd,dke->bske", src, params["wv"])
    if cfg.qk_norm:
        q = _maybe_qknorm(q, params["q_norm"])
        k = _maybe_qknorm(k, params["k_norm"])

    if rope_on and kv_source is None:
        if cfg.mrope_sections is not None:
            q = apply_mrope(q, positions, cfg.mrope_sections, cfg.mrope_theta)
            k = apply_mrope(k, positions, cfg.mrope_sections, cfg.mrope_theta)
            pos1d = positions[:, 0, :].max(axis=0)  # causal ordering stream
        else:
            q = apply_rope(q, positions, theta)
            k = apply_rope(k, positions, theta)
            pos1d = positions
    else:
        pos1d = positions if positions.ndim == 1 else positions[:, 0, :].max(axis=0)

    if cache is None:
        k_pos = jnp.arange(k.shape[1]) if kv_source is not None else pos1d
        out = attend(q, k, v, pos1d, k_pos, mask_mode=mask_mode, window=window,
                     softcap=cfg.softcap, banded=BANDED_DEFAULT)
    else:
        # decode: append new kv into (ring) cache, attend q over it.
        C = cache["k"].shape[1]
        pos = cache["pos"]  # scalar int32: absolute position of this token
        slot = pos % C
        ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0) if k.shape[1] == 1 else (0, 0, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0) if v.shape[1] == 1 else (0, 0, 0, 0))
        k_abs = pos - ((pos - jnp.arange(C)) % C)  # absolute position per slot
        # unwritten slots get a FUTURE position so the causal test excludes
        # them (a past sentinel would pass `dk <= dq` and act as an attention
        # sink of zero-vectors).
        k_positions = jnp.where(k_abs < 0, (1 << 30), k_abs)
        out = attend(q, ck, cv, pos1d[None] if pos1d.ndim == 0 else pos1d, k_positions,
                     mask_mode=mask_mode, window=window if window else 0,
                     softcap=cfg.softcap)
        cache = {"k": ck, "v": cv, "pos": pos + q.shape[1]}
    y = jnp.einsum("bshe,hed->bsd", out, params["wo"])
    return y, cache


def init_gqa_cache(batch: int, ctx: int, cfg: AttnConfig, *, window: int = 0, dtype=jnp.bfloat16) -> dict:
    C = window if window else ctx
    return {
        "k": jnp.zeros((batch, C, cfg.n_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, C, cfg.n_kv_heads, cfg.head_dim), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


# ---------------------------------------------------------------------------
# MLA (deepseek-v3): latent KV cache, absorbed decode
# ---------------------------------------------------------------------------


def mla_attention(
    params: dict,
    cfg: AttnConfig,
    x: jax.Array,
    positions: jax.Array,
    *,
    cache: dict | None = None,
) -> tuple[jax.Array, dict | None]:
    """Multi-head Latent Attention.  Cache stores only (c_kv, k_rope):
    kv_lora + rope_dim floats per token — the paper-relevant memory saving.
    """
    from repro.models.common import rms_norm

    m = cfg.mla
    H = cfg.n_heads
    cq = rms_norm(jnp.einsum("bsd,dr->bsr", x, params["w_dq"]), params["q_ln"])
    q = jnp.einsum("bsr,rhe->bshe", cq, params["w_uq"])  # e = nope + rope
    q_nope, q_rope = q[..., : m.nope_dim], q[..., m.nope_dim :]

    c_kv = rms_norm(jnp.einsum("bsd,dr->bsr", x, params["w_dkv"]), params["kv_ln"])
    k_rope = jnp.einsum("bsd,de->bse", x, params["w_kr"])  # shared across heads

    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]

    scale = (m.nope_dim + m.rope_dim) ** -0.5

    if cache is None:
        # prefill/train: expand latents (compute-optimal at long Lq)
        k_nope = jnp.einsum("bsr,rhe->bshe", c_kv, params["w_uk"])
        v = jnp.einsum("bsr,rhe->bshe", c_kv, params["w_uv"])
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (*k_rope.shape[:2], H, m.rope_dim))], -1
        )
        q_full = jnp.concatenate([q_nope, q_rope], -1)
        out = attend(q_full, k_full, v, positions, positions, mask_mode=MASK_CAUSAL)
        y = jnp.einsum("bshe,hed->bsd", out, params["wo"])
        return y, None

    # decode: absorbed form — attend in the latent space.
    pos = cache["pos"]
    C = cache["c_kv"].shape[1]
    cc = jax.lax.dynamic_update_slice(cache["c_kv"], c_kv, (0, pos % C, 0))
    cr = jax.lax.dynamic_update_slice(cache["k_rope"], k_rope, (0, pos % C, 0))
    # q_nope absorbed through w_uk: [B,1,H,nope] x [r,H,nope] -> [B,1,H,r]
    q_lat = jnp.einsum("bshe,rhe->bshr", q_nope, params["w_uk"])
    s = jnp.einsum("bshr,btr->bhst", q_lat.astype(jnp.float32), cc.astype(jnp.float32))
    s = s + jnp.einsum("bshe,bte->bhst", q_rope.astype(jnp.float32), cr.astype(jnp.float32))
    k_positions = jnp.arange(C)
    valid = k_positions <= pos
    s = jnp.where(valid[None, None, None, :], s * scale, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhst,btr->bshr", p, cc.astype(jnp.float32))  # [B,1,H,r]
    out = jnp.einsum("bshr,rhe->bshe", o_lat, params["w_uv"].astype(jnp.float32))
    y = jnp.einsum("bshe,hed->bsd", out.astype(x.dtype), params["wo"])
    return y, {"c_kv": cc, "k_rope": cr, "pos": pos + x.shape[1]}


def init_mla_cache(batch: int, ctx: int, cfg: AttnConfig, dtype=jnp.bfloat16) -> dict:
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((batch, ctx, m.kv_lora), dtype),
        "k_rope": jnp.zeros((batch, ctx, m.rope_dim), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }
