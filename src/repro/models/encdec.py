"""Encoder-decoder LM (Whisper backbone).

Per the brief, the audio frontend (mel + conv downsampling) is a STUB:
``input_specs()`` supplies precomputed frame embeddings [B, T_enc, d].  The
backbone is complete: bidirectional encoder, causal decoder with
cross-attention, learned decoder positions, pre-LN (+ biasless layer norm to
keep one norm implementation; noted in DESIGN.md)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import attention as A
from repro.models import mlp as M
from repro.models.common import shard_constraint, sinusoidal_positions
from repro.models.decoder import ModelConfig, _norm


class EncDecLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def init(self, key: jax.Array) -> dict:
        cfg = self.cfg
        dtype = cfg.compute_dtype
        n = cfg.enc_layers + 2 * cfg.n_layers + 2
        keys = iter(jax.random.split(key, 2 * n + 8))

        def enc_layer():
            return {
                "ln1": jnp.zeros((cfg.d_model,), dtype),
                "attn": A.init_gqa_params(next(keys), cfg.attn, dtype),
                "ln2": jnp.zeros((cfg.d_model,), dtype),
                "ffn": M.init_mlp_params(next(keys), cfg.d_model, cfg.d_ff, cfg.act, dtype),
            }

        def dec_layer():
            return {
                "ln1": jnp.zeros((cfg.d_model,), dtype),
                "attn": A.init_gqa_params(next(keys), cfg.attn, dtype),
                "ln_x": jnp.zeros((cfg.d_model,), dtype),
                "xattn": A.init_gqa_params(next(keys), cfg.attn, dtype),
                "ln2": jnp.zeros((cfg.d_model,), dtype),
                "ffn": M.init_mlp_params(next(keys), cfg.d_model, cfg.d_ff, cfg.act, dtype),
            }

        stack = lambda items: jax.tree.map(lambda *xs: jnp.stack(xs), *items)
        return {
            "embed": (jax.random.normal(next(keys), (cfg.vocab, cfg.d_model)) * cfg.d_model ** -0.5).astype(dtype),
            "pos_dec": (jax.random.normal(next(keys), (cfg.enc_seq + 8192, cfg.d_model)) * 0.01).astype(dtype),
            "enc": stack([enc_layer() for _ in range(cfg.enc_layers)]),
            "enc_norm": jnp.zeros((cfg.d_model,), dtype),
            "dec": stack([dec_layer() for _ in range(cfg.n_layers)]),
            "final_norm": jnp.zeros((cfg.d_model,), dtype),
        }

    # -- encoder -------------------------------------------------------------
    def encode(self, params, enc_feats: jax.Array, batch_axes=None) -> jax.Array:
        """enc_feats: [B, T, d] precomputed frame embeddings (stub frontend)."""
        cfg = self.cfg
        T = enc_feats.shape[1]
        x = enc_feats + sinusoidal_positions(T, cfg.d_model).astype(enc_feats.dtype)
        pos = jnp.arange(T)

        def body(x, lp):
            h = _norm(cfg, x, lp["ln1"])
            y, _ = A.gqa_attention(lp["attn"], cfg.attn, h, pos, mask_mode=A.MASK_BIDIR, rope_on=False)
            x = x + y
            h = _norm(cfg, x, lp["ln2"])
            x = x + M.apply_mlp(lp["ffn"], h, cfg.act)
            if batch_axes is not None:
                x = shard_constraint(x, P(batch_axes, None, None))
            return x, None

        body = jax.checkpoint(body) if cfg.remat else body
        x, _ = jax.lax.scan(body, x, params["enc"])
        return _norm(cfg, x, params["enc_norm"])

    # -- decoder -------------------------------------------------------------
    def decode(self, params, enc_out, tokens, positions, cache=None, batch_axes=None):
        """tokens: [B,S]; cache: {"self": stacked, "cross": stacked} or None."""
        cfg = self.cfg
        x = params["embed"][tokens] + params["pos_dec"][positions]
        pos = positions

        def body(carry, xs):
            x = carry
            lp, lc = xs
            h = _norm(cfg, x, lp["ln1"])
            y, nsc = A.gqa_attention(lp["attn"], cfg.attn, h, pos, mask_mode=A.MASK_CAUSAL,
                                     rope_on=False, cache=None if lc is None else lc["self"])
            x = x + y
            h = _norm(cfg, x, lp["ln_x"])
            # cross-attention: precomputed (k, v) live in the cache at decode
            y, _ = A.gqa_attention(lp["xattn"], cfg.attn, h, pos, mask_mode=A.MASK_BIDIR,
                                   rope_on=False, kv_source=enc_out)
            x = x + y
            h = _norm(cfg, x, lp["ln2"])
            x = x + M.apply_mlp(lp["ffn"], h, cfg.act)
            if batch_axes is not None:
                x = shard_constraint(x, P(batch_axes, None, None))
            return x, ({"self": nsc} if lc is not None else None)

        body_fn = jax.checkpoint(body) if (cfg.remat and cache is None) else body
        x, new_cache = jax.lax.scan(body_fn, x, (params["dec"], cache))
        x = _norm(cfg, x, params["final_norm"])
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
        return logits, new_cache

    # -- steps ---------------------------------------------------------------
    def loss(self, params, enc_feats, tokens, targets, batch_axes=None):
        enc_out = self.encode(params, enc_feats, batch_axes)
        positions = jnp.arange(tokens.shape[1])
        logits, _ = self.decode(params, enc_out, tokens, positions, batch_axes=batch_axes)
        logits = logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
        nll = (logz - gold).mean()
        return nll + 1e-4 * (logz ** 2).mean(), {"nll": nll}

    def init_cache(self, batch: int, ctx: int, dtype=jnp.bfloat16):
        one = A.init_gqa_cache(batch, ctx, self.cfg.attn, dtype=dtype)
        return jax.tree.map(lambda t: jnp.broadcast_to(t, (self.cfg.n_layers, *t.shape)).copy(), {"self": one})

    def decode_step(self, params, cache, enc_out, token, pos, batch_axes=None):
        positions = pos[None] if pos.ndim == 0 else pos
        logits, cache = self.decode(params, enc_out, token, positions, cache=cache, batch_axes=batch_axes)
        return logits[:, -1], cache
