"""Attention-free sequence mixers: RWKV6 (Finch) and Mamba2 (SSD).

Both are O(1)-state recurrences — the archs that make the long_500k cell
feasible.  Training uses lax.scan over time (compact HLO; the dry-run cost
analysis charges the true sequential FLOPs); decode is a single-step state
update with no sequence-length tensor at all.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class RWKV6Config:
    d_model: int
    head_dim: int = 64
    d_ff: int = 14336
    lora_mix: int = 32
    lora_decay: int = 64

    @property
    def n_heads(self) -> int:
        return self.d_model // self.head_dim


@dataclass(frozen=True)
class Mamba2Config:
    d_model: int
    d_state: int = 64
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim


# ---------------------------------------------------------------------------
# RWKV6 time-mix + channel-mix
# ---------------------------------------------------------------------------

_MIX_NAMES = ("r", "k", "v", "w", "g")


def init_rwkv6_params(key, cfg: RWKV6Config, dtype=jnp.float32) -> dict:
    d, r = cfg.d_model, cfg.lora_mix
    ks = jax.random.split(key, 12)
    s = d ** -0.5
    return {
        "mu_x": jnp.full((d,), 0.5, dtype),
        "mu": jnp.full((5, d), 0.5, dtype),
        "mix_w1": (jax.random.normal(ks[0], (d, 5 * r)) * s).astype(dtype),
        "mix_w2": (jax.random.normal(ks[1], (5, r, d)) * r ** -0.5).astype(dtype),
        "w0": jnp.zeros((d,), dtype),  # decay bias (per channel)
        "decay_w1": (jax.random.normal(ks[2], (d, cfg.lora_decay)) * s).astype(dtype),
        "decay_w2": (jax.random.normal(ks[3], (cfg.lora_decay, d)) * cfg.lora_decay ** -0.5).astype(dtype),
        "u": jnp.zeros((cfg.n_heads, cfg.head_dim), dtype),  # per-head bonus
        "wr": (jax.random.normal(ks[4], (d, d)) * s).astype(dtype),
        "wk": (jax.random.normal(ks[5], (d, d)) * s).astype(dtype),
        "wv": (jax.random.normal(ks[6], (d, d)) * s).astype(dtype),
        "wg": (jax.random.normal(ks[7], (d, d)) * s).astype(dtype),
        "wo": (jax.random.normal(ks[8], (d, d)) * s).astype(dtype),
        "ln_out": jnp.ones((d,), dtype),  # per-head group norm scale
        # channel mix
        "cmix_r": jnp.full((d,), 0.5, dtype),
        "cmix_k": jnp.full((d,), 0.5, dtype),
        "cm_wr": (jax.random.normal(ks[9], (d, d)) * s).astype(dtype),
        "cm_wk": (jax.random.normal(ks[10], (d, cfg.d_ff)) * s).astype(dtype),
        "cm_wv": (jax.random.normal(ks[11], (cfg.d_ff, d)) * cfg.d_ff ** -0.5).astype(dtype),
    }


def _token_shift(x: jax.Array, prev: jax.Array | None) -> jax.Array:
    """x: [B,T,d] -> previous-token stream; ``prev`` is the carry for decode."""
    if prev is None:
        return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    return jnp.concatenate([prev[:, None], x[:, :-1]], axis=1) if x.shape[1] > 1 else prev[:, None]


def rwkv6_time_mix(params, cfg: RWKV6Config, x, state):
    """x: [B,T,d]; state: {"shift": [B,d], "wkv": [B,H,hd,hd]} or None (zeros).

    Returns (out, new_state)."""
    B, T, d = x.shape
    H, hd = cfg.n_heads, cfg.head_dim
    if state is None:
        state = {
            "shift": jnp.zeros((B, d), x.dtype),
            "wkv": jnp.zeros((B, H, hd, hd), jnp.float32),
        }
    xs = _token_shift(x, state["shift"])
    xx = xs - x
    xxx = x + xx * params["mu_x"]
    mix = jnp.tanh(jnp.einsum("btd,dr->btr", xxx, params["mix_w1"]))
    mix = mix.reshape(B, T, 5, -1)
    dmu = jnp.einsum("btfr,frd->fbtd", mix, params["mix_w2"])  # [5,B,T,d]
    feeds = {n: x + xx * (params["mu"][i] + dmu[i]) for i, n in enumerate(_MIX_NAMES)}

    r = jnp.einsum("btd,de->bte", feeds["r"], params["wr"]).reshape(B, T, H, hd)
    k = jnp.einsum("btd,de->bte", feeds["k"], params["wk"]).reshape(B, T, H, hd)
    v = jnp.einsum("btd,de->bte", feeds["v"], params["wv"]).reshape(B, T, H, hd)
    g = jax.nn.silu(jnp.einsum("btd,de->bte", feeds["g"], params["wg"]))
    # data-dependent decay in (0,1): w = exp(-exp(w0 + lora(x_w)))
    dw = jnp.einsum("btd,dr->btr", jnp.tanh(jnp.einsum("btd,dr->btr", feeds["w"], params["decay_w1"])), params["decay_w2"])
    w = jnp.exp(-jnp.exp(params["w0"] + dw)).reshape(B, T, H, hd)

    u = params["u"]

    def step(s, inp):
        rt, kt, vt, wt = inp  # [B,H,hd] each
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)  # outer product
        out = jnp.einsum("bhk,bhkv->bhv", rt, s + u[None, :, :, None] * kv)
        s = s * wt[..., None] + kv  # decay applied along the key dim
        return s, out

    seq = (
        r.transpose(1, 0, 2, 3).astype(jnp.float32),
        k.transpose(1, 0, 2, 3).astype(jnp.float32),
        v.transpose(1, 0, 2, 3).astype(jnp.float32),
        w.transpose(1, 0, 2, 3).astype(jnp.float32),
    )
    new_wkv, outs = jax.lax.scan(step, state["wkv"], seq)
    out = outs.transpose(1, 0, 2, 3).reshape(B, T, d).astype(x.dtype)
    # per-head group norm, then gate and project
    out = out.reshape(B, T, H, hd)
    mu_o = out.mean(-1, keepdims=True)
    var_o = out.var(-1, keepdims=True)
    out = ((out - mu_o) * jax.lax.rsqrt(var_o + 1e-5)).reshape(B, T, d) * params["ln_out"]
    out = jnp.einsum("btd,de->bte", out * g, params["wo"])
    new_state = {"shift": x[:, -1], "wkv": new_wkv}
    return out, new_state


def rwkv6_channel_mix(params, cfg: RWKV6Config, x, state):
    if state is None:
        state = jnp.zeros((x.shape[0], x.shape[2]), x.dtype)
    xs = _token_shift(x, state)
    xx = xs - x
    xr = x + xx * params["cmix_r"]
    xk = x + xx * params["cmix_k"]
    rr = jax.nn.sigmoid(jnp.einsum("btd,de->bte", xr, params["cm_wr"]))
    kk = jnp.square(jax.nn.relu(jnp.einsum("btd,df->btf", xk, params["cm_wk"])))
    out = rr * jnp.einsum("btf,fd->btd", kk, params["cm_wv"])
    return out, x[:, -1]


# ---------------------------------------------------------------------------
# Mamba2 (SSD, scalar-per-head decay)
# ---------------------------------------------------------------------------


def init_mamba2_params(key, cfg: Mamba2Config, dtype=jnp.float32) -> dict:
    d, di, N, H = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.n_heads
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    conv_ch = di + 2 * N
    return {
        "w_in": (jax.random.normal(ks[0], (d, 2 * di + 2 * N + H)) * s).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_width, conv_ch)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.zeros((H,), dtype),
        "D": jnp.ones((H,), dtype),
        "dt_bias": jnp.zeros((H,), dtype),
        "norm": jnp.ones((di,), dtype),
        "w_out": (jax.random.normal(ks[2], (di, d)) * di ** -0.5).astype(dtype),
    }


def _causal_conv1d(x, w, b, state=None):
    """x: [B,T,C]; w: [W,C] depthwise. state: [B,W-1,C] carry for decode."""
    W = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(W))
    new_state = xp[:, -(W - 1) :]
    return jax.nn.silu(out + b), new_state


def mamba2_mix(params, cfg: Mamba2Config, x, state):
    """x: [B,T,d]; state {"conv": [B,W-1,C], "ssm": [B,H,P,N]} or None."""
    B, T, d = x.shape
    di, N, H, Pdim = cfg.d_inner, cfg.d_state, cfg.n_heads, cfg.head_dim
    if state is None:
        state = {
            "conv": jnp.zeros((B, cfg.conv_width - 1, di + 2 * N), x.dtype),
            "ssm": jnp.zeros((B, H, Pdim, N), jnp.float32),
        }
    zxbcdt = jnp.einsum("btd,de->bte", x, params["w_in"])
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * N], axis=-1)
    xbc, conv_state = _causal_conv1d(xbc, params["conv_w"], params["conv_b"], state["conv"])
    xin, Bmat, Cmat = jnp.split(xbc, [di, di + N], axis=-1)  # [B,T,di],[B,T,N],[B,T,N]
    dt = jax.nn.softplus(dt + params["dt_bias"])  # [B,T,H]
    A = -jnp.exp(params["A_log"].astype(jnp.float32))  # [H] negative

    xh = xin.reshape(B, T, H, Pdim)

    def step(s, inp):
        xt, bt, ct, dtt = inp  # [B,H,P],[B,N],[B,N],[B,H]
        decay = jnp.exp(dtt.astype(jnp.float32) * A)  # [B,H]
        upd = jnp.einsum("bhp,bn->bhpn", xt * dtt[..., None], bt)
        s = s * decay[..., None, None] + upd
        yt = jnp.einsum("bhpn,bn->bhp", s, ct)
        return s, yt

    seq = (
        xh.transpose(1, 0, 2, 3).astype(jnp.float32),
        Bmat.transpose(1, 0, 2).astype(jnp.float32),
        Cmat.transpose(1, 0, 2).astype(jnp.float32),
        dt.transpose(1, 0, 2).astype(jnp.float32),
    )
    new_ssm, ys = jax.lax.scan(step, state["ssm"], seq)
    y = ys.transpose(1, 0, 2, 3)  # [B,T,H,P]
    y = y + params["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, T, di).astype(x.dtype)
    # gated RMSNorm then out-proj
    y = y * jax.nn.silu(z)
    dt_ = y.dtype
    y32 = y.astype(jnp.float32)
    y = (y32 * jax.lax.rsqrt(jnp.mean(y32 * y32, -1, keepdims=True) + 1e-6)).astype(dt_) * params["norm"]
    out = jnp.einsum("bte,ed->btd", y, params["w_out"])
    return out, {"conv": conv_state, "ssm": new_ssm}
