from repro.checkpoint.gwlz_ckpt import compress_tensor, decompress_tensor
from repro.checkpoint.manager import CheckpointManager

__all__ = ["CheckpointManager", "compress_tensor", "decompress_tensor"]
