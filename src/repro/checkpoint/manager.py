"""Checkpoint manager: async save, atomic publish, elastic restore.

Checkpoints are mesh-agnostic (full logical arrays), so restoring onto a
different mesh/device count is just re-device_put with the new shardings —
the elastic-scaling path (runtime/elastic.py) and the restart path
(runtime/fault.py) both go through here.  An optional GWLZ stage compresses
large tensors error-bounded (gwlz_ckpt.py).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np

_SEP = "/"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(k.key) if isinstance(k, jax.tree_util.DictKey) else str(k.idx)
            for k in path
        )
        flat[key] = np.asarray(leaf)
    return flat


def _treedef_of(tree):
    return jax.tree_util.tree_structure(tree)


class CheckpointManager:
    """save(step, tree) -> ckpt_dir/step_N/{arrays.npz, manifest.json}."""

    def __init__(self, directory: str, *, keep: int = 3, async_save: bool = True,
                 gwlz_rel_eb: float | None = None):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self.gwlz_rel_eb = gwlz_rel_eb
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree, *, block: bool = False) -> None:
        flat = _flatten(tree)  # host copy happens here, synchronously
        self.wait()
        if self.async_save and not block:
            self._thread = threading.Thread(target=self._write, args=(step, flat), daemon=True)
            self._thread.start()
        else:
            self._write(step, flat)

    def _write(self, step: int, flat: dict[str, np.ndarray]) -> None:
        tmp = os.path.join(self.dir, f".tmp_step_{step}")
        final = os.path.join(self.dir, f"step_{step:08d}")
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "time": time.time(), "keys": {}, "gwlz": {}}
        plain: dict[str, np.ndarray] = {}
        for k, v in flat.items():
            manifest["keys"][k] = {"shape": list(v.shape), "dtype": str(v.dtype)}
            if self.gwlz_rel_eb is not None and v.size >= 65536 and str(v.dtype) in ("float32", "bfloat16"):
                from repro.checkpoint.gwlz_ckpt import compress_tensor

                blob = compress_tensor(v, rel_eb=self.gwlz_rel_eb)
                with open(os.path.join(tmp, k.replace(_SEP, "__") + ".gwlz"), "wb") as f:
                    f.write(blob)
                manifest["gwlz"][k] = True
            else:
                if str(v.dtype) == "bfloat16":  # np.savez can't serialize bf16
                    v = v.view(np.uint16)
                plain[k.replace(_SEP, "__")] = v
        np.savez(os.path.join(tmp, "arrays.npz"), **plain)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"), ignore_errors=True)

    # -- restore --------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_"):
                out.append(int(d[5:]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, target_tree, step: int | None = None, *, shardings=None):
        """Restore into the structure of ``target_tree`` (shapes validated).
        ``shardings``: optional pytree of NamedSharding for elastic re-shard."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        manifest = json.load(open(os.path.join(d, "manifest.json")))
        npz = np.load(os.path.join(d, "arrays.npz"))

        paths, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
        shard_leaves = (
            jax.tree_util.tree_leaves(shardings, is_leaf=lambda s: hasattr(s, "spec"))
            if shardings is not None else [None] * len(paths)
        )
        leaves = []
        for (path, leaf), shard in zip(paths, shard_leaves):
            key = _SEP.join(
                str(k.key) if isinstance(k, jax.tree_util.DictKey) else str(k.idx)
                for k in path
            )
            fkey = key.replace(_SEP, "__")
            if manifest["gwlz"].get(key):
                from repro.checkpoint.gwlz_ckpt import decompress_tensor

                arr = decompress_tensor(open(os.path.join(d, fkey + ".gwlz"), "rb").read())
            else:
                arr = npz[fkey]
                if manifest["keys"][key]["dtype"] == "bfloat16":
                    import ml_dtypes

                    arr = arr.view(ml_dtypes.bfloat16)
            exp = tuple(manifest["keys"][key]["shape"])
            assert tuple(arr.shape) == exp == tuple(leaf.shape), (key, arr.shape, leaf.shape)
            arr = np.asarray(jax.numpy.asarray(arr, dtype=leaf.dtype))
            leaves.append(jax.device_put(arr, shard) if shard is not None else arr)
        return jax.tree_util.tree_unflatten(treedef, leaves)
