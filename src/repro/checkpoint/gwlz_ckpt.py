"""GWLZ-compressed checkpoint tensors (the paper's technique applied to the
framework's own state — DESIGN.md §4).

Weight tensors are error-bounded-compressed with the SZ substrate; tensors
large enough to amortize a few enhancers get the full GWLZ treatment (grouped
residual enhancers with a short training budget).  Restores satisfy
|w - w'| <= rel_eb * range(w) elementwise, which for trained networks at
rel_eb <= 1e-4 is well under the noise floor of bf16 casting.
"""
from __future__ import annotations

import struct

import jax.numpy as jnp
import numpy as np

from repro.core.pipeline import GWLZ
from repro.core.trainer import GWLZTrainConfig
from repro.sz.szjax import SZCompressed, SZCompressor

_MAGIC = b"GWCK"


def _as_volume(v: np.ndarray) -> tuple[np.ndarray, tuple]:
    """SZ operates on 1-3D grids; fold higher ranks into 3D."""
    shape = v.shape
    if v.ndim <= 3:
        return v, shape
    lead = int(np.prod(shape[:-2]))
    return v.reshape(lead, shape[-2], shape[-1]), shape


def compress_tensor(
    v: np.ndarray,
    *,
    rel_eb: float = 1e-4,
    enhance_threshold: int = 1 << 22,
    epochs: int = 30,
    n_groups: int = 8,
) -> bytes:
    orig_dtype = str(v.dtype)
    vol, shape = _as_volume(np.asarray(v, np.float32))
    use_gwlz = vol.size >= enhance_threshold
    if use_gwlz:
        cfg = GWLZTrainConfig(n_groups=n_groups, epochs=epochs, batch_size=8)
        artifact, _stats = GWLZ(train_cfg=cfg, clamp_to_bound=True).compress(
            jnp.asarray(vol), rel_eb=rel_eb
        )
    else:
        artifact, _ = SZCompressor(predictor="interp", order="cubic", backend="zlib").compress(
            jnp.asarray(vol), rel_eb=rel_eb
        )
    payload = artifact.to_bytes()
    dt = orig_dtype.encode()
    head = _MAGIC + struct.pack("<BB", len(shape), len(dt)) + dt
    head += struct.pack(f"<{len(shape)}q", *shape)
    return head + payload


def decompress_tensor(blob: bytes) -> np.ndarray:
    assert blob[:4] == _MAGIC
    ndim, dlen = struct.unpack_from("<BB", blob, 4)
    off = 6
    dtype = blob[off : off + dlen].decode()
    off += dlen
    shape = struct.unpack_from(f"<{ndim}q", blob, off)
    off += 8 * ndim
    artifact = SZCompressed.from_bytes(blob[off:])
    if "gwlz" in artifact.extras:
        out = GWLZ(clamp_to_bound=True).decompress(artifact)
    else:
        out = SZCompressor().decompress(artifact)
    return np.asarray(out, np.float32).reshape(shape).astype(dtype)
