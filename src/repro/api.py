"""One front door for the compression stack.

Callers get a scenario-independent surface — container choice (monolithic
``SZJX`` vs tiled ``GWTC``), enhancer attachment, and random-access decode
all hide behind a numpy-like handle:

    from repro import api

    vol = api.compress(x, eb=1e-3, tiled=True, enhance=True)  # CompressedVolume
    api.save("field.gwlz", vol)

    vol = api.open("field.gwlz")          # sniffs the magic, picks the decoder
    full = np.asarray(vol)                # full decode (cached once)
    roi  = vol[8:40, :, 16:32]            # lazy slice; tiled artifacts decode
                                          # only the intersecting entropy lanes

Multi-field datasets persist as one ``GWDS`` envelope (named fields sharing
an offset index — docs/DATASET_FORMAT.md):

    api.save("snapshot.gwds", {"temperature": vol_t, "baryon_density": vol_b})
    ds = api.open("snapshot.gwds")
    ds["temperature"][0:16, :, :]

Opening is mmap-backed and lazy — only the lanes a read intersects are
ever paged in — and handles are context managers over the mapping:

    with api.open("field.gwlz") as vol:
        roi = vol[8:40, :, 16:32]

Out-of-core compression streams tile batches through a bounded-memory
executor (docs/STREAMING.md) instead of materializing the volume:

    api.compress_stream("huge.npy", "huge.gwlz", abs_eb=1e-3,
                        mem_budget=256 << 20)

Reference: docs/API.md.  The shell surface is ``python -m repro.cli``.
"""
from __future__ import annotations

import io
import itertools
import mmap as _mmap
import os
import struct
import threading
from collections.abc import Iterator, Mapping

import numpy as np

from repro.core.pipeline import GWLZ, GWLZStats
from repro.core.trainer import GWLZTrainConfig
from repro.errors import CorruptContainerError, CorruptLaneError, IntegrityError
from repro.exec.cache import TileCache
from repro.sz import artifact as A
from repro.sz import tiled as _tiled
from repro.sz.szjax import SZCompressor
from repro.sz.tiled import LaneStore, TiledCompressed, region_tiles

__all__ = [
    "CompressedVolume",
    "CorruptContainerError",
    "CorruptLaneError",
    "Dataset",
    "DecodeStats",
    "IntegrityError",
    "compress",
    "compress_stream",
    "open",
    "save",
    "from_bytes",
    "GWDS_MAGIC",
]

_VERIFY_POLICIES = ("none", "lazy", "full")
_CORRUPT_POLICIES = ("raise", "quarantine")


def _apply_verify(artifact, verify: str, on_corrupt: str, fill_value: float):
    """Install a verification policy on a parsed artifact and, under
    ``verify="full"``, checksum every lane up front (docs/ROBUSTNESS.md).
    Monolithic ``SZJX`` artifacts carry no per-lane CRCs — the policy is a
    no-op there, as it is for pre-checksum ``GWTC`` containers."""
    if verify not in _VERIFY_POLICIES:
        raise ValueError(f"verify must be one of {_VERIFY_POLICIES}, got {verify!r}")
    if on_corrupt not in _CORRUPT_POLICIES:
        raise ValueError(
            f"on_corrupt must be one of {_CORRUPT_POLICIES}, got {on_corrupt!r}")
    if isinstance(artifact, TiledCompressed):
        artifact.verify = verify
        artifact.on_corrupt = on_corrupt
        artifact.fill_value = float(fill_value)
        if verify == "full":
            _tiled.verify_lanes(artifact)
    return artifact

_builtin_open = open  # shadowed below by the façade's open()

GWDS_MAGIC = A.GWDS_MAGIC
_GWDS_VERSION = A.GWDS_VERSION
# v1/v2 header: magic, version, pad x3, count (v1: n_fields; v2: reserved —
# the field count of a streamed envelope lands in the footer)
_GWDS_HDR = struct.Struct("<4sB3xI")
# per-field index entry tail (after the name): absolute offset, length
_GWDS_ENTRY = struct.Struct("<QQ")

# Default byte cap for the per-handle decoded-tile LRU cache.
DEFAULT_TILE_CACHE_BYTES = int(
    os.environ.get("REPRO_TILE_CACHE_BYTES", 256 << 20))


def _release_resources(resources: tuple) -> None:
    """Best-effort release of handle-owned mmap/file resources, in order
    (views before their mmap, the mmap before its file)."""
    for r in resources:
        try:
            if isinstance(r, memoryview):
                r.release()
            else:
                r.close()
        except (BufferError, OSError):  # pragma: no cover - best effort
            pass


class DecodeStats:
    """Per-handle decode observability: ``tiles_decoded`` (entropy lanes
    actually decoded by this handle), ``tiles_total`` (lanes in the
    artifact), and ``cache_hits`` (reads served from the decoded-tile cache,
    another thread's in-flight decode, or the one-shot full-decode cache).

    Counters are guarded by a per-handle lock and EXACT under concurrent
    region reads — ``tiles_decoded + cache_hits`` equals the number of
    lane touches across every thread (the serving daemon's ``/metrics``
    is built on these, so lost updates would silently skew hit rates).
    When the volume carries train-time
    :class:`~repro.core.pipeline.GWLZStats` (the paper metrics), their
    attributes forward through this object, so ``vol.stats.psnr_gwlz``
    keeps working.  The module-global ``repro.sz.tiled.DECODE_STATS`` is the
    deprecated cross-handle mirror of the same counts."""

    def __init__(self, tiles_total: int, train: GWLZStats | None = None):
        self._lock = threading.Lock()
        self.tiles_decoded = 0  # guarded-by: _lock
        self.tiles_total = tiles_total
        self.cache_hits = 0  # guarded-by: _lock
        # lanes whose CRC check failed under on_corrupt="quarantine" — these
        # decode as the fill value instead of raising (docs/ROBUSTNESS.md)
        self.quarantined = 0  # guarded-by: _lock
        self._train = train

    def record(self, *, decoded: int = 0, hits: int = 0) -> None:
        """Atomically account one read's lane touches."""
        with self._lock:
            self.tiles_decoded += decoded
            self.cache_hits += hits

    def record_quarantined(self, n: int) -> None:
        """Absolute update from the artifact's (grow-only) quarantine set."""
        with self._lock:
            if n > self.quarantined:
                self.quarantined = n

    def __getattr__(self, name):
        train = self.__dict__.get("_train")
        if train is not None and not name.startswith("_"):
            return getattr(train, name)
        raise AttributeError(
            f"DecodeStats has no attribute {name!r} (train-time GWLZStats "
            "are only attached by enhanced compression)")

    def __repr__(self) -> str:
        s = (f"DecodeStats(tiles_decoded={self.tiles_decoded}, "
             f"tiles_total={self.tiles_total}, cache_hits={self.cache_hits}")
        if self.quarantined:
            s += f", quarantined={self.quarantined}"
        return s + (", +train)" if self._train is not None else ")")


# ---------------------------------------------------------------------------
# the handle
# ---------------------------------------------------------------------------

# Process-wide namespace allocator for tile-cache keys: every handle keys its
# entries as ``(ns, tile_id)`` so MANY handles can share one budgeted
# TileCache (the serving daemon's pool) without id collisions.
_VOL_NS = itertools.count(1)


class CompressedVolume:
    """Lazy numpy-like handle over a compressed artifact.

    Wraps either container behind one interface: ``shape``/``dtype``/
    ``nbytes``/``stats``/``size_report()``, ``np.asarray(vol)`` for the full
    decode, and numpy-style slicing.  Slicing routes to the random-access
    region decoder on tiled artifacts (only intersecting entropy lanes are
    touched; an attached GWLZ enhancer runs per decoded tile) and to
    crop-after-decode on monolithic ones, where the full decode is computed
    once and cached.  Region and full decode are bit-identical by the
    stack's construction, so the same consumer code works on either
    container.

    ``tile_cache`` injects a SHARED :class:`TileCache` (docs/SERVING.md):
    the handle namespaces its keys with ``cache_ns`` (default: a fresh
    process-unique id), never clears entries it does not own, and on
    :meth:`close` drops only its own namespace.
    """

    def __init__(self, artifact: A.Artifact, *, stats: GWLZStats | None = None,
                 pipeline: GWLZ | None = None, cache_bytes: int | None = None,
                 tile_cache: TileCache | None = None, cache_ns=None,
                 decode_batcher=None):
        self.artifact = artifact
        self.train_stats = stats  # GWLZStats from enhanced compression, or None
        self.pipeline = pipeline or GWLZ()
        # optional cross-request DecodeBatcher (exec/cache.py): owned claimed
        # lanes are decoded through a shared micro-batched dispatch instead of
        # one device call per request (the serving pool injects this)
        self.decode_batcher = decode_batcher
        self._cache: np.ndarray | None = None  # one-shot full-decode cache
        tiles_total = artifact.n_tiles if isinstance(artifact, TiledCompressed) else 1
        self.stats = DecodeStats(tiles_total, train=stats)
        self._owns_cache = tile_cache is None
        self.tile_cache = tile_cache if tile_cache is not None else TileCache(
            DEFAULT_TILE_CACHE_BYTES if cache_bytes is None else cache_bytes)
        self.cache_ns = cache_ns if cache_ns is not None else next(_VOL_NS)
        self._resources: tuple = ()  # mmap/file handles owned by this handle
        self._closed = False

    # -- lifecycle ---------------------------------------------------------

    def _adopt_resources(self, resources: tuple) -> None:
        """Take ownership of open/mmap resources (released by close())."""
        self._resources = tuple(resources)

    def _ensure_open(self) -> None:
        if self._closed:
            raise ValueError("operation on a closed CompressedVolume")

    def close(self) -> None:
        """Drop the decode caches and release the backing mmap (if any).

        Idempotent; after close, decoding raises.  ``api.open`` handles are
        context managers: ``with api.open(p) as vol: ...``."""
        if self._closed:
            return
        self._closed = True
        self._cache = None
        if self._owns_cache:
            self.tile_cache.clear()
        else:  # shared cache: evict only this handle's namespace
            self.tile_cache.drop_namespace(self.cache_ns)
        lanes = getattr(self.artifact, "tile_blobs", None)
        if isinstance(lanes, LaneStore):
            lanes.release()
        _release_resources(self._resources)
        self._resources = ()

    def __enter__(self) -> "CompressedVolume":
        self._ensure_open()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    # -- metadata ----------------------------------------------------------

    @property
    def shape(self) -> tuple[int, ...]:
        return tuple(self.artifact.shape)

    @property
    def dtype(self) -> np.dtype:
        return np.dtype(np.float32)

    @property
    def ndim(self) -> int:
        return len(self.shape)

    @property
    def size(self) -> int:
        return int(np.prod(self.shape))

    @property
    def nbytes(self) -> int:
        """Compressed size — what :func:`save` writes to disk."""
        return self.artifact.nbytes

    @property
    def eb_abs(self) -> float:
        return float(self.artifact.eb_abs)

    @property
    def tiled(self) -> bool:
        return isinstance(self.artifact, TiledCompressed)

    @property
    def enhanced(self) -> bool:
        """True when a trained GWLZ enhancer model rides in the artifact."""
        return "gwlz" in self.artifact.extras

    def size_report(self) -> dict:
        return self.artifact.size_report()

    def to_bytes(self) -> bytes:
        return self.artifact.to_bytes()

    def __repr__(self) -> str:
        kind = "GWTC tiled" if self.tiled else "SZJX"
        enh = "+gwlz" if self.enhanced else ""
        return (f"CompressedVolume({kind}{enh}, shape={self.shape}, "
                f"eb_abs={self.eb_abs:.4g}, nbytes={self.nbytes})")

    # -- decode ------------------------------------------------------------

    def decode(self) -> np.ndarray:
        """Full decode (enhancer applied when attached), cached once.

        The returned array is marked read-only: it IS the cache (and
        monolithic slicing returns views of it), so caller mutation would
        otherwise corrupt every later decode from this handle.  Copy to
        mutate."""
        self._ensure_open()
        if self._cache is None:
            self._cache = np.asarray(self.pipeline.decode(self.artifact))
            self._cache.setflags(write=False)
            self.stats.record(decoded=self.stats.tiles_total)
            self._sync_quarantine()
        else:
            self.stats.record(hits=self.stats.tiles_total)
        return self._cache

    def _sync_quarantine(self) -> None:
        """Mirror the artifact's quarantined-lane set into the handle stats
        (the set only grows, so an absolute copy is race-safe)."""
        q = getattr(self.artifact, "quarantined", None)
        if q:
            self.stats.record_quarantined(len(q))

    def _tiles_for(self, ids: list[int]) -> np.ndarray:
        """Final (enhanced) tile values for the given lane ids, through the
        size-capped (possibly shared) LRU with single-flight coalescing:
        cached tiles return as-is, lanes nobody is decoding are claimed and
        entropy-decode in ONE batched pipeline call, and lanes another
        thread already claimed are awaited instead of decoded twice — so
        concurrent overlapping ROIs cost each lane exactly one decode.
        Lookups/claims lock inside :class:`TileCache`; decoding runs outside
        the lock.  An abandoned claim (the owner's decode raised) wakes the
        waiters, one of which re-claims and retries (hitting the same
        deterministic error if the lane is truly corrupt)."""
        cache, ns = self.tile_cache, self.cache_ns
        found: dict[int, np.ndarray] = {}
        decoded = 0
        pending = list(dict.fromkeys(ids))
        while pending:
            got, mine, theirs = cache.claim([(ns, i) for i in pending])
            for (_n, i), v in got.items():
                found[i] = v
            if mine:
                mine_ids = [k[1] for k in mine]
                try:
                    got = self._decode_claimed(mine_ids)
                except BaseException:
                    cache.abandon(mine)
                    raise
                for k in mine:
                    tile = got[k[1]]
                    cache.fulfill(k, tile)
                    found[k[1]] = tile
                decoded += len(mine)
            pending = []
            for k, flight in theirs.items():
                v = cache.wait(flight)
                if v is None:  # owner abandoned: re-claim this lane
                    pending.append(k[1])
                else:
                    found[k[1]] = v
        self.stats.record(decoded=decoded, hits=len(ids) - decoded)
        self._sync_quarantine()
        # deprecated module mirror: lanes the request touched (legacy
        # semantics predate the cache, where touched == entropy-decoded)
        _tiled._mirror_stats(len(ids), self.stats.tiles_total)
        return np.stack([found[i] for i in ids])

    def _decode_claimed(self, mine_ids: list[int]) -> dict[int, np.ndarray]:
        """Decode lanes this request owns claims for: one direct pipeline
        call, or — with a ``decode_batcher`` attached — a shared micro-batched
        dispatch coalescing concurrent requests to this volume.  The batcher
        group key is the cache namespace (volume identity in a shared pool)."""

        def decode(ids: list[int]) -> dict[int, np.ndarray]:
            dec = np.asarray(self.pipeline.decode_tiles(self.artifact, ids))
            return {i: np.ascontiguousarray(dec[j])
                    for j, i in enumerate(ids)}

        if self.decode_batcher is None:
            return decode(mine_ids)
        return self.decode_batcher.submit(self.cache_ns, mine_ids, decode)

    def __array__(self, dtype=None, copy=None) -> np.ndarray:
        arr = self.decode()
        if dtype is not None and np.dtype(dtype) != arr.dtype:
            return arr.astype(dtype)
        if copy:
            return arr.copy()
        return arr

    def __getitem__(self, key) -> np.ndarray:
        """Numpy-style slicing (ints, slices with any positive step,
        Ellipsis; missing trailing axes are full slices).

        Tiled artifacts ALWAYS route through the region decoder — partial
        reads never pay for non-intersecting lanes (and never populate the
        full-decode cache); monolithic artifacts crop the cached full
        decode."""
        self._ensure_open()
        specs = self._normalize_key(key)
        out_empty = any(hi <= lo for lo, hi, _step, _sq in specs)
        if out_empty:
            shape = tuple(_strided_len(lo, hi, step)
                          for lo, hi, step, sq in specs if not sq)
            return np.empty(shape, np.float32)
        if self.tiled:
            roi = tuple(slice(lo, hi) for lo, hi, _s, _q in specs)
            ids, geom = region_tiles(self.artifact, roi)
            tiles = self._tiles_for(ids.tolist())
            block = _tiled.assemble_region(tiles, geom, self.artifact.tile)
            origin = [lo for lo, _h, _s, _q in specs]
        else:
            block = self.decode()
            origin = [0] * self.ndim
        crop = tuple(
            lo - o if sq else slice(lo - o, hi - o, step)
            for (lo, hi, step, sq), o in zip(specs, origin))
        out = block[crop]
        # container-independent contract: tiled slices are fresh writable
        # arrays, so monolithic crops (views of the read-only cache) copy
        return out if out.flags.writeable else out.copy()

    def _normalize_key(self, key) -> list[tuple[int, int, int, bool]]:
        """key -> per-dim (lo, hi, step, squeeze) with 0 <= lo,hi <= dim."""
        if not isinstance(key, tuple):
            key = (key,)
        if any(k is Ellipsis for k in key):
            i = key.index(Ellipsis)
            if any(k is Ellipsis for k in key[i + 1:]):
                raise IndexError("an index can only have a single ellipsis")
            fill = self.ndim - (len(key) - 1)
            key = key[:i] + (slice(None),) * fill + key[i + 1:]
        if len(key) > self.ndim:
            raise IndexError(
                f"too many indices for a {self.ndim}-d compressed volume")
        key = key + (slice(None),) * (self.ndim - len(key))
        specs = []
        for k, d in zip(key, self.shape):
            if isinstance(k, (int, np.integer)):
                i = int(k) + d if k < 0 else int(k)
                if not 0 <= i < d:
                    raise IndexError(f"index {int(k)} out of bounds for dim of size {d}")
                specs.append((i, i + 1, 1, True))
            elif isinstance(k, slice):
                start, stop, step = k.indices(d)
                if step < 1:
                    raise IndexError(
                        "negative-step slicing is not supported on a "
                        "CompressedVolume; decode with np.asarray() first")
                specs.append((start, max(start, stop), step, False))
            else:
                raise IndexError(
                    f"unsupported index {k!r}; use ints, slices, or Ellipsis")
        return specs


def _strided_len(lo: int, hi: int, step: int) -> int:
    return max(0, -(-(hi - lo) // step))


# ---------------------------------------------------------------------------
# compress
# ---------------------------------------------------------------------------


def compress(
    x,
    *,
    eb: float | None = None,
    abs_eb: float | None = None,
    tiled: bool = False,
    tile=(64, 64, 64),
    enhance: bool | GWLZTrainConfig = False,
    predictor: str = "interp",
    order: str = "cubic",
    backend: str = "huffman+zlib",
    max_levels: int = 5,
    clamp_to_bound: bool = False,
    callback=None,
) -> CompressedVolume:
    """Compress ``x`` into a :class:`CompressedVolume` handle.

    ``eb`` is the *relative* error bound (scaled by the value range);
    ``abs_eb`` is absolute — pass exactly one.  ``tiled=True`` selects the
    random-access ``GWTC`` container over the tile grid ``tile``;
    ``predictor``/``order``/``backend`` configure the transform and entropy
    stages on either path.  ``enhance`` trains group-wise GWLZ enhancers and
    attaches them to the artifact: ``True`` uses the default
    :class:`GWLZTrainConfig`, or pass a config instance; the handle's
    ``stats`` then carries the paper's metrics (PSNR/CR/overhead)."""
    sz = SZCompressor(predictor, order, backend, max_levels)
    if not enhance:
        if tiled:
            artifact, _recon = sz.compress_tiled(x, tile, rel_eb=eb, abs_eb=abs_eb)
        else:
            artifact, _recon = sz.compress(x, rel_eb=eb, abs_eb=abs_eb)
        return CompressedVolume(
            artifact, pipeline=GWLZ(sz=sz, clamp_to_bound=clamp_to_bound))
    cfg = enhance if isinstance(enhance, GWLZTrainConfig) else GWLZTrainConfig()
    gw = GWLZ(sz=sz, train_cfg=cfg, clamp_to_bound=clamp_to_bound)
    return gw.compress_volume(
        x, tiled=tiled, tile=tile, rel_eb=eb, abs_eb=abs_eb, callback=callback)


def compress_stream(
    source,
    out,
    *,
    eb: float | None = None,
    abs_eb: float | None = None,
    tile=(64, 64, 64),
    mem_budget: int = 256 << 20,
    predictor: str = "lorenzo",
    order: str = "cubic",
    backend: str = "huffman+zlib",
    max_levels: int = 5,
    enhance: "bool | GWLZTrainConfig" = False,
    shape=None,
    resume: bool = False,
    retry=None,
):
    """Out-of-core compress: stream ``source`` into a ``GWTC`` container at
    ``out`` without ever materializing the volume (docs/STREAMING.md).

    ``source`` is a ``.npy`` path, an array/``np.memmap``, a
    :class:`repro.exec.TileSource`, or an iterator of axis-0 slabs (pass
    ``shape=``); ``out`` a path, file object, or an open
    :class:`repro.exec.GWTCWriter` (e.g. ``GWDSWriter.stream_field``).  The
    executor reads tile batches sized against ``mem_budget``, overlaps
    device prequant+predict with host entropy coding, and appends lanes
    through the incremental writer — the tile index lands in the container
    footer on finalize.  ``enhance`` trains group-wise GWLZ enhancers on a
    reservoir sample of tile batches (the bounded-memory counterpart of the
    eager training pass).  A relative ``eb`` takes a min/max prepass over
    the source, so one-shot iterator sources need ``abs_eb``.

    Returns a :class:`repro.exec.StreamReport` (peak tracked bytes, batch
    geometry, container size).  Open the result with :func:`open` — reads
    are lane-lazy, so region decodes of a huge streamed artifact stay
    bounded too.

    Fault tolerance (docs/ROBUSTNESS.md): transient encode/append failures
    retry under ``retry`` (a :class:`repro.runtime.fault.RetryPolicy`;
    default 3 attempts with backoff), each batch is journaled as it lands,
    and ``resume=True`` re-opens an interrupted path destination at its
    last committed batch — for Lorenzo the resumed container is
    byte-identical to an uninterrupted run."""
    from repro.exec import stream_compress

    return stream_compress(
        source, out, tile=tile, rel_eb=eb, abs_eb=abs_eb, backend=backend,
        predictor=predictor, order=order, max_levels=max_levels,
        mem_budget=mem_budget,
        enhance=(enhance if enhance else None),
        shape=shape, resume=resume, retry=retry)


# ---------------------------------------------------------------------------
# multi-field dataset (GWDS)
# ---------------------------------------------------------------------------


class Dataset(Mapping):
    """Lazy mapping of field name -> :class:`CompressedVolume` backed by one
    ``GWDS`` envelope (docs/DATASET_FORMAT.md).

    Field blobs parse on first access — opening a dataset reads the shared
    offset index only, so touching one field of a many-field snapshot never
    pays for the others.  When opened through ``api.open`` the backing is an
    mmap: field parse is lazy down to the lane level, and :meth:`close` (or
    the context manager) releases the mapping."""

    def __init__(self, blob, index: dict[str, tuple[int, int]],
                 *, pipeline: GWLZ | None = None, cache_bytes: int | None = None,
                 tile_cache: TileCache | None = None,
                 verify: str = "lazy", on_corrupt: str = "raise",
                 fill_value: float = 0.0):
        self._blob = blob
        self._index = index
        self._pipeline = pipeline
        self._cache_bytes = cache_bytes
        self._tile_cache = tile_cache
        self._verify = verify
        self._on_corrupt = on_corrupt
        self._fill_value = fill_value
        self._cache: dict[str, CompressedVolume] = {}
        self._resources: tuple = ()
        self._closed = False

    @staticmethod
    def from_bytes(blob, *, pipeline: GWLZ | None = None,
                   cache_bytes: int | None = None,
                   tile_cache: TileCache | None = None, verify: str = "lazy",
                   on_corrupt: str = "raise", fill_value: float = 0.0) -> "Dataset":
        try:
            magic, ver, n_fields = _GWDS_HDR.unpack_from(blob, 0)
            if magic != GWDS_MAGIC:
                raise CorruptContainerError(
                    "bad GWDS magic", offset=0, expected=GWDS_MAGIC,
                    actual=bytes(magic))
            if ver == 1:
                # v1: index-first layout, field count in the header
                off = _GWDS_HDR.size
                index: dict[str, tuple[int, int]] = {}
                for _ in range(n_fields):
                    (nlen,) = struct.unpack_from("<I", blob, off)
                    off += 4
                    name = bytes(blob[off : off + nlen]).decode()
                    off += nlen
                    fo, fl = _GWDS_ENTRY.unpack_from(blob, off)
                    off += _GWDS_ENTRY.size
                    if fo + fl > len(blob):
                        raise CorruptContainerError(
                            f"GWDS field {name!r} extends past the blob: "
                            "truncated file?", offset=off - _GWDS_ENTRY.size,
                            expected=f"<= {len(blob)}", actual=int(fo + fl))
                    index[name] = (int(fo), int(fl))
            elif ver == _GWDS_VERSION:
                # v2: append-only layout, index in the footer (streamable)
                from repro.exec.writer import parse_gwds_v2

                index = parse_gwds_v2(blob)
            else:
                raise CorruptContainerError(
                    "unsupported GWDS version", offset=4,
                    expected=(1, _GWDS_VERSION), actual=int(ver))
        except struct.error as e:
            raise CorruptContainerError(
                f"truncated or corrupt GWDS envelope: {e}", offset=0) from e
        return Dataset(blob, index, pipeline=pipeline, cache_bytes=cache_bytes,
                       tile_cache=tile_cache, verify=verify,
                       on_corrupt=on_corrupt, fill_value=fill_value)

    @staticmethod
    def build(fields: Mapping[str, "CompressedVolume | A.Artifact"]) -> bytes:
        """Serialize named artifacts into one GWDS (v2) envelope.

        Routed through the incremental :class:`repro.exec.writer.GWDSWriter`
        so an eagerly built envelope is byte-identical to a streamed one."""
        from repro.exec.writer import GWDSWriter

        if not fields:
            raise ValueError("a GWDS dataset needs at least one field")
        buf = io.BytesIO()
        w = GWDSWriter(buf)
        for name, vol in fields.items():
            art = vol.artifact if isinstance(vol, CompressedVolume) else vol
            if not isinstance(art, A.Artifact):
                raise TypeError(
                    f"GWDS field {name!r} is a {type(vol).__name__}; expected "
                    "CompressedVolume or artifact (compress it first)")
            w.add_field(name, art.to_bytes())
        w.finalize()
        return buf.getvalue()

    def __getitem__(self, name: str) -> CompressedVolume:
        if self._closed:
            raise ValueError("operation on a closed Dataset")
        if name not in self._cache:
            fo, fl = self._index[name]  # raises KeyError for unknown fields
            art = A.from_bytes(self._blob[fo : fo + fl])
            _apply_verify(art, self._verify, self._on_corrupt, self._fill_value)
            self._cache[name] = CompressedVolume(
                art, pipeline=self._pipeline, cache_bytes=self._cache_bytes,
                tile_cache=self._tile_cache)
        return self._cache[name]

    def __iter__(self) -> Iterator[str]:
        return iter(self._index)

    def __len__(self) -> int:
        return len(self._index)

    # -- lifecycle ---------------------------------------------------------

    def _adopt_resources(self, resources: tuple) -> None:
        self._resources = tuple(resources)

    def close(self) -> None:
        """Close every opened field handle and release the backing mmap."""
        if self._closed:
            return
        self._closed = True
        for vol in self._cache.values():
            vol.close()
        self._cache = {}
        _release_resources(self._resources)
        self._resources = ()
        self._blob = b""

    def __enter__(self) -> "Dataset":
        if self._closed:
            raise ValueError("operation on a closed Dataset")
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    @property
    def fields(self) -> tuple[str, ...]:
        return tuple(self._index)

    @property
    def nbytes(self) -> int:
        return len(self._blob)

    def to_bytes(self) -> bytes:
        return self._blob if isinstance(self._blob, bytes) else bytes(self._blob)

    def size_report(self) -> dict:
        per_field = {n: fl for n, (_fo, fl) in self._index.items()}
        payload = sum(per_field.values())
        return {"fields": per_field, "index": self.nbytes - payload,
                "total": self.nbytes}

    def __repr__(self) -> str:
        return f"Dataset(GWDS, fields={list(self._index)}, nbytes={self.nbytes})"


# ---------------------------------------------------------------------------
# persistence: save / open (self-sniffing)
# ---------------------------------------------------------------------------


def from_bytes(blob, *, pipeline: GWLZ | None = None,
               cache_bytes: int | None = None,
               tile_cache: TileCache | None = None, cache_ns=None,
               verify: str = "lazy", on_corrupt: str = "raise",
               fill_value: float = 0.0, decode_batcher=None):
    """Sniff the envelope magic and reconstruct the right reader.

    ``SZJX``/``GWTC`` (any registered artifact container) ->
    :class:`CompressedVolume`; ``GWDS`` -> :class:`Dataset`.  ``blob`` may
    be bytes or any buffer (a memoryview over an mmap parses lazily: tiled
    lanes stay on disk until a decode touches them).  ``verify`` /
    ``on_corrupt`` / ``fill_value`` install the integrity policy described
    under :func:`open`; ``tile_cache`` / ``cache_ns`` inject a shared
    decoded-tile cache as described there too."""
    if A.sniff_magic(blob) == GWDS_MAGIC:
        return Dataset.from_bytes(blob, pipeline=pipeline,
                                  cache_bytes=cache_bytes,
                                  tile_cache=tile_cache, verify=verify,
                                  on_corrupt=on_corrupt, fill_value=fill_value)
    art = _apply_verify(A.from_bytes(blob), verify, on_corrupt, fill_value)
    return CompressedVolume(art, pipeline=pipeline, cache_bytes=cache_bytes,
                            tile_cache=tile_cache, cache_ns=cache_ns,
                            decode_batcher=decode_batcher)


def save(path: str | os.PathLike,
         obj: "CompressedVolume | A.Artifact | Mapping | Dataset") -> int:
    """Write ``obj`` to ``path``; returns the byte count on disk.

    A volume handle (or bare artifact) writes its self-describing container
    bytes verbatim, so bytes-on-disk == ``vol.nbytes``.  A mapping of
    ``{name: volume}`` (or a :class:`Dataset`) writes one multi-field
    ``GWDS`` envelope."""
    if isinstance(obj, Dataset):
        blob = obj.to_bytes()
    elif isinstance(obj, Mapping):
        blob = Dataset.build(obj)
    elif isinstance(obj, CompressedVolume):
        blob = obj.to_bytes()
    elif isinstance(obj, A.Artifact):
        blob = obj.to_bytes()
    else:
        raise TypeError(
            f"cannot save {type(obj).__name__}; expected CompressedVolume, "
            "artifact, Dataset, or a {name: volume} mapping")
    with _builtin_open(path, "wb") as f:
        f.write(blob)
    return len(blob)


def open(path: str | os.PathLike, *, pipeline: GWLZ | None = None,
         mmap: bool = True, cache_bytes: int | None = None,
         tile_cache: TileCache | None = None, cache_ns=None,
         verify: str = "lazy", on_corrupt: str = "raise",
         fill_value: float = 0.0, decode_batcher=None):
    """Open a compressed file, sniffing the envelope to pick the decoder.

    Returns a :class:`CompressedVolume` for single-artifact files (``SZJX``
    monolithic, ``GWTC`` tiled — attached GWLZ enhancer models ride along in
    the container extras and are applied on decode) or a :class:`Dataset`
    for multi-field ``GWDS`` files.

    By default the file is memory-mapped and parsed lazily: only the
    header/index pages are touched at open, and a region read pages in just
    the intersecting entropy lanes.  The returned handle owns the mapping —
    use it as a context manager (or call ``close()``) to release it;
    ``mmap=False`` forces an eager full read (no handle-held resources).
    ``cache_bytes`` caps the handle's decoded-tile LRU cache
    (default ``REPRO_TILE_CACHE_BYTES`` or 256 MiB; 0 disables it).
    Alternatively ``tile_cache`` injects an existing (shared)
    :class:`~repro.exec.cache.TileCache` — many handles then compete for
    ONE byte budget, each keyed under its own ``cache_ns`` namespace (the
    ``repro.serve`` daemon's pooling mode, docs/SERVING.md); closing such a
    handle evicts only its namespace, never its neighbors' tiles.

    Integrity (docs/ROBUSTNESS.md): structural damage (truncation, garbage,
    bad offsets, metadata checksum failure) raises
    :class:`~repro.errors.CorruptContainerError` here.  ``verify`` sets the
    per-lane CRC policy for containers that carry checksums — ``"lazy"``
    (default) checks each lane on its first decode, ``"full"`` checks every
    lane at open, ``"none"`` skips checking.  A failed lane raises
    :class:`~repro.errors.CorruptLaneError`, or — with
    ``on_corrupt="quarantine"`` — decodes as ``fill_value`` while
    ``vol.stats.quarantined`` counts the damaged tiles."""
    f = _builtin_open(path, "rb")
    mm = None
    if mmap:
        try:
            mm = _mmap.mmap(f.fileno(), 0, access=_mmap.ACCESS_READ)
        except (ValueError, OSError):
            mm = None  # empty or unmappable file: fall back to a full read
    if mm is None:
        with f:
            blob = f.read()
        return from_bytes(blob, pipeline=pipeline, cache_bytes=cache_bytes,
                          tile_cache=tile_cache, cache_ns=cache_ns,
                          verify=verify, on_corrupt=on_corrupt,
                          fill_value=fill_value, decode_batcher=decode_batcher)
    mv = memoryview(mm)
    try:
        obj = from_bytes(mv, pipeline=pipeline, cache_bytes=cache_bytes,
                         tile_cache=tile_cache, cache_ns=cache_ns,
                         verify=verify, on_corrupt=on_corrupt,
                         fill_value=fill_value, decode_batcher=decode_batcher)
    except BaseException:
        mv.release()
        mm.close()
        f.close()
        raise
    obj._adopt_resources((mv, mm, f))
    return obj


def region_lane_count(vol: CompressedVolume, roi) -> tuple[int, int]:
    """(lanes a region decode of ``roi`` touches, total lanes) for a tiled
    volume — the observability hook behind ``python -m repro.cli region``
    (monolithic volumes report (1, 1): one decode covers everything).

    ``roi`` is anything ``vol[roi]`` accepts (ints, stepped slices,
    Ellipsis, partial rank); an empty ROI touches 0 lanes on either
    container (``vol[roi]`` short-circuits without decoding)."""
    specs = vol._normalize_key(roi)
    total = vol.artifact.n_tiles if vol.tiled else 1
    if any(hi <= lo for lo, hi, _step, _sq in specs):
        return (0, total)
    if not vol.tiled:
        return (1, 1)
    ids, _ = region_tiles(vol.artifact, tuple((lo, hi) for lo, hi, _s, _q in specs))
    return (int(ids.size), total)
