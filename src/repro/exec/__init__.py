"""Out-of-core streaming execution layer (docs/STREAMING.md).

Turns compression into a plan of per-tile-batch tasks run by a
bounded-memory executor:

* :mod:`repro.exec.sources` — uniform tile access over ndarray / memmap /
  ``.npy`` path / slab-iterator inputs,
* :mod:`repro.exec.plan` — batch sizing against a byte budget,
* :mod:`repro.exec.writer` — incremental append-only ``GWTC``/``GWDS``
  writers (index written as a footer on ``finalize()``),
* :mod:`repro.exec.executor` — the streaming loop (device predict for
  batch k+1 overlaps host entropy coding of batch k),
* :mod:`repro.exec.cache` — the size-capped, thread-safe LRU tile cache
  behind ``repro.api.CompressedVolume`` region reads.

The public entry point is :func:`repro.api.compress_stream`; everything
here is importable for tests and power users.
"""
from repro.exec.cache import TileCache
from repro.exec.executor import StreamReport, stream_compress
from repro.exec.plan import StreamPlan, max_inflight_tiles, plan_stream, tile_working_bytes
from repro.exec.sources import ArraySource, IterSource, NpyFileSource, TileSource, as_source
from repro.exec.writer import GWDSWriter, GWTCWriter, journal_path

__all__ = [
    "ArraySource",
    "GWDSWriter",
    "GWTCWriter",
    "IterSource",
    "NpyFileSource",
    "StreamPlan",
    "StreamReport",
    "TileCache",
    "TileSource",
    "as_source",
    "journal_path",
    "max_inflight_tiles",
    "plan_stream",
    "stream_compress",
    "tile_working_bytes",
]
