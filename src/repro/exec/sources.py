"""Tile sources: uniform, bounded-memory access to volume data.

The streaming executor never sees a whole volume — it asks a source for
one rectangular block at a time (a tile's extent, clamped to the volume).
Sources adapt the inputs :func:`repro.api.compress_stream` accepts:

* in-memory arrays and ``np.memmap`` views (:class:`ArraySource` — memmap
  block reads fault in only the touched pages),
* ``.npy`` paths (:class:`NpyFileSource` — opened with
  ``np.load(mmap_mode="r")``, so nothing is materialized),
* iterators of axis-0 slabs (:class:`IterSource` — a plane-window buffer
  holds only the slabs covering the current tile row).
"""
from __future__ import annotations

import os

import numpy as np


class TileSource:
    """Protocol: ``shape`` plus rectangular block reads.

    ``rescannable`` sources can be read more than once (needed to resolve a
    *relative* error bound, which takes a min/max prepass); one-shot
    iterator sources are not and require ``abs_eb``."""

    shape: tuple[int, ...]
    rescannable: bool = True

    def read_block(self, lo: tuple[int, ...], hi: tuple[int, ...]) -> np.ndarray:
        """float32 copy of ``x[lo:hi]`` (executor-owned; mutation is fine)."""
        raise NotImplementedError

    def read_tile(self, lo, hi, tile: tuple[int, ...]) -> np.ndarray:
        """One tile's block, edge-padded to the full tile shape — the same
        values ``tiled.pad_to_tiles`` + ``split_tiles`` would produce."""
        block = self.read_block(lo, hi)
        pads = [(0, t - (h - l)) for l, h, t in zip(lo, hi, tile)]
        if any(p for _z, p in pads):
            block = np.pad(block, pads, mode="edge")
        return block

    def close(self) -> None:  # pragma: no cover - trivial default
        pass


class ArraySource(TileSource):
    """Blocks out of an in-memory ndarray or an ``np.memmap`` view."""

    def __init__(self, a: np.ndarray):
        self._a = a
        self.shape = tuple(int(d) for d in a.shape)

    def read_block(self, lo, hi) -> np.ndarray:
        sl = tuple(slice(l, h) for l, h in zip(lo, hi))
        return np.asarray(self._a[sl], np.float32)


class NpyFileSource(ArraySource):
    """``.npy`` file opened as a read-only memmap: block reads touch only
    the pages under the requested extent."""

    def __init__(self, path):
        self.path = os.fspath(path)
        super().__init__(np.load(self.path, mmap_mode="r"))

    def close(self) -> None:
        mm = getattr(self._a, "_mmap", None)
        self._a = None
        if mm is not None:
            mm.close()


class IterSource(TileSource):
    """One-shot iterator of axis-0 slabs with a declared total ``shape``.

    Keeps a sliding window of planes: tile batches arrive in row-major grid
    order, so the first-axis extent of successive reads is nondecreasing —
    planes behind the window are dropped as soon as the next tile row
    starts.  Peak buffer: one tile row of planes plus the largest incoming
    slab."""

    rescannable = False

    def __init__(self, it, shape: tuple[int, ...]):
        self._it = iter(it)
        self.shape = tuple(int(d) for d in shape)
        self._win_start = 0  # first buffered plane
        self._buf = np.zeros((0,) + self.shape[1:], np.float32)

    def _advance(self, lo0: int, hi0: int) -> None:
        if lo0 < self._win_start:
            raise ValueError(
                f"iterator source cannot seek backwards (plane {lo0} < window "
                f"start {self._win_start}); tile reads must be row-major")

        def drop_front() -> None:
            # planes both buffered and behind the window start are consumed
            d = min(lo0 - self._win_start, self._buf.shape[0])
            if d:
                self._buf = self._buf[d:]
                self._win_start += d

        drop_front()
        while self._win_start + self._buf.shape[0] < hi0:
            try:
                slab = np.asarray(next(self._it), np.float32)
            except StopIteration:
                raise ValueError(
                    f"iterator source exhausted at plane "
                    f"{self._win_start + self._buf.shape[0]} of {self.shape[0]}"
                ) from None
            if slab.ndim == len(self.shape) - 1:
                slab = slab[None]
            if slab.shape[1:] != self.shape[1:]:
                raise ValueError(
                    f"slab shape {slab.shape} does not match volume planes "
                    f"{self.shape[1:]}")
            if not self._buf.shape[0] and self._win_start + slab.shape[0] <= lo0:
                self._win_start += slab.shape[0]  # skipped whole slab: no copy
            else:
                self._buf = slab if not self._buf.shape[0] else \
                    np.concatenate([self._buf, slab])
                drop_front()

    def read_block(self, lo, hi) -> np.ndarray:
        self._advance(lo[0], hi[0])
        a, b = lo[0] - self._win_start, hi[0] - self._win_start
        sl = (slice(a, b),) + tuple(slice(l, h) for l, h in zip(lo[1:], hi[1:]))
        return np.array(self._buf[sl], np.float32)


def as_source(src, *, shape=None) -> TileSource:
    """Adapt whatever the caller has into a :class:`TileSource`.

    Accepts a source instance, a ``.npy`` path, any array (ndarray, memmap,
    jax array), or an iterable of axis-0 slabs (``shape`` required)."""
    if isinstance(src, TileSource):
        return src
    if isinstance(src, (str, os.PathLike)):
        path = os.fspath(src)
        if not path.endswith(".npy"):
            raise ValueError(
                f"streaming sources read .npy volumes, got {path!r} "
                "(decode other containers through api.open)")
        return NpyFileSource(path)
    if hasattr(src, "__array__") or isinstance(src, np.ndarray):
        a = src if isinstance(src, (np.ndarray, np.memmap)) else np.asarray(src)
        return ArraySource(a)
    if hasattr(src, "__iter__") or hasattr(src, "__next__"):
        if shape is None:
            raise ValueError("iterator sources need an explicit shape=")
        return IterSource(src, shape)
    raise TypeError(f"cannot stream from a {type(src).__name__}")


def value_range(source: TileSource, slab_planes: int = 8) -> tuple[float, float]:
    """Streaming (min, max) prepass over a rescannable source — what a
    *relative* error bound needs before any tile is encoded."""
    if not source.rescannable:
        raise ValueError(
            "relative error bounds need a min/max prepass, which a one-shot "
            "iterator source cannot replay; pass abs_eb instead")
    shape = source.shape
    lo_v, hi_v = np.inf, -np.inf
    for p in range(0, shape[0], slab_planes):
        block = source.read_block(
            (p,) + (0,) * (len(shape) - 1),
            (min(p + slab_planes, shape[0]),) + shape[1:])
        lo_v = min(lo_v, float(block.min()))
        hi_v = max(hi_v, float(block.max()))
    return lo_v, hi_v
