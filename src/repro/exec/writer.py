"""Incremental, append-only container writers.

Both writers emit *footer-indexed* layouts: lanes / field blobs are
appended as they become available, and the offset index lands at the END of
the container when :meth:`finalize` runs — nothing is buffered and nothing
is seeked backwards, so a stream can be written through a pipe as well as a
file.  ``GWTC`` v3 and ``GWDS`` v2 are exactly these layouts
(docs/TILED_FORMAT.md, docs/DATASET_FORMAT.md); the eager
``TiledCompressed._serialize`` / ``Dataset.build`` paths route through the
same writers so eager and streamed bytes are identical for identical
content.
"""
from __future__ import annotations

import os
import struct

import numpy as np

from repro.sz import tiled as T

_GWDS_MAGIC = b"GWDS"
_GWDS_VERSION = 2
# v2 header: magic, version, pad x3, reserved u32 (field count lives in the
# footer — it is not known when a streaming writer starts)
_GWDS_HDR = struct.Struct("<4sB3xI")
# v2 footer: index offset, field count, sentinel
_GWDS_FOOTER = struct.Struct("<QI4s")
_GWDS_SENTINEL = b"GWDX"


class _Dest:
    """Append-only byte sink over a path or file-like; tracks bytes written
    relative to the container start (NOT the file start — a GWTC container
    embedded as a GWDS field needs container-relative footer offsets)."""

    def __init__(self, dest):
        if hasattr(dest, "write"):
            self._f = dest
            self._own = False
        else:
            self._f = open(os.fspath(dest), "wb")
            self._own = True
        self.written = 0

    def write(self, b) -> None:
        self._f.write(b)
        self.written += len(b)

    def close(self) -> None:
        if self._own:
            self._f.close()


class GWTCWriter:
    """Streaming ``GWTC`` v3 writer: header up front, lanes appended in
    row-major tile order, extras + index + footer on :meth:`finalize`.

    The tile geometry (and therefore the lane count) is fixed at
    construction; :meth:`finalize` refuses a partial container.  ``extras``
    is a plain dict — attach entries (e.g. a trained GWLZ model under
    ``"gwlz"``) any time before finalize."""

    def __init__(self, dest, *, shape, tile, eb_abs: float,
                 backend: str = "huffman+zlib", predictor: str = "lorenzo",
                 order: str = "cubic", levels: int = 0, on_finalize=None):
        from repro.sz.predictor import ORDER_IDS, PRED_IDS

        shape = tuple(int(d) for d in shape)
        tile = T.normalize_tile(tile, len(shape))
        self.shape, self.tile = shape, tile
        self.n_tiles = int(np.prod(T.tile_grid(shape, tile)))
        self.eb_abs = float(eb_abs)
        self.backend, self.predictor = backend, predictor
        self.order, self.levels = order, int(levels)
        self.extras: dict = {}
        self._lens: list[int] = []
        self._on_finalize = on_finalize
        # sharing an existing sink (a GWDS envelope streaming this container
        # as a field) keeps ITS byte counter advancing; footer offsets are
        # container-relative either way, via the base mark
        self._shared = isinstance(dest, _Dest)
        self._dest = dest if self._shared else _Dest(dest)
        self._base = self._dest.written
        self._finalized = False
        nd = len(shape)
        hdr = T._HDR_V3.pack(T._MAGIC, T._VERSION, nd, T._BACKENDS[backend],
                             PRED_IDS[predictor], ORDER_IDS[order], int(levels),
                             0, np.float64(self.eb_abs).view(np.uint64),
                             self.n_tiles)
        self._dest.write(hdr)
        self._dest.write(struct.pack(f"<{nd}q", *shape))
        self._dest.write(struct.pack(f"<{nd}q", *tile))

    @property
    def lanes_written(self) -> int:
        return len(self._lens)

    def append_lane(self, lane) -> None:
        if self._finalized:
            raise ValueError("writer already finalized")
        if len(self._lens) >= self.n_tiles:
            raise ValueError(
                f"container holds {self.n_tiles} lanes; lane {len(self._lens)} "
                "does not fit")
        lane = bytes(lane)
        self._lens.append(len(lane))
        self._dest.write(lane)

    def finalize(self) -> int:
        """Write extras + index + footer; returns total container bytes."""
        if self._finalized:
            raise ValueError("writer already finalized")
        if len(self._lens) != self.n_tiles:
            raise ValueError(
                f"container needs {self.n_tiles} lanes, got {len(self._lens)}")
        extras_off = self._dest.written - self._base
        self._dest.write(T._pack_extras(self.extras))
        index_off = self._dest.written - self._base
        self._dest.write(np.asarray(self._lens, np.uint64).tobytes())
        self._dest.write(T._FOOTER_V3.pack(extras_off, index_off))
        self._finalized = True
        total = self._dest.written - self._base
        if not self._shared:
            self._dest.close()
        if self._on_finalize is not None:
            self._on_finalize(total)
        return total

    def abort(self) -> None:
        """Give up on a partial container: close the sink (when owned)
        without writing a footer.  The bytes on disk are unreadable by
        design — a missing footer is how a truncated stream is detected."""
        if not self._finalized and not self._shared:
            self._dest.close()

    def __enter__(self) -> "GWTCWriter":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is None and not self._finalized:
            self.finalize()
        elif exc_type is not None:
            self.abort()


class GWDSWriter:
    """Streaming multi-field ``GWDS`` v2 writer.

    Fields are appended one at a time — either whole
    (:meth:`add_field` with a volume/artifact/bytes) or streamed in place
    (:meth:`stream_field` returns a :class:`GWTCWriter` that writes the
    field's lanes directly into the envelope) — so a many-field snapshot
    never needs two fields in memory at once.  The name index is written as
    a footer on :meth:`finalize`."""

    def __init__(self, dest):
        self._dest = _Dest(dest)
        self._index: list[tuple[str, int, int]] = []  # (name, off, len)
        self._names: set[str] = set()
        self._streaming: str | None = None
        self._finalized = False
        self._dest.write(_GWDS_HDR.pack(_GWDS_MAGIC, _GWDS_VERSION, 0))

    def _begin(self, name: str) -> int:
        if self._finalized:
            raise ValueError("writer already finalized")
        if self._streaming is not None:
            raise ValueError(
                f"field {self._streaming!r} is still streaming; finalize it first")
        if name in self._names:
            raise ValueError(f"duplicate GWDS field {name!r}")
        return self._dest.written

    def _end(self, name: str, off: int, length: int) -> None:
        self._index.append((name, off, length))
        self._names.add(name)

    def add_field(self, name: str, obj) -> None:
        """Append one complete field (CompressedVolume, artifact, or bytes)."""
        off = self._begin(name)
        blob = obj if isinstance(obj, (bytes, bytearray, memoryview)) \
            else obj.to_bytes()
        self._dest.write(bytes(blob))
        self._end(name, off, self._dest.written - off)

    def stream_field(self, name: str, **gwtc_kwargs) -> GWTCWriter:
        """Open a :class:`GWTCWriter` that streams one tiled field straight
        into the envelope; the field is recorded when that writer finalizes."""
        off = self._begin(name)
        self._streaming = name

        def done(total: int) -> None:
            self._streaming = None
            self._end(name, off, total)

        return GWTCWriter(self._dest, on_finalize=done, **gwtc_kwargs)

    def finalize(self) -> int:
        if self._finalized:
            raise ValueError("writer already finalized")
        if self._streaming is not None:
            raise ValueError(f"field {self._streaming!r} is still streaming")
        if not self._index:
            raise ValueError("a GWDS dataset needs at least one field")
        index_off = self._dest.written
        for name, off, length in self._index:
            nb = name.encode()
            self._dest.write(struct.pack("<I", len(nb)) + nb
                             + struct.pack("<QQ", off, length))
        self._dest.write(_GWDS_FOOTER.pack(index_off, len(self._index),
                                           _GWDS_SENTINEL))
        self._finalized = True
        total = self._dest.written
        self._dest.close()
        return total

    def __enter__(self) -> "GWDSWriter":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is None and not self._finalized:
            self.finalize()
        elif exc_type is not None:
            self._dest.close()


def parse_gwds_v2(blob) -> dict[str, tuple[int, int]]:
    """Footer-indexed ``GWDS`` v2 parse: name -> (offset, length).

    Accepts any buffer (bytes or a memoryview over an mmap); only the
    header, footer, and index bytes are touched."""
    if len(blob) < _GWDS_HDR.size + _GWDS_FOOTER.size:
        raise ValueError("truncated GWDS v2 envelope")
    index_off, n_fields, sentinel = _GWDS_FOOTER.unpack_from(
        blob, len(blob) - _GWDS_FOOTER.size)
    if sentinel != _GWDS_SENTINEL:
        raise ValueError("truncated or corrupt GWDS v2 envelope (bad footer)")
    if index_off > len(blob) - _GWDS_FOOTER.size:
        raise ValueError("corrupt GWDS v2 envelope (index offset out of range)")
    index: dict[str, tuple[int, int]] = {}
    off = index_off
    for _ in range(n_fields):
        (nlen,) = struct.unpack_from("<I", blob, off)
        off += 4
        name = bytes(blob[off : off + nlen]).decode()
        off += nlen
        fo, fl = struct.unpack_from("<QQ", blob, off)
        off += 16
        if fo + fl > index_off:
            raise ValueError(
                f"GWDS field {name!r} extends past the payload "
                f"({fo}+{fl} > {index_off}): truncated file?")
        index[name] = (int(fo), int(fl))
    return index
