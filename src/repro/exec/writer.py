"""Incremental, append-only container writers.

Both writers emit *footer-indexed* layouts: lanes / field blobs are
appended as they become available, and the offset index lands at the END of
the container when :meth:`finalize` runs — nothing is buffered and nothing
is seeked backwards, so a stream can be written through a pipe as well as a
file.  ``GWTC`` v3 and ``GWDS`` v2 are exactly these layouts
(docs/TILED_FORMAT.md, docs/DATASET_FORMAT.md); the eager
``TiledCompressed._serialize`` / ``Dataset.build`` paths route through the
same writers so eager and streamed bytes are identical for identical
content.

Fault tolerance (docs/ROBUSTNESS.md): when the destination is a *path*, the
``GWTC`` writer keeps a sidecar commit journal (``<path>.journal``) —
:meth:`GWTCWriter.commit` durably records the lanes appended so far (data
file is fsync'd *before* the journal entry lands, so a journaled lane is
always really on disk), :meth:`GWTCWriter.rollback_uncommitted` truncates a
half-appended batch away so it can be retried, and
:meth:`GWTCWriter.resume` re-opens an interrupted container at its last
committed byte.  :meth:`finalize` removes the journal — a surviving journal
file is exactly the marker of an interrupted stream.  Every lane's CRC32
is tracked as it is appended and lands in the v3 footer index
(``sz/tiled.py``) for end-to-end integrity checking on decode.
"""
from __future__ import annotations

import os
import struct
import zlib

import numpy as np

from repro.errors import CorruptContainerError
from repro.sz import artifact as A
from repro.sz import tiled as T

_GWDS_MAGIC = A.GWDS_MAGIC
_GWDS_VERSION = A.GWDS_VERSION
# v2 header: magic, version, pad x3, reserved u32 (field count lives in the
# footer — it is not known when a streaming writer starts)
_GWDS_HDR = struct.Struct("<4sB3xI")
# v2 footer: index offset, field count, sentinel
_GWDS_FOOTER = struct.Struct("<QI4s")
_GWDS_SENTINEL = A.GWDS_SENTINEL

# --- commit journal (sidecar <path>.journal) --------------------------------
# header:  magic 'GWJL', version, pad, prefix_len u32, prefix bytes, crc u32
#          (prefix = the container's header|shape|tile bytes, so resume can
#          verify it is appending to the stream it thinks it is)
# blocks:  n_new u32 | n_new x (lane_len u64, lane_crc u32) | committed u64
#          | block crc u32 — one block per commit(); a torn tail block (crash
#          mid-append) fails its CRC and is ignored, the previous block wins.
_JOURNAL_MAGIC = A.JOURNAL_MAGIC
_JOURNAL_VERSION = A.JOURNAL_VERSION
_JOURNAL_HDR = struct.Struct("<4sB3xI")
_LANE_ENTRY = struct.Struct("<QI")


def journal_path(path) -> str:
    return os.fspath(path) + ".journal"


def _read_journal(jpath):
    """Parse a commit journal -> (prefix, lens, crcs, committed_bytes).

    Walks commit blocks until EOF or the first torn/corrupt block; the
    state as of the last intact block is returned.  Raises
    :class:`CorruptContainerError` when the journal itself is unusable."""
    with open(jpath, "rb") as f:
        blob = f.read()
    try:
        magic, ver, prefix_len = _JOURNAL_HDR.unpack_from(blob, 0)
    except struct.error as e:
        raise CorruptContainerError(
            f"truncated commit journal {jpath}: {e}", offset=0) from e
    if magic != _JOURNAL_MAGIC or ver != _JOURNAL_VERSION:
        raise CorruptContainerError(
            "bad commit journal header", offset=0,
            expected=(_JOURNAL_MAGIC, _JOURNAL_VERSION),
            actual=(bytes(magic), int(ver)))
    off = _JOURNAL_HDR.size
    prefix = blob[off : off + prefix_len]
    off += prefix_len
    try:
        (pcrc,) = struct.unpack_from("<I", blob, off)
    except struct.error as e:
        raise CorruptContainerError(
            f"truncated commit journal {jpath} (no prefix crc)",
            offset=off) from e
    off += 4
    if len(prefix) != prefix_len or zlib.crc32(prefix) & 0xFFFFFFFF != pcrc:
        raise CorruptContainerError(
            "commit journal prefix failed its checksum", offset=_JOURNAL_HDR.size)
    lens: list[int] = []
    crcs: list[int] = []
    committed = len(prefix)
    while off < len(blob):
        block_start = off
        try:
            (n_new,) = struct.unpack_from("<I", blob, off)
            off += 4
            entries = [_LANE_ENTRY.unpack_from(blob, off + i * _LANE_ENTRY.size)
                       for i in range(n_new)]
            off += n_new * _LANE_ENTRY.size
            (total,) = struct.unpack_from("<Q", blob, off)
            off += 8
            (bcrc,) = struct.unpack_from("<I", blob, off)
            off += 4
        except struct.error:
            break  # torn tail block from a crash mid-append: previous wins
        if zlib.crc32(blob[block_start : off - 4]) & 0xFFFFFFFF != bcrc:
            break
        lens.extend(int(ln) for ln, _c in entries)
        crcs.extend(int(c) for _ln, c in entries)
        committed = int(total)
    return bytes(prefix), lens, crcs, committed


class _Dest:
    """Append-only byte sink over a path or file-like; tracks bytes written
    relative to the container start (NOT the file start — a GWTC container
    embedded as a GWDS field needs container-relative footer offsets)."""

    def __init__(self, dest, *, own: bool | None = None):
        if hasattr(dest, "write"):
            self._f = dest
            self._own = bool(own)
        else:
            self._f = open(os.fspath(dest), "wb")
            self._own = True
        self.written = 0

    def write(self, b) -> None:
        self._f.write(b)
        self.written += len(b)

    def fsync(self) -> None:
        """Flush to the OS and (for real files) to the device — called
        before a journal commit so committed lanes are durably on disk."""
        self._f.flush()
        try:
            os.fsync(self._f.fileno())
        except (OSError, AttributeError):
            pass  # BytesIO / pipes: flush is all the durability there is

    def truncate(self, n: int) -> None:
        """Drop everything past byte ``n`` (file-absolute) and reposition —
        the rollback primitive for retrying a half-appended batch."""
        self._f.flush()
        self._f.truncate(n)
        self._f.seek(n)
        self.written = n

    def close(self) -> None:
        if self._own:
            self._f.close()


class GWTCWriter:
    """Streaming ``GWTC`` v3 writer: header up front, lanes appended in
    row-major tile order, extras + index + footer on :meth:`finalize`.

    The tile geometry (and therefore the lane count) is fixed at
    construction; :meth:`finalize` refuses a partial container.  ``extras``
    is a plain dict — attach entries (e.g. a trained GWLZ model under
    ``"gwlz"``) any time before finalize.

    Path destinations are *journaled*: each :meth:`commit` fsyncs the data
    file then appends a checksummed block to ``<path>.journal``, making the
    committed prefix durable and :meth:`resume`-able; :meth:`finalize`
    deletes the journal.  In-memory / shared sinks write no journal but
    still track the commit point so :meth:`rollback_uncommitted` works
    wherever the sink supports truncation."""

    def __init__(self, dest, *, shape, tile, eb_abs: float,
                 backend: str = "huffman+zlib", predictor: str = "lorenzo",
                 order: str = "cubic", levels: int = 0, on_finalize=None,
                 journal: bool | None = None):
        from repro.sz.predictor import ORDER_IDS, PRED_IDS

        shape = tuple(int(d) for d in shape)
        tile = T.normalize_tile(tile, len(shape))
        self.shape, self.tile = shape, tile
        self.n_tiles = int(np.prod(T.tile_grid(shape, tile)))
        self.eb_abs = float(eb_abs)
        self.backend, self.predictor = backend, predictor
        self.order, self.levels = order, int(levels)
        self.extras: dict = {}
        self._lens: list[int] = []
        self._crcs: list[int] = []
        self._on_finalize = on_finalize
        # sharing an existing sink (a GWDS envelope streaming this container
        # as a field) keeps ITS byte counter advancing; footer offsets are
        # container-relative either way, via the base mark
        self._shared = isinstance(dest, _Dest)
        is_path = not self._shared and not hasattr(dest, "write")
        self._journal_path = journal_path(dest) \
            if (journal if journal is not None else is_path) and is_path else None
        self._journal_f = None
        self._dest = dest if self._shared else _Dest(dest)
        self._base = self._dest.written
        self._finalized = False
        nd = len(shape)
        hdr = T._HDR_V3.pack(T._MAGIC, T._VERSION, nd, T._BACKENDS[backend],
                             PRED_IDS[predictor], ORDER_IDS[order], int(levels),
                             0, np.float64(self.eb_abs).view(np.uint64),
                             self.n_tiles)
        self._prefix = (hdr + struct.pack(f"<{nd}q", *shape)
                        + struct.pack(f"<{nd}q", *tile))
        self._dest.write(self._prefix)
        # everything up to and including the fixed prefix counts as committed
        self._committed_lanes = 0
        self._committed_bytes = len(self._prefix)
        if self._journal_path is not None:
            self._journal_f = open(self._journal_path, "wb")
            self._journal_f.write(
                _JOURNAL_HDR.pack(_JOURNAL_MAGIC, _JOURNAL_VERSION,
                                  len(self._prefix))
                + self._prefix
                + struct.pack("<I", zlib.crc32(self._prefix) & 0xFFFFFFFF))
            self._journal_f.flush()

    @property
    def lanes_written(self) -> int:
        return len(self._lens)

    @property
    def committed_lanes(self) -> int:
        """Lanes durably recorded by the last :meth:`commit` — a resumed
        stream restarts from exactly this point."""
        return self._committed_lanes

    @property
    def can_rollback(self) -> bool:
        """Whether :meth:`rollback_uncommitted` is available (owned sinks
        only — a shared GWDS envelope cannot be truncated mid-field)."""
        return not self._shared

    def append_lane(self, lane) -> None:
        if self._finalized:
            raise ValueError("writer already finalized")
        if len(self._lens) >= self.n_tiles:
            raise ValueError(
                f"container holds {self.n_tiles} lanes; lane {len(self._lens)} "
                "does not fit")
        lane = bytes(lane)
        self._lens.append(len(lane))
        self._crcs.append(zlib.crc32(lane) & 0xFFFFFFFF)
        self._dest.write(lane)

    def commit(self) -> None:
        """Durably record every lane appended so far.

        Ordering matters: the data file is fsync'd *first*, then the journal
        block is appended and flushed — a journal entry therefore never
        refers to bytes that might not have reached the disk."""
        if self._finalized:
            raise ValueError("writer already finalized")
        n_new = len(self._lens) - self._committed_lanes
        if n_new <= 0:
            return
        self._dest.fsync()
        self._committed_lanes = len(self._lens)
        self._committed_bytes = len(self._prefix) + sum(self._lens)
        if self._journal_f is not None:
            block = struct.pack("<I", n_new)
            for i in range(self._committed_lanes - n_new, self._committed_lanes):
                block += _LANE_ENTRY.pack(self._lens[i], self._crcs[i])
            block += struct.pack("<Q", self._committed_bytes)
            block += struct.pack("<I", zlib.crc32(block) & 0xFFFFFFFF)
            self._journal_f.write(block)
            self._journal_f.flush()
            os.fsync(self._journal_f.fileno())

    def rollback_uncommitted(self) -> int:
        """Truncate everything after the last commit point (a half-appended
        batch being retried); returns the number of lanes dropped."""
        if self._finalized:
            raise ValueError("writer already finalized")
        if self._shared:
            raise ValueError("cannot roll back a writer on a shared sink")
        dropped = len(self._lens) - self._committed_lanes
        if dropped:
            del self._lens[self._committed_lanes:]
            del self._crcs[self._committed_lanes:]
            self._dest.truncate(self._base + self._committed_bytes)
        return dropped

    def truncate_lanes(self, n: int) -> None:
        """Shrink the *committed* stream to its first ``n`` lanes (resume
        alignment: a commit point mid-batch is rounded down to a batch
        boundary so the re-streamed batches reproduce the original bytes)."""
        if self._shared:
            raise ValueError("cannot truncate a writer on a shared sink")
        if not 0 <= n <= self._committed_lanes:
            raise ValueError(
                f"cannot truncate to {n} lanes; {self._committed_lanes} committed")
        del self._lens[n:]
        del self._crcs[n:]
        self._committed_lanes = n
        self._committed_bytes = len(self._prefix) + sum(self._lens)
        self._dest.truncate(self._base + self._committed_bytes)
        if self._journal_f is not None:
            # rewrite the journal from scratch: header + one block
            self._journal_f.close()
            self._journal_f = open(self._journal_path, "wb")
            self._journal_f.write(
                _JOURNAL_HDR.pack(_JOURNAL_MAGIC, _JOURNAL_VERSION,
                                  len(self._prefix))
                + self._prefix
                + struct.pack("<I", zlib.crc32(self._prefix) & 0xFFFFFFFF))
            self._journal_f.flush()
            self._committed_lanes = 0  # re-journal the kept lanes as one block
            self.commit() if n else self._journal_f.flush()
            self._committed_lanes = n

    @classmethod
    def resume(cls, path) -> "GWTCWriter":
        """Re-open an interrupted journaled stream at its last commit point.

        Validates that the data file still begins with the journaled
        container prefix and holds at least the committed bytes, truncates
        any uncommitted tail, and returns a writer positioned to append
        lane ``committed_lanes`` next.  Raises
        :class:`CorruptContainerError` when the file and journal disagree."""
        from repro.sz.predictor import ORDER_NAMES, PRED_NAMES

        jpath = journal_path(path)
        if not os.path.exists(jpath):
            raise FileNotFoundError(
                f"no commit journal at {jpath}; nothing to resume")
        prefix, lens, crcs, committed = _read_journal(jpath)
        (_m, _v, nd, backend, pred, order, levels, _pad, ebbits,
         _n_tiles) = T._HDR_V3.unpack_from(prefix, 0)
        shape = struct.unpack_from(f"<{nd}q", prefix, T._HDR_V3.size)
        tile = struct.unpack_from(f"<{nd}q", prefix, T._HDR_V3.size + 8 * nd)
        f = open(os.fspath(path), "r+b")
        try:
            head = f.read(len(prefix))
            if head != prefix:
                raise CorruptContainerError(
                    "container prefix does not match its commit journal "
                    "(wrong file, or header bytes were damaged)", offset=0)
            f.seek(0, 2)
            size = f.tell()
            if size < committed:
                raise CorruptContainerError(
                    "container is shorter than its journaled commit point",
                    offset=size, expected=f">= {committed} bytes", actual=size)
        except BaseException:
            f.close()
            raise
        f.truncate(committed)
        f.seek(committed)
        self = cls.__new__(cls)
        self.shape, self.tile = tuple(map(int, shape)), tuple(map(int, tile))
        self.n_tiles = int(np.prod(T.tile_grid(self.shape, self.tile)))
        self.eb_abs = float(np.uint64(ebbits).view(np.float64))
        self.backend = T._BACKENDS_INV[backend]
        self.predictor, self.order = PRED_NAMES[pred], ORDER_NAMES[order]
        self.levels = int(levels)
        self.extras = {}
        self._lens, self._crcs = list(lens), list(crcs)
        self._on_finalize = None
        self._shared = False
        self._journal_path = jpath
        self._journal_f = open(jpath, "ab")
        self._dest = _Dest(f, own=True)
        self._dest.written = committed
        self._base = 0
        self._finalized = False
        self._prefix = prefix
        self._committed_lanes = len(lens)
        self._committed_bytes = committed
        return self

    def finalize(self) -> int:
        """Write extras + index (lens | lane CRCs | metadata CRC) + footer;
        removes the commit journal; returns total container bytes."""
        if self._finalized:
            raise ValueError("writer already finalized")
        if len(self._lens) != self.n_tiles:
            raise ValueError(
                f"container needs {self.n_tiles} lanes, got {len(self._lens)}")
        extras_off = self._dest.written - self._base
        extras_blob = T._pack_extras(self.extras)
        self._dest.write(extras_blob)
        index_off = self._dest.written - self._base
        self._dest.write(np.asarray(self._lens, np.uint64).tobytes())
        self._dest.write(np.asarray(self._crcs, np.uint32).tobytes())
        meta_crc = zlib.crc32(extras_blob, zlib.crc32(self._prefix)) & 0xFFFFFFFF
        self._dest.write(struct.pack("<I", meta_crc))
        self._dest.write(T._FOOTER_V3.pack(extras_off, index_off))
        self._finalized = True
        total = self._dest.written - self._base
        if not self._shared:
            self._dest.close()
        if self._journal_f is not None:
            self._journal_f.close()
            self._journal_f = None
            os.unlink(self._journal_path)
        if self._on_finalize is not None:
            self._on_finalize(total)
        return total

    def abort(self) -> None:
        """Give up on a partial container: close the sink (when owned)
        without writing a footer.  The bytes on disk are unreadable by
        design — a missing footer is how a truncated stream is detected.
        A journaled writer keeps its journal: the pair stays resumable."""
        if self._journal_f is not None:
            self._journal_f.close()
            self._journal_f = None
        if not self._finalized and not self._shared:
            self._dest.close()

    def __enter__(self) -> "GWTCWriter":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is None and not self._finalized:
            self.finalize()
        elif exc_type is not None:
            self.abort()


class GWDSWriter:
    """Streaming multi-field ``GWDS`` v2 writer.

    Fields are appended one at a time — either whole
    (:meth:`add_field` with a volume/artifact/bytes) or streamed in place
    (:meth:`stream_field` returns a :class:`GWTCWriter` that writes the
    field's lanes directly into the envelope) — so a many-field snapshot
    never needs two fields in memory at once.  The name index is written as
    a footer on :meth:`finalize`."""

    def __init__(self, dest):
        self._dest = _Dest(dest)
        self._index: list[tuple[str, int, int]] = []  # (name, off, len)
        self._names: set[str] = set()
        self._streaming: str | None = None
        self._finalized = False
        self._dest.write(_GWDS_HDR.pack(_GWDS_MAGIC, _GWDS_VERSION, 0))

    def _begin(self, name: str) -> int:
        if self._finalized:
            raise ValueError("writer already finalized")
        if self._streaming is not None:
            raise ValueError(
                f"field {self._streaming!r} is still streaming; finalize it first")
        if name in self._names:
            raise ValueError(f"duplicate GWDS field {name!r}")
        return self._dest.written

    def _end(self, name: str, off: int, length: int) -> None:
        self._index.append((name, off, length))
        self._names.add(name)

    def add_field(self, name: str, obj) -> None:
        """Append one complete field (CompressedVolume, artifact, or bytes)."""
        off = self._begin(name)
        blob = obj if isinstance(obj, (bytes, bytearray, memoryview)) \
            else obj.to_bytes()
        self._dest.write(bytes(blob))
        self._end(name, off, self._dest.written - off)

    def stream_field(self, name: str, **gwtc_kwargs) -> GWTCWriter:
        """Open a :class:`GWTCWriter` that streams one tiled field straight
        into the envelope; the field is recorded when that writer finalizes."""
        off = self._begin(name)
        self._streaming = name

        def done(total: int) -> None:
            self._streaming = None
            self._end(name, off, total)

        return GWTCWriter(self._dest, on_finalize=done, **gwtc_kwargs)

    def finalize(self) -> int:
        if self._finalized:
            raise ValueError("writer already finalized")
        if self._streaming is not None:
            raise ValueError(f"field {self._streaming!r} is still streaming")
        if not self._index:
            raise ValueError("a GWDS dataset needs at least one field")
        index_off = self._dest.written
        for name, off, length in self._index:
            nb = name.encode()
            self._dest.write(struct.pack("<I", len(nb)) + nb
                             + struct.pack("<QQ", off, length))
        self._dest.write(_GWDS_FOOTER.pack(index_off, len(self._index),
                                           _GWDS_SENTINEL))
        self._finalized = True
        total = self._dest.written
        self._dest.close()
        return total

    def __enter__(self) -> "GWDSWriter":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is None and not self._finalized:
            self.finalize()
        elif exc_type is not None:
            self._dest.close()


def parse_gwds_v2(blob) -> dict[str, tuple[int, int]]:
    """Footer-indexed ``GWDS`` v2 parse: name -> (offset, length).

    Accepts any buffer (bytes or a memoryview over an mmap); only the
    header, footer, and index bytes are touched."""
    if len(blob) < _GWDS_HDR.size + _GWDS_FOOTER.size:
        raise CorruptContainerError(
            "truncated GWDS v2 envelope", offset=0,
            expected=f">= {_GWDS_HDR.size + _GWDS_FOOTER.size} bytes",
            actual=len(blob))
    index_off, n_fields, sentinel = _GWDS_FOOTER.unpack_from(
        blob, len(blob) - _GWDS_FOOTER.size)
    if sentinel != _GWDS_SENTINEL:
        raise CorruptContainerError(
            "truncated or corrupt GWDS v2 envelope (bad footer)",
            offset=len(blob) - 4, expected=_GWDS_SENTINEL,
            actual=bytes(sentinel))
    if index_off > len(blob) - _GWDS_FOOTER.size:
        raise CorruptContainerError(
            "corrupt GWDS v2 envelope (index offset out of range)",
            offset=len(blob) - _GWDS_FOOTER.size,
            expected=f"<= {len(blob) - _GWDS_FOOTER.size}",
            actual=int(index_off))
    index: dict[str, tuple[int, int]] = {}
    off = index_off
    try:
        for _ in range(n_fields):
            (nlen,) = struct.unpack_from("<I", blob, off)
            off += 4
            name = bytes(blob[off : off + nlen]).decode()
            off += nlen
            fo, fl = struct.unpack_from("<QQ", blob, off)
            off += 16
            if fo + fl > index_off:
                raise CorruptContainerError(
                    f"GWDS field {name!r} extends past the payload: "
                    "truncated file?", offset=off - 16,
                    expected=f"<= {int(index_off)}", actual=int(fo + fl))
            index[name] = (int(fo), int(fl))
    except struct.error as e:
        raise CorruptContainerError(
            f"truncated GWDS v2 index: {e}", offset=int(index_off)) from e
    return index
