"""Bounded-memory streaming compression executor.

The volume never materializes: the plan's contiguous tile-id runs are
pulled from a :class:`~repro.exec.sources.TileSource` one batch at a time,
each batch runs the device transform (prequant + predict, fanned across
the mesh by the predictor's ``encode_tiles``), and the host entropy stage
(lane serialization + container append) runs on a single background worker
so host coding of batch *k* overlaps device work on batch *k+1*.  In-flight
work is capped at one encoded batch, so at most two batches of working set
are alive — the plan sizes batches at half the byte budget, keeping the
tracked peak within it.

``MemTracker`` is the RSS hook the acceptance test asserts against: it
accounts the executor-owned buffers exactly (batch input, payload leaves,
reservoir), where process-level ``ru_maxrss`` is polluted by allocator and
JIT baselines.  Both land in the :class:`StreamReport`.

Fault tolerance (docs/ROBUSTNESS.md): the device encode and the host
append both run under a :class:`~repro.runtime.fault.RetryPolicy` — a
transient ``RuntimeError``/``OSError`` is retried with backoff instead of
killing the stream (``injector``/``write_injector`` hooks let tests drive
deterministic fault schedules through the real code paths).  Each batch's
lanes are journaled by :meth:`GWTCWriter.commit` once appended, so an
exhausted retry leaves a *resumable* partial container behind
(``resume=True`` picks up from the first uncommitted batch) rather than
unlinking the work done so far.
"""
from __future__ import annotations

import os
import resource
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field

import numpy as np

from repro.exec.plan import StreamPlan, plan_stream
from repro.exec.sources import TileSource, as_source, value_range
from repro.exec.writer import GWTCWriter
from repro.runtime.fault import RetryPolicy


class MemTracker:
    """Byte accounting for executor-owned buffers (current + high-water)."""

    def __init__(self):
        self._lock = threading.Lock()
        self.current = 0
        self.peak = 0

    def add(self, n: int) -> None:
        with self._lock:
            self.current += int(n)
            self.peak = max(self.peak, self.current)

    def sub(self, n: int) -> None:
        with self._lock:
            self.current -= int(n)


@dataclass
class StreamReport:
    """What a finished streaming compression did and what it cost."""

    path: str | None
    shape: tuple[int, ...]
    tile: tuple[int, ...]
    n_tiles: int
    n_batches: int
    batch_tiles: int
    nbytes: int
    eb_abs: float
    predictor: str
    backend: str
    mem_budget: int
    peak_tracked_bytes: int
    ru_maxrss_kb: int
    enhanced: bool = False
    reservoir_tiles: int = 0
    # fault-tolerance accounting: total retried attempts, the batch indices
    # that needed at least one retry, and how many batches a resume skipped
    retries: int = 0
    failed_batches: tuple[int, ...] = field(default_factory=tuple)
    resumed_batches: int = 0
    # entropy-stage accounting: whether lane packing ran in the device stage
    # (Pallas Huffman kernels) and total wall time the host stage spent —
    # with device entropy the host stage shrinks to container append+commit
    host_stage_s: float = 0.0
    entropy_device: bool = False
    # compile accounting: how many FRESH device programs this stream forced
    # (the plan's uniform batch width means at most one encode program per
    # stream geometry; 0 = fully warm, via tiled.register_program_key)
    programs_compiled: int = 0

    @property
    def peak_over_budget(self) -> float:
        return self.peak_tracked_bytes / max(self.mem_budget, 1)


def _resolve_eb_streaming(source: TileSource, rel_eb, abs_eb) -> float:
    """Streaming mirror of ``repro.sz.quantizer.resolve_eb``: same f32
    range arithmetic (so streamed and eager artifacts agree on eb bit-for-
    bit), fed by a block prepass instead of a whole-volume reduction."""
    if (rel_eb is None) == (abs_eb is None):
        raise ValueError("pass exactly one of rel_eb / abs_eb")
    if rel_eb is not None:
        lo, hi = value_range(source)
        vrange = float(np.float32(hi) - np.float32(lo))
        abs_eb = rel_eb * max(vrange, float(np.finfo(np.float32).tiny))
        absmax = max(abs(lo), abs(hi))
        max_q = absmax / (2.0 * float(abs_eb))
        if max_q >= 2**30:
            raise ValueError(
                f"eb={abs_eb:g} too small for data magnitude "
                f"(q={max_q:.3g} >= 2^30)")
    return float(abs_eb)


def _tile_bounds(i: int, grid, tile, shape):
    coord = np.unravel_index(i, grid)
    lo = tuple(int(c) * t for c, t in zip(coord, tile))
    hi = tuple(min(l + t, d) for l, t, d in zip(lo, tile, shape))
    return lo, hi


def _read_batch(source: TileSource, ids, plan: StreamPlan) -> np.ndarray:
    """[B, *tile] float32 batch, padded to the plan's uniform width by
    repeating the final tile (so the device program compiles once)."""
    B = plan.batch_tiles
    out = np.empty((B,) + plan.tile, np.float32)
    for j, i in enumerate(ids):
        lo, hi = _tile_bounds(i, plan.grid, plan.tile, plan.shape)
        out[j] = source.read_tile(lo, hi, plan.tile)
    for j in range(len(ids), B):
        out[j] = out[len(ids) - 1]
    return out


def stream_compress(
    source,
    dest,
    *,
    tile=(64, 64, 64),
    rel_eb: float | None = None,
    abs_eb: float | None = None,
    backend: str = "huffman+zlib",
    predictor: str = "lorenzo",
    order: str = "cubic",
    max_levels: int = 5,
    mem_budget: int = 256 << 20,
    enhance=None,
    reservoir_tiles: int | None = None,
    shape=None,
    use_pallas: bool | None = None,
    retry: RetryPolicy | None = None,
    resume: bool = False,
    injector=None,
    write_injector=None,
) -> StreamReport:
    """Compress a streamed volume into a ``GWTC`` v3 container.

    ``source`` is anything :func:`repro.exec.sources.as_source` accepts;
    ``dest`` a path, writable file object, or an already-open
    :class:`GWTCWriter` (e.g. from ``GWDSWriter.stream_field``).  ``enhance``
    optionally trains group-wise GWLZ enhancers on a reservoir sample of
    (recon, residual) tile pairs — the bounded-memory stand-in for the
    eager path's whole-volume training set — and attaches the model before
    the footer is written.  Returns a :class:`StreamReport`; open the
    artifact with ``api.open`` (lazily — only decoded lanes are read).

    ``retry`` (default :class:`RetryPolicy()`) governs both the device
    encode and the host append; ``resume=True`` re-opens an interrupted
    path destination at its journaled commit point and streams only the
    uncommitted batches (Lorenzo resume is byte-identical to an
    uninterrupted run).  ``injector`` / ``write_injector`` are
    :class:`~repro.runtime.fault.FailureInjector` hooks for tests: the
    first fires per batch index inside the device encode, the second per
    global lane id inside the host append."""
    import jax

    from repro.sz.predictor import get_predictor
    from repro.sz.tiled import normalize_tile

    from repro.sz.entropy import _accel_default

    retry = retry if retry is not None else RetryPolicy()
    src = as_source(source, shape=shape)
    tile = normalize_tile(tile, len(src.shape))
    eb = _resolve_eb_streaming(src, rel_eb, abs_eb)
    pred = get_predictor(predictor)
    levels = pred.plan(tile, max_levels)
    # device entropy moves lane packing into the device stage, so the host
    # stage shrinks to container append + commit (same auto-detect rule as
    # the entropy layer; bytes are bit-identical either way)
    device_entropy = _accel_default() if use_pallas is None else bool(use_pallas)
    plan = plan_stream(src.shape, tile, mem_budget, predictor=predictor,
                       levels=levels, device_entropy=device_entropy)
    # the plan guarantees one uniform device-batch width (the short final run
    # is padded), so this stream's encode is exactly one compiled program —
    # register its identity so StreamReport can say whether it was fresh
    from repro.sz.tiled import register_program_key

    programs_compiled = int(register_program_key(
        ("stream-encode", predictor, tuple(plan.tile), int(plan.batch_tiles),
         order, int(levels), bool(device_entropy))))
    want = (plan.shape, plan.tile, eb, backend, predictor, order, levels)

    start_tile, resumed_batches = 0, 0
    if resume:
        if enhance:
            raise ValueError(
                "resume=True cannot train enhancers: the reservoir would "
                "sample only the re-streamed batches, so the attached model "
                "(and the container bytes) would depend on where the "
                "interruption fell — re-run without resume to enhance")
        if isinstance(dest, GWTCWriter) or hasattr(dest, "write"):
            raise ValueError("resume=True needs a path destination "
                             "(the commit journal lives next to the file)")
        writer, path = GWTCWriter.resume(dest), str(dest)
        aligned = plan.resume_point(writer.committed_lanes)
        if aligned != writer.committed_lanes:
            writer.truncate_lanes(aligned)  # mid-batch commit: redo the batch
        start_tile = aligned
        resumed_batches = start_tile // plan.batch_tiles
    elif isinstance(dest, GWTCWriter):
        # a pre-made writer already wrote its header; every header field must
        # agree with how the lanes will actually be encoded, or the container
        # would self-describe a decode that does not match its bytes
        writer, path = dest, None
    else:
        path = None if hasattr(dest, "write") else str(dest)
        writer = GWTCWriter(dest, shape=plan.shape, tile=plan.tile, eb_abs=eb,
                            backend=backend, predictor=predictor, order=order,
                            levels=levels)
    if resume or isinstance(dest, GWTCWriter):
        wrote = (writer.shape, writer.tile, writer.eb_abs, writer.backend,
                 writer.predictor, writer.order, writer.levels)
        if wrote != want:
            if resume:
                writer.abort()
            raise ValueError(
                f"writer header {wrote} does not match the encode settings "
                f"{want} (shape, tile, eb_abs, backend, predictor, order, "
                "levels must agree)")

    reservoir = None
    if enhance:
        from repro.core.trainer import GWLZTrainConfig, TileReservoir

        cfg = enhance if isinstance(enhance, GWLZTrainConfig) else GWLZTrainConfig()
        if reservoir_tiles is None:
            pair_bytes = 8 * int(np.prod(tile))  # f32 recon + f32 residual
            reservoir_tiles = max(4, (mem_budget // 4) // pair_bytes)
        reservoir = TileReservoir(int(reservoir_tiles), seed=cfg.seed)

    mem = MemTracker()
    pool = ThreadPoolExecutor(1, thread_name_prefix="gwtc-host")
    pending = None
    # retry accounting, shared between the main thread (device stage) and
    # the host worker — on_retry callbacks from both land here
    fault_lock = threading.Lock()
    retries = 0
    failed_batches: set[int] = set()

    def note_retry(bidx: int):
        def cb(_exc, _attempt):
            nonlocal retries
            with fault_lock:
                retries += 1
                failed_batches.add(bidx)
        return cb

    host_time_lock = threading.Lock()
    host_stage_s = 0.0

    def host_stage(payload_np, ids, bidx: int, nbytes_held: int,
                   blobs=None) -> None:
        """``blobs`` set means the device stage already packed the lanes —
        the host stage is pure container append + commit."""
        nonlocal host_stage_s
        t0 = time.perf_counter()
        try:
            def append_batch():
                if writer.can_rollback:
                    # drop any half-appended lanes from a previous attempt so
                    # the retry replays the whole batch from the commit point
                    writer.rollback_uncommitted()
                for j in range(len(ids)):
                    if write_injector is not None:
                        write_injector.maybe_fail(ids[j])
                    writer.append_lane(
                        blobs[j] if blobs is not None
                        else pred.lane_bytes(payload_np, j, backend))
                writer.commit()

            if writer.can_rollback:
                retry.run(append_batch, on_retry=note_retry(bidx))
            else:
                append_batch()  # shared sink: no safe replay, fail fast
        finally:
            mem.sub(nbytes_held)
            with host_time_lock:
                host_stage_s += time.perf_counter() - t0

    try:
        for bidx, run in enumerate(plan.batches(start_tile),
                                   start=resumed_batches):
            ids = list(run)
            # the batch read stays OUTSIDE the retry scope: sources are
            # forward-only streams, a re-read is not generally possible
            batch = _read_batch(src, ids, plan)
            # same f32-overflow guard as quantizer.resolve_eb, applied to the
            # data actually seen (an abs_eb stream takes no range prepass)
            max_q = float(np.abs(batch[: len(ids)]).max()) / (2.0 * eb)
            if max_q >= 2**30:
                raise ValueError(
                    f"eb={eb:g} too small for data magnitude "
                    f"(q={max_q:.3g} >= 2^30)")
            mem.add(batch.nbytes)

            def encode():
                if injector is not None:
                    injector.maybe_fail(bidx)
                return pred.encode_tiles(batch, eb, order=order,
                                         levels=levels, use_pallas=use_pallas)

            payload, recon = retry.run(encode, on_retry=note_retry(bidx))
            payload_np = jax.tree.map(np.asarray, payload)
            held = sum(leaf.nbytes for leaf in jax.tree.leaves(payload_np))
            blobs = None
            if device_entropy:
                # device stage emits the packed lane bytes directly (Pallas
                # encode kernel); only the lanes actually written, not the
                # batch's repeat padding
                blobs = pred.lane_bytes_batch(payload_np, len(ids), backend,
                                              use_pallas=True)
                held += sum(len(b) for b in blobs)
            mem.add(held)
            if reservoir is not None:
                recon_np = np.asarray(recon)[: len(ids)]
                mem.add(recon_np.nbytes)
                grew = reservoir.offer(recon_np, batch[: len(ids)] - recon_np)
                mem.add(grew)
                mem.sub(recon_np.nbytes)
            del recon
            mem.sub(batch.nbytes)
            del batch
            if pending is not None:
                pending.result()  # cap in-flight host work at one batch
            pending = pool.submit(host_stage, payload_np, ids, bidx, held,
                                  blobs)
            del payload, payload_np, blobs
        if pending is not None:
            pending.result()
            pending = None

        enhanced = False
        if reservoir is not None and len(reservoir):
            from repro.core.pipeline import serialize_model
            from repro.core.trainer import train_enhancers_streamed

            model, _hist = train_enhancers_streamed(reservoir, cfg)
            writer.extras["gwlz"] = serialize_model(model)
            enhanced = True
        nbytes = writer.finalize()
    except BaseException:
        if pending is not None:  # drain the worker before touching the sink
            try:
                pending.result()
            # the worker can only fail the ways the append path fails; a
            # propagating exception here would mask the original error
            except (OSError, RuntimeError, ValueError):
                pass
            pending = None
        if not isinstance(dest, GWTCWriter):
            journaled = writer._journal_path is not None
            writer.abort()  # close the fd; no footer = detectably truncated
            if path is not None and not journaled:
                try:
                    os.unlink(path)  # don't leave a garbage container behind
                except OSError:
                    pass
            # journaled path dests keep the partial container + journal on
            # disk: that pair is exactly what resume=True needs
        raise
    finally:
        if pending is not None:  # a failed batch: drain the worker first
            try:
                pending.result()
            except (OSError, RuntimeError, ValueError):
                pass
        pool.shutdown(wait=True)
        src.close()

    return StreamReport(
        path=path, shape=plan.shape, tile=plan.tile, n_tiles=plan.n_tiles,
        n_batches=plan.n_batches, batch_tiles=plan.batch_tiles, nbytes=nbytes,
        eb_abs=eb, predictor=predictor, backend=backend,
        mem_budget=int(mem_budget), peak_tracked_bytes=mem.peak,
        ru_maxrss_kb=int(resource.getrusage(resource.RUSAGE_SELF).ru_maxrss),
        enhanced=enhanced,
        reservoir_tiles=len(reservoir) if reservoir is not None else 0,
        retries=retries,
        failed_batches=tuple(sorted(failed_batches)),
        resumed_batches=resumed_batches,
        host_stage_s=host_stage_s,
        entropy_device=device_entropy,
        programs_compiled=programs_compiled,
    )
