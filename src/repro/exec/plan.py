"""Streaming plans: tile-batch sizing against a byte budget.

A plan turns (volume shape, tile grid, memory budget) into a sequence of
contiguous row-major tile-id runs.  Two invariants matter:

* every batch has the SAME tile count (the final short run is padded at
  execution time), so the device encode compiles exactly one program,
* with the executor's one-batch-in-flight overlap, at most two batches of
  working set are alive at once — so each batch is sized to half the
  budget, keeping tracked peak memory ≤ the budget (asserted by the
  acceptance test at ≤ 2x for safety against allocator slack).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.sz.predictor import _padded_shape
from repro.sz.tiled import bucket_chunks, tile_grid


def tile_working_bytes(tile: tuple[int, ...], predictor: str, levels: int,
                       *, device_entropy: bool = False) -> int:
    """Conservative per-tile working-set estimate for one streamed tile:
    f32 input + the predictor's payload leaves + its recon.

    ``device_entropy`` adds the Pallas encode-pack words (one u32 lane per
    symbol, held until the host stage splices the lane blob)."""
    t = int(np.prod(tile))
    extra = 4 * t if device_entropy else 0
    if predictor == "interp":
        p = int(np.prod(_padded_shape(tile, levels)))
        # codes i32 + omask bool + ovals f32 + recon f32 on the padded grid
        return 4 * t + 13 * p + extra
    # lorenzo: codes i32 + recon f32 on the tile grid
    return 4 * t + 8 * t + extra


def bucketed_batch_tiles(n_lanes: int, bucket_cap: int | None = None) -> int:
    """Device-batch tile count after bucket padding: the sum of the bucket
    widths ``tiled.dispatch_bucketed`` will actually dispatch for ``n_lanes``
    real tiles.  Admission control prices requests with THIS number — padded
    rows occupy device working set exactly like real rows, so a 5-lane
    request dispatched through an 8-wide bucket must be admitted as 8."""
    return sum(bucket_chunks(int(n_lanes), bucket_cap))


def max_inflight_tiles(
    mem_budget: int,
    tile: tuple[int, ...],
    *,
    predictor: str = "lorenzo",
    levels: int = 0,
) -> int:
    """Admission width for concurrent DECODE: how many tiles may be
    in flight at once before their working sets overflow ``mem_budget``.

    The per-tile cost reuses :func:`tile_working_bytes` — decode walks the
    same payload leaves the streamed encode does — so the serving daemon's
    admission control and the streaming executor's batch sizing are two
    views of one byte budget (docs/SERVING.md).  Always admits at least
    one tile: a budget smaller than a single working set serializes
    requests rather than deadlocking them."""
    per = tile_working_bytes(tile, predictor, levels)
    return max(1, int(mem_budget) // per)


@dataclass(frozen=True)
class StreamPlan:
    shape: tuple[int, ...]
    tile: tuple[int, ...]
    grid: tuple[int, ...]
    n_tiles: int
    batch_tiles: int  # uniform device-batch width
    mem_budget: int
    tile_bytes: int  # per-tile working-set estimate
    device_entropy: bool = False  # lane packing runs in the device stage

    @property
    def n_batches(self) -> int:
        return -(-self.n_tiles // self.batch_tiles)

    def batches(self, start_tile: int = 0):
        """Contiguous row-major id runs: range(a, b) per batch.

        ``start_tile`` (a batch-aligned tile id, see :meth:`resume_point`)
        skips the already-committed prefix when resuming an interrupted
        stream — the remaining runs are exactly the ones an uninterrupted
        stream would have produced."""
        if start_tile % self.batch_tiles:
            raise ValueError(
                f"start_tile {start_tile} is not aligned to the batch width "
                f"{self.batch_tiles}")
        for a in range(start_tile, self.n_tiles, self.batch_tiles):
            yield range(a, min(a + self.batch_tiles, self.n_tiles))

    def resume_point(self, committed_lanes: int) -> int:
        """Round a writer's commit point *down* to a batch boundary.

        Resume must re-encode whole batches (the device program and the
        reservoir-free entropy stage are deterministic per batch), so a
        commit landing mid-batch surrenders the partial batch and restarts
        it — the price of byte-identical output."""
        committed_lanes = min(int(committed_lanes), self.n_tiles)
        return (committed_lanes // self.batch_tiles) * self.batch_tiles


def plan_stream(
    shape: tuple[int, ...],
    tile: tuple[int, ...],
    mem_budget: int,
    *,
    predictor: str = "lorenzo",
    levels: int = 0,
    devices: int | None = None,
    device_entropy: bool = False,
) -> StreamPlan:
    """Size tile batches so ~two in-flight batches fit the byte budget.

    ``devices`` (default: the local device count) rounds the batch down to
    a device multiple when possible, so ``sharding.map_tiles`` fan-out pads
    nothing in steady state."""
    from repro.launch.sharding import device_round

    grid = tile_grid(shape, tile)
    n_tiles = int(np.prod(grid))
    per = tile_working_bytes(tile, predictor, levels,
                             device_entropy=device_entropy)
    batch = max(1, int(mem_budget) // (2 * per))
    batch = min(batch, n_tiles)
    batch = device_round(batch, devices)
    return StreamPlan(shape=tuple(shape), tile=tuple(tile), grid=grid,
                      n_tiles=n_tiles, batch_tiles=batch,
                      mem_budget=int(mem_budget), tile_bytes=per,
                      device_entropy=device_entropy)
