"""Size-capped, thread-safe LRU cache of decoded tiles.

Backs ``repro.api.CompressedVolume`` region reads: repeated / overlapping
ROI decodes under concurrent load hit finished tiles instead of re-running
entropy decode + prediction + enhancement.  Values are read-only numpy
tiles (post-enhancement, so a hit is the final answer); the cap is in
BYTES, not entries, because tile shapes vary across volumes sharing a
handle-less default.

One instance may be SHARED by many volume handles (the ``repro.serve``
daemon pools every open volume behind one budgeted cache) — callers
namespace their keys, e.g. ``(volume_ns, tile_id)``, and
:meth:`drop_namespace` evicts one volume's tiles without disturbing its
neighbors.

Besides plain ``get_many``/``put``, the cache implements **single-flight**
decode coalescing (:meth:`claim` / :meth:`fulfill` / :meth:`abandon`):
concurrent readers that miss on the same key agree on ONE owner to decode
it; everyone else blocks on the in-flight entry and receives the decoded
tile directly — even when the cache itself is too small to retain it — so
overlapping ROIs arriving together cost each lane exactly one decode.
"""
from __future__ import annotations

import threading
import time
from collections import OrderedDict

import numpy as np


class _Flight:
    """An in-flight decode: the owner decodes, waiters block on ``event``.

    ``value`` doubles as the hand-off channel so waiters get the tile even
    when a zero/over-capacity cache refuses to retain it; ``value is None``
    after the event fires means the owner failed — waiters re-claim."""

    __slots__ = ("event", "value")

    def __init__(self):
        self.event = threading.Event()
        self.value: np.ndarray | None = None


class TileCache:
    """LRU over ``key -> read-only np.ndarray`` with a byte capacity.

    All operations take the internal lock and are O(1) amortized; decoding
    itself happens OUTSIDE the cache (callers insert results), so the lock
    is never held across slow work.  ``capacity_bytes=0`` disables caching
    (every ``get`` misses, ``put`` drops) but single-flight coalescing
    still works — the in-flight hand-off does not go through the LRU.

    Observability: ``hits`` (``get_many``/``claim`` found the key),
    ``misses`` (a caller was told to decode it), and ``coalesced``
    (a caller waited on another thread's in-flight decode instead of
    duplicating it) are monotone counters reported by :meth:`info` with
    the derived ``hit_rate`` — hits over touched keys — which the serving
    daemon exposes as the truth on ``/metrics``."""

    def __init__(self, capacity_bytes: int):
        self.capacity = int(capacity_bytes)
        self._lock = threading.Lock()
        self._d: OrderedDict[object, np.ndarray] = OrderedDict()  # guarded-by: _lock
        self._nbytes = 0  # guarded-by: _lock
        self._inflight: dict[object, _Flight] = {}  # guarded-by: _lock
        self._hits = 0  # guarded-by: _lock
        self._misses = 0  # guarded-by: _lock
        self._coalesced = 0  # guarded-by: _lock

    @property
    def nbytes(self) -> int:
        with self._lock:
            return self._nbytes

    def __len__(self) -> int:
        with self._lock:
            return len(self._d)

    @property
    def hits(self) -> int:
        with self._lock:
            return self._hits

    @property
    def misses(self) -> int:
        with self._lock:
            return self._misses

    def get_many(self, keys) -> dict:
        """Present entries among ``keys`` (each hit refreshed to MRU)."""
        out = {}
        with self._lock:
            for k in keys:
                v = self._d.get(k)
                if v is not None:
                    self._d.move_to_end(k)
                    out[k] = v
                    self._hits += 1
                else:
                    self._misses += 1
        return out

    # -- single-flight -----------------------------------------------------

    def claim(self, keys) -> tuple[dict, list, dict]:
        """Partition ``keys`` into ``(found, mine, theirs)`` atomically.

        ``found`` maps cached keys to their tiles (refreshed to MRU);
        ``mine`` lists the keys THIS caller now owns — it must decode them
        and :meth:`fulfill` (or :meth:`abandon`) every one; ``theirs`` maps
        keys another thread is already decoding to the :class:`_Flight` to
        wait on via :meth:`wait`."""
        found: dict = {}
        mine: list = []
        theirs: dict = {}
        with self._lock:
            for k in keys:
                v = self._d.get(k)
                if v is not None:
                    self._d.move_to_end(k)
                    found[k] = v
                    self._hits += 1
                elif k in self._inflight:
                    theirs[k] = self._inflight[k]
                    self._coalesced += 1
                else:
                    self._inflight[k] = _Flight()
                    mine.append(k)
                    self._misses += 1
        return found, mine, theirs

    def fulfill(self, key, arr: np.ndarray) -> None:
        """Complete an owned in-flight decode: insert into the LRU, hand
        the tile to every waiter, and release the flight."""
        self.put(key, arr)
        with self._lock:
            flight = self._inflight.pop(key, None)
        if flight is not None:
            flight.value = arr
            flight.event.set()

    def abandon(self, keys) -> None:
        """Release owned in-flight entries WITHOUT a value (decode failed).

        Waiters wake with ``value is None`` and re-claim — one of them
        becomes the new owner and retries (or re-raises the same error)."""
        with self._lock:
            flights = [self._inflight.pop(k, None) for k in keys]
        for flight in flights:
            if flight is not None:
                flight.event.set()

    @staticmethod
    def wait(flight: _Flight, timeout: float | None = None) -> np.ndarray | None:
        """Block until another thread's in-flight decode resolves; ``None``
        means the owner abandoned it and the caller should re-claim."""
        flight.event.wait(timeout)
        return flight.value

    # -- insert / evict ----------------------------------------------------

    def put(self, key, arr: np.ndarray) -> None:
        nb = int(arr.nbytes)
        if nb > self.capacity:
            return  # larger than the whole cache: never admit
        arr = np.ascontiguousarray(arr)
        arr.setflags(write=False)
        with self._lock:
            old = self._d.pop(key, None)
            if old is not None:
                self._nbytes -= old.nbytes
            self._d[key] = arr
            self._nbytes += nb
            while self._nbytes > self.capacity:
                _k, v = self._d.popitem(last=False)
                self._nbytes -= v.nbytes

    def drop_namespace(self, ns) -> int:
        """Evict every entry whose key is ``(ns, ...)`` — one closing volume
        leaving a shared cache.  Returns the number of tiles dropped."""
        with self._lock:
            doomed = [k for k in self._d
                      if isinstance(k, tuple) and k and k[0] == ns]
            for k in doomed:
                self._nbytes -= self._d.pop(k).nbytes
        return len(doomed)

    def clear(self) -> None:
        with self._lock:
            self._d.clear()
            self._nbytes = 0

    def info(self) -> dict:
        """Snapshot: occupancy plus the true hit/miss/coalesced counts (a
        coalesced wait is neither — the decode happened, once, elsewhere)."""
        with self._lock:
            touched = self._hits + self._misses
            return {"tiles": len(self._d), "nbytes": self._nbytes,
                    "capacity": self.capacity, "hits": self._hits,
                    "misses": self._misses, "coalesced": self._coalesced,
                    "inflight": len(self._inflight),
                    "hit_rate": (self._hits / touched) if touched else 0.0}


class _Round:
    """One micro-batch round for a group: the ids accumulated so far, the
    completion event, and the shared outcome (result dict or error)."""

    __slots__ = ("ids", "done", "result", "error")

    def __init__(self):
        self.ids: list = []
        self.done = threading.Event()
        self.result: dict | None = None
        self.error: BaseException | None = None


class DecodeBatcher:
    """Cross-request decode micro-batcher (continuous batching).

    Concurrent region requests that each own a few claimed tiles of the same
    volume would issue one device dispatch apiece; the batcher coalesces them:
    the FIRST submitter for a group becomes the round's *leader*, waits up to
    ``max_wait_ms`` for followers to append their tile ids, then decodes the
    union in one bucketed dispatch and hands every submitter its slice.
    Followers that arrive after the leader drained the round start the next
    round — there is no global tick, so an idle volume pays zero latency and
    a busy one forms batches back-to-back.

    This layers ABOVE the single-flight claim/fulfill protocol: submitters
    only bring ids they already own claims for, so the batcher never sees a
    duplicate decode across requests (dedup within a round is still applied
    in case two submitters race the same abandoned claim).  The leader calls
    ``decode_fn`` OUTSIDE the lock; it holds no cache locks while waiting, so
    batching cannot deadlock against claim/fulfill.

    ``max_batch_tiles`` wakes the leader early once enough work is pending —
    the latency knob bounds the wait, the size knob bounds the batch."""

    def __init__(self, *, max_wait_ms: float = 2.0, max_batch_tiles: int = 256):
        self.max_wait_ms = float(max_wait_ms)
        self.max_batch_tiles = int(max_batch_tiles)
        self._cv = threading.Condition()
        self._rounds: dict = {}  # guarded-by: _cv
        self.submits = 0  # guarded-by: _cv
        self.dispatches = 0  # guarded-by: _cv
        self.coalesced_submits = 0  # guarded-by: _cv
        self.pending_tiles = 0  # guarded-by: _cv
        self.peak_pending_tiles = 0  # guarded-by: _cv
        self.batch_hist: dict = {}  # guarded-by: _cv

    def submit(self, group, lane_ids, decode_fn) -> dict:
        """Decode ``lane_ids`` (for ``group``) via a shared round; returns
        ``{lane_id: tile}`` for exactly the requested ids.

        ``decode_fn(ids)`` must return ``{id: np.ndarray}`` for the union of
        a round's ids; it runs once per round, on the leader's thread.  A
        leader-side decode error propagates to every submitter in the round
        (all their claims fail together — callers abandon and re-raise, the
        single-flight protocol's normal error path)."""
        lane_ids = list(lane_ids)
        if not lane_ids:
            return {}
        deadline = None
        with self._cv:
            self.submits += 1
            rnd = self._rounds.get(group)
            leader = rnd is None
            if leader:
                rnd = self._rounds[group] = _Round()
            else:
                self.coalesced_submits += 1
            rnd.ids.extend(lane_ids)
            self.pending_tiles += len(lane_ids)
            self.peak_pending_tiles = max(self.peak_pending_tiles,
                                          self.pending_tiles)
            self._cv.notify_all()
            if leader:
                deadline = time.monotonic() + self.max_wait_ms / 1e3
                while (len(rnd.ids) < self.max_batch_tiles
                       and (remaining := deadline - time.monotonic()) > 0):
                    self._cv.wait(remaining)
                # drain: later submits for this group start a fresh round
                del self._rounds[group]
                ids = list(dict.fromkeys(rnd.ids))
                self.pending_tiles -= len(rnd.ids)
                self.dispatches += 1
                self.batch_hist[len(ids)] = self.batch_hist.get(len(ids), 0) + 1
        if leader:
            try:
                rnd.result = decode_fn(ids)
            except BaseException as e:
                rnd.error = e
                raise
            finally:
                rnd.done.set()
        else:
            rnd.done.wait()
            if rnd.error is not None:
                raise rnd.error
        return {i: rnd.result[i] for i in lane_ids}

    def info(self) -> dict:
        """Snapshot for ``/metrics`` (histogram keys stringified for JSON)."""
        with self._cv:
            return {"submits": self.submits, "dispatches": self.dispatches,
                    "coalesced_submits": self.coalesced_submits,
                    "pending_tiles": self.pending_tiles,
                    "peak_pending_tiles": self.peak_pending_tiles,
                    "max_wait_ms": self.max_wait_ms,
                    "max_batch_tiles": self.max_batch_tiles,
                    "batch_hist": {str(k): v
                                   for k, v in sorted(self.batch_hist.items())}}
