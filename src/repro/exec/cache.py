"""Size-capped, thread-safe LRU cache of decoded tiles.

Backs ``repro.api.CompressedVolume`` region reads: repeated / overlapping
ROI decodes under concurrent load hit finished tiles instead of re-running
entropy decode + prediction + enhancement.  Values are read-only numpy
tiles (post-enhancement, so a hit is the final answer); the cap is in
BYTES, not entries, because tile shapes vary across volumes sharing a
handle-less default.
"""
from __future__ import annotations

import threading
from collections import OrderedDict

import numpy as np


class TileCache:
    """LRU over ``key -> read-only np.ndarray`` with a byte capacity.

    All operations take the internal lock and are O(1) amortized; decoding
    itself happens OUTSIDE the cache (callers insert results), so the lock
    is never held across slow work.  ``capacity_bytes=0`` disables caching
    (every ``get`` misses, ``put`` drops)."""

    def __init__(self, capacity_bytes: int):
        self.capacity = int(capacity_bytes)
        self._lock = threading.Lock()
        self._d: OrderedDict[object, np.ndarray] = OrderedDict()
        self._nbytes = 0

    @property
    def nbytes(self) -> int:
        return self._nbytes

    def __len__(self) -> int:
        return len(self._d)

    def get_many(self, keys) -> dict:
        """Present entries among ``keys`` (each hit refreshed to MRU)."""
        out = {}
        with self._lock:
            for k in keys:
                v = self._d.get(k)
                if v is not None:
                    self._d.move_to_end(k)
                    out[k] = v
        return out

    def put(self, key, arr: np.ndarray) -> None:
        nb = int(arr.nbytes)
        if nb > self.capacity:
            return  # larger than the whole cache: never admit
        arr = np.ascontiguousarray(arr)
        arr.setflags(write=False)
        with self._lock:
            old = self._d.pop(key, None)
            if old is not None:
                self._nbytes -= old.nbytes
            self._d[key] = arr
            self._nbytes += nb
            while self._nbytes > self.capacity:
                _k, v = self._d.popitem(last=False)
                self._nbytes -= v.nbytes

    def clear(self) -> None:
        with self._lock:
            self._d.clear()
            self._nbytes = 0

    def info(self) -> dict:
        with self._lock:
            return {"tiles": len(self._d), "nbytes": self._nbytes,
                    "capacity": self.capacity}
