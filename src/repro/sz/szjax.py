"""End-to-end SZ3-class compressor API.

``compress`` returns both the serializable artifact and the decompressor-
visible reconstruction (conventional error-bounded compressors produce the
decompressed data during compression anyway, for bound checking — GWLZ relies
on this to train enhancers without a second decompress pass).
"""
from __future__ import annotations

import struct
import zlib
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.errors import CorruptContainerError
from repro.sz import artifact as A
from repro.sz import predictor as P
from repro.sz.entropy import decode_codes, encode_codes
from repro.sz.quantizer import resolve_eb

_HDR = struct.Struct("<4sBBBBQ")  # magic, ndim, predictor, order, levels, eb bits as u64
_MAGIC = A.SZJX_MAGIC
# Wire ids are shared with the GWTC container (canonical registry ids).
_PRED = P.PRED_IDS
_PRED_INV = P.PRED_NAMES
_ORD = P.ORDER_IDS
_ORD_INV = P.ORDER_NAMES


@dataclass
class SZCompressed:
    """Self-describing compressed artifact (all host-side)."""

    shape: tuple[int, ...]
    padded_shape: tuple[int, ...]
    levels: int
    eb_abs: float
    predictor: str
    order: str
    code_blob: bytes
    outlier_idx: np.ndarray  # int64 flat indices into the padded volume
    outlier_val: np.ndarray  # float32 exact values
    extras: dict = field(default_factory=dict)  # e.g. attached GWLZ enhancers
    # serialization cache: (extras fingerprint, blob); GWLZ.compress asks for
    # nbytes before and after attaching enhancers, and size_report() again
    _blob_cache: tuple | None = field(default=None, init=False, repr=False, compare=False)

    @property
    def nbytes(self) -> int:
        return len(self.to_bytes())

    def _extras_key(self) -> tuple:
        # exact: holds references to the immutable values, no copies or hashes
        return tuple(sorted(self.extras.items()))

    def size_report(self) -> dict:
        extras = sum(len(v) for v in self.extras.values())
        return {
            "codes": len(self.code_blob),
            "outliers": 8 * self.outlier_idx.size + 4 * self.outlier_val.size,
            "extras": extras,
            "header": _HDR.size + 8 * len(self.shape) * 2 + 16,
            "total": self.nbytes,
        }

    def to_bytes(self) -> bytes:
        key = self._extras_key()
        if self._blob_cache is not None and self._blob_cache[0] == key:
            return self._blob_cache[1]
        blob = self._serialize()
        self._blob_cache = (key, blob)
        return blob

    def _serialize(self) -> bytes:
        hdr = _HDR.pack(
            _MAGIC,
            len(self.shape),
            _PRED[self.predictor],
            _ORD[self.order],
            self.levels,
            np.float64(self.eb_abs).view(np.uint64),
        )
        dims = struct.pack(f"<{len(self.shape)}q", *self.shape)
        pdims = struct.pack(f"<{len(self.padded_shape)}q", *self.padded_shape)
        out_blob = zlib.compress(
            self.outlier_idx.astype(np.int64).tobytes()
            + self.outlier_val.astype(np.float32).tobytes(),
            6,
        )
        extras_items = sorted(self.extras.items())
        extras_blob = struct.pack("<I", len(extras_items))
        for k, v in extras_items:
            kb = k.encode()
            extras_blob += struct.pack("<II", len(kb), len(v)) + kb + v
        return (
            hdr
            + dims
            + pdims
            + struct.pack("<QQ", self.outlier_idx.size, len(out_blob))
            + out_blob
            + struct.pack("<Q", len(self.code_blob))
            + self.code_blob
            + extras_blob
        )

    @staticmethod
    def from_bytes(blob) -> "SZCompressed":
        # buffer inputs (memoryview over an mmap) materialize: the monolithic
        # container is whole-volume by construction, so there is nothing to
        # read lazily — and owning plain bytes lets the mmap close under it
        if not isinstance(blob, (bytes, bytearray)):
            blob = bytes(blob)
        try:
            magic, ndim, pred, order, levels, ebbits = _HDR.unpack_from(blob, 0)
            if magic != _MAGIC:
                raise CorruptContainerError(
                    "bad SZJX magic", offset=0, expected=_MAGIC,
                    actual=bytes(magic))
            if pred not in _PRED_INV or order not in _ORD_INV:
                raise CorruptContainerError(
                    "unknown SZJX predictor/order id", offset=6,
                    actual=(int(pred), int(order)))
            off = _HDR.size
            shape = struct.unpack_from(f"<{ndim}q", blob, off)
            off += 8 * ndim
            pshape = struct.unpack_from(f"<{ndim}q", blob, off)
            off += 8 * ndim
            n_out, out_len = struct.unpack_from("<QQ", blob, off)
            off += 16
            raw = zlib.decompress(blob[off : off + out_len])
            off += out_len
            oidx = np.frombuffer(raw, np.int64, n_out).copy()
            oval = np.frombuffer(raw, np.float32, n_out, offset=8 * n_out).copy()
            (clen,) = struct.unpack_from("<Q", blob, off)
            off += 8
            code_blob = blob[off : off + clen]
            off += clen
            (n_extras,) = struct.unpack_from("<I", blob, off)
            off += 4
            extras = {}
            for _ in range(n_extras):
                klen, vlen = struct.unpack_from("<II", blob, off)
                off += 8
                k = blob[off : off + klen].decode()
                off += klen
                extras[k] = blob[off : off + vlen]
                off += vlen
        except struct.error as e:
            raise CorruptContainerError(
                f"truncated SZJX blob: {e}", offset=0) from e
        except zlib.error as e:
            raise CorruptContainerError(
                f"corrupt SZJX outlier stream: {e}", offset=_HDR.size) from e
        return SZCompressed(
            shape=tuple(shape),
            padded_shape=tuple(pshape),
            levels=levels,
            eb_abs=float(np.uint64(ebbits).view(np.float64)),
            predictor=_PRED_INV[pred],
            order=_ORD_INV[order],
            code_blob=code_blob,
            outlier_idx=oidx,
            outlier_val=oval,
            extras=extras,
        )


A.register_container(_MAGIC, SZCompressed)


class SZCompressor:
    """Configurable error-bounded compressor (predictor x order x backend).

    The default ``huffman+zlib`` backend emits the chunked, vectorized-decode
    entropy format (docs/ENTROPY_FORMAT.md); artifacts produced by the seed
    single-stream format still decompress."""

    def __init__(self, predictor: str = "interp", order: str = "cubic",
                 backend: str = "huffman+zlib", max_levels: int = 5):
        if predictor not in _PRED or order not in _ORD:
            raise ValueError(f"unknown predictor/order {predictor!r}/{order!r} "
                             f"(predictors: {sorted(_PRED)}, orders: {sorted(_ORD)})")
        self.predictor = predictor
        self.order = order
        self.backend = backend
        self.max_levels = max_levels

    def compress(
        self, x: jax.Array, *, rel_eb: float | None = None, abs_eb: float | None = None
    ) -> tuple[SZCompressed, jax.Array]:
        """Returns (artifact, reconstruction). Exactly one of rel_eb/abs_eb."""
        x = jnp.asarray(x, jnp.float32)
        abs_eb = resolve_eb(x, rel_eb, abs_eb)

        if self.predictor == "lorenzo":
            codes = P.lorenzo_encode(x, abs_eb)
            recon = P.lorenzo_decode(codes, abs_eb, x.dtype)
            artifact = SZCompressed(
                shape=tuple(x.shape),
                padded_shape=tuple(x.shape),
                levels=0,
                eb_abs=abs_eb,
                predictor="lorenzo",
                order=self.order,
                code_blob=encode_codes(np.asarray(codes), self.backend),
                outlier_idx=np.zeros(0, np.int64),
                outlier_val=np.zeros(0, np.float32),
            )
            return artifact, recon

        codes, omask, ovals, recon, meta = P.interp_encode(
            x, abs_eb, order=self.order, max_levels=self.max_levels
        )
        orig_shape, pshape, levels = meta
        omask_np = np.asarray(omask)
        oidx = np.flatnonzero(omask_np.ravel()).astype(np.int64)
        oval = np.asarray(ovals).ravel()[oidx].astype(np.float32)
        artifact = SZCompressed(
            shape=orig_shape,
            padded_shape=pshape,
            levels=levels,
            eb_abs=abs_eb,
            predictor="interp",
            order=self.order,
            code_blob=encode_codes(np.asarray(codes), self.backend),
            outlier_idx=oidx,
            outlier_val=oval,
        )
        recon = recon[tuple(slice(0, d) for d in orig_shape)]
        return artifact, recon

    def compress_tiled(
        self, x: jax.Array, tile=(64, 64, 64), *,
        rel_eb: float | None = None, abs_eb: float | None = None,
        predictor: str | None = None,
        use_pallas: bool | None = None, workers: int | None = None,
    ):
        """Tile-grid compress (independent entropy lanes, ``GWTC`` v2
        container — docs/TILED_FORMAT.md).  Returns (TiledCompressed,
        reconstruction); the artifact supports :meth:`decompress_region`
        without a full-volume entropy decode.

        The per-tile transform dispatches through the predictor registry and
        honors ``self.predictor``/``self.order``/``self.backend`` exactly
        like the monolithic :meth:`compress` (each tile is an independent
        prediction domain, so interp tiles decode standalone and region
        decode stays bit-identical to the full decode's crop).  Pass
        ``predictor=`` to override per call."""
        from repro.sz import tiled

        return tiled.compress_tiled(
            x, tile, rel_eb=rel_eb, abs_eb=abs_eb, backend=self.backend,
            predictor=self.predictor if predictor is None else predictor,
            order=self.order, max_levels=self.max_levels,
            use_pallas=use_pallas, workers=workers)

    def decompress_tiled(self, artifact, *, workers: int | None = None) -> jax.Array:
        from repro.sz import tiled

        return tiled.decompress_tiled(artifact, workers=workers)

    def decompress_region(self, artifact, roi, *, workers: int | None = None) -> jax.Array:
        """Decode only the tiles intersecting ``roi`` (slices or (lo, hi)
        pairs); equals ``decompress_tiled(artifact)[roi]`` bit-for-bit."""
        from repro.sz import tiled

        return tiled.decompress_region(artifact, roi, workers=workers)

    def decompress(self, artifact: SZCompressed) -> jax.Array:
        if artifact.predictor == "lorenzo":
            codes = jnp.asarray(decode_codes(artifact.code_blob, artifact.shape))
            return P.lorenzo_decode(codes, artifact.eb_abs)
        codes = decode_codes(artifact.code_blob, artifact.padded_shape)
        omask = np.zeros(int(np.prod(artifact.padded_shape)), bool)
        ovals = np.zeros(int(np.prod(artifact.padded_shape)), np.float32)
        omask[artifact.outlier_idx] = True
        ovals[artifact.outlier_idx] = artifact.outlier_val
        meta = (artifact.shape, artifact.padded_shape, artifact.levels)
        return P.interp_decode(
            jnp.asarray(codes),
            jnp.asarray(omask.reshape(artifact.padded_shape)),
            jnp.asarray(ovals.reshape(artifact.padded_shape)),
            artifact.eb_abs,
            meta,
            order=artifact.order,
        )


def compress(x, *, rel_eb=None, abs_eb=None, predictor="interp", order="cubic",
             backend="huffman+zlib", max_levels=5):
    c = SZCompressor(predictor, order, backend, max_levels)
    return c.compress(x, rel_eb=rel_eb, abs_eb=abs_eb)


def decompress(artifact: SZCompressed) -> jax.Array:
    pred = artifact.predictor
    return SZCompressor(pred, artifact.order).decompress(artifact)
