"""Host-side entropy stage: canonical Huffman + zlib backends.

Bitstream packing is byte-sequential with no TPU analogue (real SZ GPU
pipelines also run it on host) — see DESIGN.md §3.5.  The TPU side hands this
module a dense int32 code tensor; encoding is fully vectorized numpy, decoding
is a table-driven walk (fast enough for benchmark volumes).
"""
from __future__ import annotations

import heapq
import struct
import zlib
from dataclasses import dataclass

import numpy as np

_MAGIC = b"RPRE"


def shannon_bits(symbols: np.ndarray) -> float:
    """Ideal entropy-coded size in bits (lower bound for any entropy coder)."""
    _, counts = np.unique(symbols, return_counts=True)
    p = counts / counts.sum()
    return float(-(p * np.log2(p)).sum() * symbols.size)


# ---------------------------------------------------------------------------
# Canonical Huffman
# ---------------------------------------------------------------------------


def _code_lengths(counts: np.ndarray) -> np.ndarray:
    """Huffman code length per symbol from frequency counts (heap build)."""
    n = len(counts)
    if n == 1:
        return np.array([1], np.int64)
    heap = [(int(c), i) for i, c in enumerate(counts)]
    heapq.heapify(heap)
    parent = np.full(2 * n - 1, -1, np.int64)
    nxt = n
    while len(heap) > 1:
        c1, i1 = heapq.heappop(heap)
        c2, i2 = heapq.heappop(heap)
        parent[i1] = nxt
        parent[i2] = nxt
        heapq.heappush(heap, (c1 + c2, nxt))
        nxt += 1
    depth = np.zeros(2 * n - 1, np.int64)
    for i in range(nxt - 2, -1, -1):  # parents always have higher index
        depth[i] = depth[parent[i]] + 1
    return depth[:n]


def _canonical_codes(lengths: np.ndarray) -> np.ndarray:
    """Assign canonical codewords (as uint64) given code lengths."""
    order = np.lexsort((np.arange(len(lengths)), lengths))
    codes = np.zeros(len(lengths), np.uint64)
    code = 0
    prev_len = 0
    for sym in order:
        L = int(lengths[sym])
        code <<= L - prev_len
        codes[sym] = code
        code += 1
        prev_len = L
    return codes


@dataclass
class HuffmanCodec:
    """Canonical Huffman over a dense alphabet produced by np.unique remap."""

    alphabet: np.ndarray  # original symbol values, sorted
    lengths: np.ndarray
    codes: np.ndarray

    @staticmethod
    def fit(symbols: np.ndarray) -> "HuffmanCodec":
        alphabet, inv, counts = np.unique(symbols, return_inverse=True, return_counts=True)
        lengths = _code_lengths(counts)
        codes = _canonical_codes(lengths)
        codec = HuffmanCodec(alphabet, lengths, codes)
        codec._inv = inv  # cache the remap for the immediate encode
        return codec

    # -- encode (vectorized) ------------------------------------------------
    def encode(self, symbols: np.ndarray) -> bytes:
        inv = getattr(self, "_inv", None)
        if inv is None or inv.size != symbols.size:
            inv = np.searchsorted(self.alphabet, symbols.ravel())
        lens = self.lengths[inv]
        cws = self.codes[inv]
        total = int(lens.sum())
        ends = np.cumsum(lens)
        starts = ends - lens
        # bit i belongs to symbol searchsorted(ends, i, 'right')
        bit_idx = np.arange(total, dtype=np.int64)
        sym_of_bit = np.searchsorted(ends, bit_idx, side="right")
        pos_in_code = bit_idx - starts[sym_of_bit]
        shift = (lens[sym_of_bit] - 1 - pos_in_code).astype(np.uint64)
        bits = ((cws[sym_of_bit] >> shift) & np.uint64(1)).astype(np.uint8)
        packed = np.packbits(bits)
        return struct.pack("<Q", total) + packed.tobytes()

    # -- decode (table-driven walk) -----------------------------------------
    def decode(self, blob: bytes, n_symbols: int) -> np.ndarray:
        (total,) = struct.unpack_from("<Q", blob, 0)
        bits = np.unpackbits(np.frombuffer(blob, np.uint8, offset=8))[:total]
        # canonical decode tables: for each length, first code + index base
        max_len = int(self.lengths.max())
        order = np.lexsort((np.arange(len(self.lengths)), self.lengths))
        sorted_syms = order
        first_code = np.zeros(max_len + 2, np.int64)
        first_idx = np.zeros(max_len + 2, np.int64)
        count_at = np.bincount(self.lengths.astype(np.int64), minlength=max_len + 1)
        code = 0
        idx = 0
        for L in range(1, max_len + 1):
            first_code[L] = code
            first_idx[L] = idx
            code = (code + count_at[L]) << 1
            idx += count_at[L]
        out = np.empty(n_symbols, self.alphabet.dtype)
        pos = 0
        bits_list = bits.tolist()
        fl_code = first_code.tolist()
        fl_idx = first_idx.tolist()
        cnt = count_at.tolist()
        for i in range(n_symbols):
            code = 0
            L = 0
            while True:
                code = (code << 1) | bits_list[pos]
                pos += 1
                L += 1
                if cnt[L] and code - fl_code[L] < cnt[L]:
                    out[i] = self.alphabet[sorted_syms[fl_idx[L] + code - fl_code[L]]]
                    break
        return out

    # -- serialization --------------------------------------------------------
    def table_bytes(self) -> bytes:
        return (
            struct.pack("<I", len(self.alphabet))
            + self.alphabet.astype(np.int32).tobytes()
            + self.lengths.astype(np.uint8).tobytes()
        )

    @staticmethod
    def from_table(blob: bytes) -> tuple["HuffmanCodec", int]:
        (n,) = struct.unpack_from("<I", blob, 0)
        off = 4
        alphabet = np.frombuffer(blob, np.int32, n, offset=off).copy()
        off += 4 * n
        lengths = np.frombuffer(blob, np.uint8, n, offset=off).astype(np.int64)
        off += n
        return HuffmanCodec(alphabet, lengths, _canonical_codes(lengths)), off


# ---------------------------------------------------------------------------
# Backends
# ---------------------------------------------------------------------------


def encode_codes(codes: np.ndarray, backend: str = "huffman+zlib") -> bytes:
    """Entropy-encode an int32 code tensor; returns a self-describing blob."""
    flat = np.ascontiguousarray(codes, np.int32).ravel()
    if backend == "zlib":
        # int32 -> int16 when it fits (usual case): halves the zlib input
        if flat.size and abs(flat).max(initial=0) < 2**15:
            payload = zlib.compress(flat.astype(np.int16).tobytes(), 6)
            tag = b"z2"
        else:
            payload = zlib.compress(flat.tobytes(), 6)
            tag = b"z4"
        return _MAGIC + tag + struct.pack("<Q", flat.size) + payload
    if backend in ("huffman", "huffman+zlib"):
        codec = HuffmanCodec.fit(flat)
        stream = codec.encode(flat)
        if backend == "huffman+zlib":
            stream = zlib.compress(stream, 6)
            tag = b"hz"
        else:
            tag = b"hf"
        table = codec.table_bytes()
        return (
            _MAGIC + tag + struct.pack("<QI", flat.size, len(table)) + table + stream
        )
    raise ValueError(f"unknown entropy backend {backend!r}")


def decode_codes(blob: bytes, shape: tuple[int, ...]) -> np.ndarray:
    assert blob[:4] == _MAGIC, "bad entropy blob"
    tag = blob[4:6]
    if tag in (b"z2", b"z4"):
        (n,) = struct.unpack_from("<Q", blob, 6)
        raw = zlib.decompress(blob[14:])
        dt = np.int16 if tag == b"z2" else np.int32
        return np.frombuffer(raw, dt).astype(np.int32).reshape(shape)
    if tag in (b"hf", b"hz"):
        n, tlen = struct.unpack_from("<QI", blob, 6)
        off = 6 + 12
        codec, used = HuffmanCodec.from_table(blob[off : off + tlen])
        stream = blob[off + tlen :]
        if tag == b"hz":
            stream = zlib.decompress(stream)
        return codec.decode(stream, n).astype(np.int32).reshape(shape)
    raise ValueError(f"unknown entropy tag {tag!r}")
