"""Host-side entropy stage: chunked canonical Huffman + zlib backends.

Bitstream packing is byte-sequential with no TPU analogue (real SZ GPU
pipelines also run it on host).  The TPU side hands this module a dense int32
code tensor; encoding is fully vectorized numpy, decoding is a chunked,
table-driven, vectorized walk: the symbol stream is split into fixed-size
chunks at encode time (per-chunk bit lengths live in the header), and every
chunk steps forward in lockstep — one word-level gather against a k-bit
multi-symbol canonical-Huffman LUT decodes all complete codes in the window
(codes longer than k bits resolve through one searchsorted over the
left-aligned codewords).  Chunk lanes are dispatched across cores with
``concurrent.futures``.

Blob layout, tag registry, and backward compatibility (legacy ``hf``/``hz``
blobs still decode through the seed per-symbol walk) are specified in
``docs/ENTROPY_FORMAT.md``.
"""
from __future__ import annotations

import heapq
import os
import struct
import sys
import zlib
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass
from typing import NamedTuple

import numpy as np

from repro.sz.artifact import ENTROPY_MAGIC

_MAGIC = ENTROPY_MAGIC

DEFAULT_CHUNK = 256  # symbols per independently decodable chunk
_LUT_BITS = 12  # primary decode-table width cap (2**k uint64 entries)
_FMT_CODE_LEN = 32  # FROZEN in the hc/hZ blob format (chunk-table width rule)
_MAX_CODE_LEN = _FMT_CODE_LEN  # encoder policy; must never exceed _FMT_CODE_LEN
_ACCEL_SPAN = 4096  # dense alphabet span served by the symbol_hist kernel
_DENSE_SPAN = 1 << 22  # host bincount beyond this falls back to np.unique


def _chunk_bits_dtype(chunk_size: int) -> str:
    """Chunk-table entry width: u16 whenever a full chunk of max-length codes
    fits.  Part of the hc/hZ wire format — the rule is pinned to the frozen
    ``_FMT_CODE_LEN``, never to current encoder policy."""
    return "<u2" if chunk_size * _FMT_CODE_LEN <= 0xFFFF else "<u4"


def shannon_bits(symbols: np.ndarray) -> float:
    """Ideal entropy-coded size in bits (lower bound for any entropy coder).

    Dense integer alphabets count through ``bincount`` (O(n)) exactly like
    ``HuffmanCodec.fit``; only sparse/float inputs pay the ``np.unique``
    sort."""
    flat = np.asarray(symbols).ravel()
    if flat.size == 0:
        return 0.0
    counts = None
    if np.issubdtype(flat.dtype, np.integer):
        lo, hi = int(flat.min()), int(flat.max())
        if hi - lo + 1 <= _DENSE_SPAN:
            counts = np.bincount(flat.astype(np.int64) - lo)
            counts = counts[counts > 0]
    if counts is None:
        _, counts = np.unique(flat, return_counts=True)
    p = counts / counts.sum()
    return float(-(p * np.log2(p)).sum() * flat.size)


# ---------------------------------------------------------------------------
# Canonical Huffman
# ---------------------------------------------------------------------------


def _code_lengths(counts: np.ndarray) -> np.ndarray:
    """Huffman code length per symbol from frequency counts (heap build)."""
    n = len(counts)
    if n == 0:
        return np.zeros(0, np.int64)
    if n == 1:
        return np.array([1], np.int64)
    heap = [(int(c), i) for i, c in enumerate(counts)]
    heapq.heapify(heap)
    parent = np.full(2 * n - 1, -1, np.int64)
    nxt = n
    while len(heap) > 1:
        c1, i1 = heapq.heappop(heap)
        c2, i2 = heapq.heappop(heap)
        parent[i1] = nxt
        parent[i2] = nxt
        heapq.heappush(heap, (c1 + c2, nxt))
        nxt += 1
    depth = np.zeros(2 * n - 1, np.int64)
    for i in range(nxt - 2, -1, -1):  # parents always have higher index
        depth[i] = depth[parent[i]] + 1
    return depth[:n]


def _limited_code_lengths(counts: np.ndarray, max_len: int = _MAX_CODE_LEN) -> np.ndarray:
    """Code lengths capped at ``max_len`` by count-halving (pathological skew
    only; equal counts give a balanced tree, so the loop terminates)."""
    c = np.asarray(counts, np.int64)
    lengths = _code_lengths(c)
    while lengths.size and int(lengths.max()) > max_len:
        c = (c + 1) >> 1
        lengths = _code_lengths(c)
    return lengths


def _canonical_codes(lengths: np.ndarray) -> np.ndarray:
    """Assign canonical codewords (as uint64) given code lengths."""
    order = np.lexsort((np.arange(len(lengths)), lengths))
    codes = np.zeros(len(lengths), np.uint64)
    code = 0
    prev_len = 0
    for sym in order:
        L = int(lengths[sym])
        code <<= L - prev_len
        codes[sym] = code
        code += 1
        prev_len = L
    return codes


def _accel_default() -> bool:
    # jax missing entirely or unable to initialize a backend means "no
    # accelerator" — anything else (KeyboardInterrupt, a typo'd plugin
    # import raising AttributeError, ...) is a real bug and must propagate
    try:
        import jax

        return jax.default_backend() == "tpu"
    except (ImportError, RuntimeError):
        return False


def _accel_hist(flat: np.ndarray, lo: int, span: int) -> np.ndarray:
    import jax.numpy as jnp

    from repro.kernels import ops

    shifted = jnp.asarray((flat.astype(np.int64) - lo).astype(np.int32))
    return np.asarray(ops.symbol_hist_op(shifted, n_bins=span), np.int64)


def _splice_chunks(local: np.ndarray, chunk_bits: np.ndarray) -> tuple[bytes, int]:
    """Concatenate per-chunk word-packed bit streams into one continuous
    MSB-first byte stream (hc/hZ chunks are *not* byte-aligned).

    ``local`` is the device pack output viewed as uint32 [C, W]: chunk c's
    bits live MSB-first in its first ``ceil(chunk_bits[c]/32)`` words, zeros
    beyond.  Each chunk's words shift right by its global bit offset mod 32
    (the spill re-split mirrors the kernel's two-step shifts), then land at
    word index offset>>5.  Adjacent chunks overlap in at most one boundary
    word with disjoint bits, so the scatter-OR is one exact float64
    ``bincount`` sum.  Output matches ``np.packbits`` byte-for-byte."""
    C, W = local.shape
    ends = np.cumsum(chunk_bits, dtype=np.int64)
    total = int(ends[-1]) if C else 0
    offs = ends - chunk_bits
    sh = (offs & 31).astype(np.uint32)[:, None]
    shifted = np.zeros((C, W + 1), np.uint32)
    shifted[:, :W] = local >> sh
    shifted[:, 1:] |= (local << (np.uint32(31) - sh)) << np.uint32(1)
    idx = (offs >> 5)[:, None] + np.arange(W + 1, dtype=np.int64)
    nwords = (total + 31) // 32
    out = np.bincount(idx.ravel(), weights=shifted.ravel().astype(np.float64),
                      minlength=nwords + 1)[:nwords]
    # disjoint bits per word => every float64 sum is exact and fits in u32
    stream = out.astype(np.int64).astype(np.uint32).astype(">u4").tobytes()
    return stream[: (total + 7) // 8], total


# ---------------------------------------------------------------------------
# Vectorized chunk decode machinery
# ---------------------------------------------------------------------------


def _sliding_words(stream: bytes, tail_pad: int = 16) -> tuple[np.ndarray, np.ndarray]:
    """(words, bytes) where words[i] holds stream[i:i+8] big-endian in uint64.

    Built once per decode so the per-step window gather is a single indexed
    load instead of eight.  ``tail_pad`` extra zero bytes keep gathers in
    bounds — decode_chunked sizes it so finished lanes can overrun the
    stream end harmlessly instead of clamping positions every step."""
    raw = np.frombuffer(stream, np.uint8)
    padded = np.zeros(raw.size + tail_pad, np.uint64)
    padded[: raw.size] = raw
    words = np.zeros(raw.size + tail_pad - 7, np.uint64)
    for j in range(8):
        words = (words << np.uint64(8)) | padded[j : j + words.size]
    return words, padded


def _gather_window(words: np.ndarray, padded: np.ndarray, p: np.ndarray) -> np.ndarray:
    """64-bit MSB-aligned window starting at bit position p (vectorized)."""
    byte = p >> np.uint64(3)
    sh = p & np.uint64(7)
    # sh == 0 is safe: x >> 8 on the uint64-widened byte is 0, not UB
    return (words[byte] << sh) | (padded[byte + 8] >> (np.uint64(8) - sh))


class _Tables(NamedTuple):
    """Canonical decode tables (per codec, built lazily)."""

    max_len: int
    k: int  # single-symbol LUT width in bits
    first_code: np.ndarray  # per-length canonical decode bases (bit walk)
    first_idx: np.ndarray
    count_at: np.ndarray
    order: np.ndarray  # symbol ids in canonical order
    lut: np.ndarray  # single-symbol LUT: (sym+1)<<8 | len, 0 = escape
    cw_left: np.ndarray  # left-aligned canonical codewords (monotone)
    L_sorted: np.ndarray  # code lengths in canonical order


class _MultiTables(NamedTuple):
    tables: _Tables
    mlut: np.ndarray  # multi-symbol probe LUT (see _multi_lut)
    B: int  # bits per symbol id slot
    S: int  # id slots per probe entry


def _resolve_long(w: np.ndarray, tables: _Tables) -> tuple[np.ndarray, np.ndarray]:
    """Escape path: windows whose code is longer than the LUT width.

    A complete prefix code partitions the 64-bit window space into intervals
    that start at the left-aligned codewords, so one searchsorted resolves
    any window regardless of code length."""
    i = np.searchsorted(tables.cw_left, w, side="right") - 1
    return tables.order[i], tables.L_sorted[i].astype(np.uint64)


def _id_shift0(B: int) -> int:
    """Bit offset of the first symbol id in a packed probe entry.

    Entries are byte-aligned so symbol expansion is a plain byte-view
    extraction: byte 0 = count, byte 1 = consumed bits, ids from byte 2
    (byte 4 for B=32 so the id stays dtype-aligned)."""
    return 32 if B == 32 else 16


def _multi_lut(lut1: np.ndarray, k: int, B: int, S: int) -> np.ndarray:
    """Multi-symbol LUT: entry packs count (byte 0), consumed bits (byte 1)
    and up to S symbol ids (B-bit slots from ``_id_shift0``), greedily
    covering every complete code in the k-bit window.

    ``lut1`` is the single-symbol table ((sym+1)<<8|len, 0 = escape).  An
    entry of 0 means even the first code overflows the window (escape)."""
    size = 1 << k
    W = np.arange(size, dtype=np.uint64)
    kmask = np.uint64(size - 1)
    consumed = np.zeros(size, np.uint64)
    count = np.zeros(size, np.uint64)
    acc = np.zeros(size, np.uint64)
    active = np.ones(size, bool)
    base = _id_shift0(B)
    for j in range(S):
        sub = (W << consumed) & kmask
        e1 = lut1[sub]
        ln = e1 & np.uint64(0xFF)
        ok = active & (e1 != 0) & (consumed + ln <= k)
        if not ok.any():
            break
        sym = (e1[ok] >> np.uint64(8)) - np.uint64(1)
        acc[ok] |= sym << np.uint64(base + j * B)
        consumed[ok] += ln[ok]
        count[ok] += np.uint64(1)
        active = ok
    return acc | (consumed << np.uint64(8)) | count


def _decode_lanes(words, padded, bit_pos, targets, out2d, mtables) -> int:
    """Lockstep decode: every lane (= chunk) runs one LUT probe per step.

    A probe decodes *all* complete codes inside its k-bit window (up to S,
    packed by ``_multi_lut``), so skewed streams advance several symbols per
    step.  ``out2d`` ([chunk_size, n_lanes] — step-major so the per-step
    store is contiguous) receives the raw packed entries; the caller expands
    them to symbols in one vectorized pass.  Finished lanes keep probing
    harmlessly into the zero tail pad — no per-lane bookkeeping in the hot
    loop.  Returns the number of steps taken."""
    tables, mlut = mtables.tables, mtables.mlut
    shift_k = np.uint64(64 - tables.k)
    pos = bit_pos.astype(np.uint64)
    cur = np.zeros(pos.size, np.uint64)
    targets = targets.astype(np.uint64)
    spill = tables.max_len > 56  # legacy-crafted deep tables need the 9th byte
    it = 0
    while not (cur >= targets).all():
        if it >= out2d.shape[0]:  # every probe yields >= 1 symbol
            raise ValueError("corrupt Huffman stream: chunk did not terminate")
        p = pos  # finished lanes overrun into the zero tail pad harmlessly
        if spill:
            w = _gather_window(words, padded, p)
        else:
            w = words[p >> np.uint64(3)] << (p & np.uint64(7))
        e = mlut[w >> shift_k]
        if not e.all():  # 0 entries = first code longer than the LUT width
            mi = np.flatnonzero(e == 0)
            s2, l2 = _resolve_long(w[mi], tables)
            e[mi] = ((s2.astype(np.uint64) << np.uint64(_id_shift0(mtables.B)))
                     | (l2 << np.uint64(8)) | np.uint64(1))
        out2d[it] = e
        pos = p + ((e >> np.uint64(8)) & np.uint64(0xFF))
        cur += e & np.uint64(0xFF)
        it += 1
    return it


def _expand_entries(used, targets, n_symbols, B, S) -> np.ndarray:
    """Unpack [n_lanes, n_steps] probe entries into the flat symbol-id stream.

    Each entry carries up to S byte-aligned symbol ids.  Because every lane
    owns a contiguous output region and probes emit ids in stream order, a
    single boolean extraction over the byte-view id slots in row-major
    order IS the symbol stream — no shifts, no scatter.  Overshoot ids
    (probes that crossed a chunk boundary) are dropped by the target
    clamp."""
    C, niter = used.shape
    cnts = (used & np.uint64(0xFF)).astype(np.int32)  # byteorder-safe
    excl = np.cumsum(cnts, axis=1, dtype=np.int32) - cnts
    take_n = np.minimum(cnts, np.maximum(targets[:, None].astype(np.int32) - excl, 0))
    if int(take_n.sum()) != n_symbols:
        raise ValueError("corrupt Huffman stream: symbol count mismatch")
    sel = np.arange(S) < take_n[..., None]
    if sys.byteorder == "little":
        off = _id_shift0(B) // 8
        if B == 8:
            ids = used.view(np.uint8).reshape(C, niter, 8)[:, :, off : off + S]
        elif B == 16:
            ids = used.view(np.uint16).reshape(C, niter, 4)[:, :, off // 2 : off // 2 + S]
        else:
            ids = used.view(np.uint32).reshape(C, niter, 2)[:, :, off // 4 : off // 4 + S]
    else:  # pragma: no cover — big-endian hosts take the shift path
        mask = np.uint64((1 << B) - 1)
        ids = np.stack([(used >> np.uint64(_id_shift0(B) + j * B)) & mask
                        for j in range(S)], axis=-1)
    return ids[sel].astype(np.int64)


# ---------------------------------------------------------------------------
# Codec
# ---------------------------------------------------------------------------


@dataclass
class HuffmanCodec:
    """Canonical Huffman over a dense alphabet.

    ``fit`` counts symbol frequencies through the ``symbol_hist`` accelerator
    op (dense-span alphabets; host bincount / np.unique otherwise), so the
    full volume never goes through a host sort."""

    alphabet: np.ndarray  # original symbol values, sorted
    lengths: np.ndarray
    codes: np.ndarray

    @staticmethod
    def fit(symbols: np.ndarray, *, use_accel: bool | None = None) -> "HuffmanCodec":
        flat = np.ascontiguousarray(symbols).ravel()
        if flat.size == 0:
            empty = np.zeros(0, np.int64)
            return HuffmanCodec(flat[:0].copy(), empty, empty.astype(np.uint64))
        dense_ok = np.issubdtype(flat.dtype, np.integer)
        if dense_ok:
            lo, hi = int(flat.min()), int(flat.max())
            span = hi - lo + 1
            dense_ok = span <= _DENSE_SPAN
        if dense_ok:
            accel = use_accel if use_accel is not None else _accel_default()
            shifted = flat.astype(np.int64) - lo
            if accel and span <= _ACCEL_SPAN:
                counts_full = _accel_hist(flat, lo, span)
            else:
                counts_full = np.bincount(shifted, minlength=span)
            nz = np.flatnonzero(counts_full)
            alphabet = (nz + lo).astype(flat.dtype)
            counts = counts_full[nz]
            rank = np.full(span, -1, np.int64)
            rank[nz] = np.arange(nz.size)
            inv = rank[shifted]
        else:
            alphabet, inv, counts = np.unique(flat, return_inverse=True, return_counts=True)
        lengths = _limited_code_lengths(counts)
        codec = HuffmanCodec(alphabet, lengths, _canonical_codes(lengths))
        codec._inv = inv  # cache the remap for the immediate encode
        return codec

    # -- encode (vectorized) ------------------------------------------------
    def _encode_bits(self, symbols: np.ndarray) -> tuple[np.ndarray, np.ndarray, int]:
        """Pack the code stream; returns (packed bytes, per-symbol cumulative
        bit ends, total bit count)."""
        flat = np.ascontiguousarray(symbols).ravel()
        # the fit-time remap is one-shot: it describes the fitted array, and a
        # size match alone can't prove `symbols` is that array
        inv = self.__dict__.pop("_inv", None)
        if inv is None or inv.size != flat.size:
            inv = np.searchsorted(self.alphabet, flat)
        lens = self.lengths[inv].astype(np.int64)
        cws = self.codes[inv]
        total = int(lens.sum())
        ends = np.cumsum(lens)
        starts = ends - lens
        # bit i belongs to symbol searchsorted(ends, i, 'right')
        bit_idx = np.arange(total, dtype=np.int64)
        sym_of_bit = np.searchsorted(ends, bit_idx, side="right")
        pos_in_code = bit_idx - starts[sym_of_bit]
        shift = (lens[sym_of_bit] - 1 - pos_in_code).astype(np.uint64)
        bits = ((cws[sym_of_bit] >> shift) & np.uint64(1)).astype(np.uint8)
        return np.packbits(bits), ends, total

    def encode(self, symbols: np.ndarray) -> bytes:
        packed, _, total = self._encode_bits(symbols)
        return struct.pack("<Q", total) + packed.tobytes()

    # -- decode tables -------------------------------------------------------
    def _decode_tables(self):
        cached = getattr(self, "_tables", None)
        if cached is not None:
            return cached
        n = len(self.lengths)
        max_len = int(self.lengths.max()) if n else 0
        k = min(max_len, _LUT_BITS)
        order = np.lexsort((np.arange(n), self.lengths))
        count_at = np.bincount(self.lengths.astype(np.int64), minlength=max_len + 2)
        first_code = np.zeros(max_len + 2, np.int64)
        first_idx = np.zeros(max_len + 2, np.int64)
        code = idx = 0
        for L in range(1, max_len + 1):
            first_code[L] = code
            first_idx[L] = idx
            code = (code + count_at[L]) << 1
            idx += count_at[L]
        # primary LUT: every k-bit window -> (symbol+1)<<8 | code_len packed in
        # one uint64 (single gather per decode step); canonical codes of
        # length <= k tile a contiguous prefix, the rest escapes (entry 0)
        lut = np.zeros(1 << k, np.uint64)
        L_sorted = self.lengths[order].astype(np.int64)
        if n:
            short = L_sorted <= k  # prefix of the canonical order
            widths = np.left_shift(1, k - L_sorted[short])
            packed = ((order[short] + 1) << 8) | L_sorted[short]
            lut[: int(widths.sum())] = np.repeat(packed, widths).astype(np.uint64)
        # left-aligned canonical codewords (monotone): escape resolution is
        # one searchsorted over them, whatever the code length
        cw_left = self.codes[order] << (64 - L_sorted).astype(np.uint64)
        tables = _Tables(max_len, k, first_code, first_idx, count_at, order,
                         lut, cw_left, L_sorted)
        self._tables = tables
        return tables

    def _multi_tables(self) -> _MultiTables:
        cached = getattr(self, "_mtables", None)
        if cached is not None:
            return cached
        tables = self._decode_tables()
        n = len(self.alphabet)
        B = 8 if n <= 256 else (16 if n <= 65536 else 32)
        S = (64 - _id_shift0(B)) // B  # 6 / 3 / 1 ids per probe entry
        mtables = _MultiTables(tables, _multi_lut(tables.lut, tables.k, B, S), B, S)
        self._mtables = mtables
        return mtables

    # -- decode (seed reference: per-symbol bit walk) -------------------------
    def decode_bitwalk(self, blob: bytes, n_symbols: int) -> np.ndarray:
        """Seed per-symbol decode, kept as the correctness reference and as
        the benchmark baseline for the vectorized path."""
        if n_symbols == 0:
            return self.alphabet[:0].copy()
        (total,) = struct.unpack_from("<Q", blob, 0)
        bits = np.unpackbits(np.frombuffer(blob, np.uint8, offset=8))[:total]
        t = self._decode_tables()
        sorted_syms = t.order
        out = np.empty(n_symbols, self.alphabet.dtype)
        pos = 0
        bits_list = bits.tolist()
        fl_code = t.first_code.tolist()
        fl_idx = t.first_idx.tolist()
        cnt = t.count_at.tolist()
        for i in range(n_symbols):
            code = 0
            L = 0
            while True:
                code = (code << 1) | bits_list[pos]
                pos += 1
                L += 1
                if cnt[L] and code - fl_code[L] < cnt[L]:
                    out[i] = self.alphabet[sorted_syms[fl_idx[L] + code - fl_code[L]]]
                    break
        return out

    decode = decode_bitwalk  # legacy API (hf/hz blobs, small streams)

    # -- decode (chunked, vectorized, parallel) -------------------------------
    def decode_chunked(
        self,
        stream: bytes,
        n_symbols: int,
        chunk_size: int,
        chunk_bits: np.ndarray,
        *,
        total_bits: int | None = None,
        workers: int | None = None,
        chunk_range: tuple[int, int] | None = None,
    ) -> np.ndarray:
        """Decode a chunked stream: each chunk's bit offset comes from the
        chunk table, so lanes decode independently and in parallel.

        ``chunk_range=(c0, c1)`` decodes only chunks ``[c0, c1)`` — the
        random-access primitive behind :func:`decode_codes_range`: the
        chunk table gives every chunk's bit offset, so a sub-range costs
        O(symbols in range), not O(stream)."""
        if n_symbols == 0:
            return self.alphabet[:0].copy()
        if self.alphabet.size == 0:
            raise ValueError("empty codec cannot decode a nonempty stream")
        mtables = self._multi_tables()
        if mtables.tables.max_len > 63:  # a 64-bit probe window can't hold the code
            raise ValueError("chunked decode supports code lengths <= 63")
        chunk_bits = np.asarray(chunk_bits, np.int64)
        C = chunk_bits.size
        if C != -(-n_symbols // chunk_size):
            raise ValueError("chunk table size inconsistent with symbol count")
        ends = np.cumsum(chunk_bits)
        if total_bits is not None and int(ends[-1]) != total_bits:
            raise ValueError("chunk table inconsistent with stream length")
        offsets = ends - chunk_bits
        counts = np.full(C, chunk_size, np.int64)
        counts[-1] = n_symbols - chunk_size * (C - 1)
        total = int(ends[-1])
        if chunk_range is not None:
            c0, c1 = chunk_range
            if not 0 <= c0 < c1 <= C:
                raise ValueError(f"chunk range {chunk_range} outside [0, {C})")
            offsets, counts = offsets[c0:c1], counts[c0:c1]
            n_symbols = int(counts.sum())
            C = c1 - c0
        if len(stream) < (total + 7) // 8:
            raise ValueError("truncated Huffman stream")
        # tail pad absorbs finished lanes overrunning the stream end (<= 63
        # bits per step for at most chunk_size steps) without clamping
        words, padded = _sliding_words(stream, tail_pad=8 * chunk_size + 16)
        if workers is not None:
            w = max(1, min(workers, C))
        else:
            # threads only pay off past GIL contention: need cores and lanes
            cores = os.cpu_count() or 1
            w = max(1, min(cores, 8, C // 256)) if cores > 2 else 1
        # step-major probe log; threaded runs zero it so a worker stopping
        # early leaves count=0 slots, single-lane runs fill every used row
        out2d = (np.empty if w <= 1 else np.zeros)((chunk_size, C), np.uint64)
        if w <= 1:
            niter = _decode_lanes(words, padded, offsets, counts, out2d, mtables)
        else:
            bounds = np.linspace(0, C, w + 1).astype(int)
            with ThreadPoolExecutor(w) as ex:
                futs = [
                    ex.submit(_decode_lanes, words, padded, offsets[a:b], counts[a:b],
                              out2d[:, a:b], mtables)
                    for a, b in zip(bounds[:-1], bounds[1:])
                    if b > a
                ]
                niter = max(f.result() for f in futs)
        used = np.ascontiguousarray(out2d[:niter].T)  # lane-major for expansion
        return self.alphabet[_expand_entries(used, counts, n_symbols,
                                             mtables.B, mtables.S)]

    # -- device (Pallas) pack / decode ---------------------------------------
    def _device_eligible(self) -> bool:
        """hc/hZ device kernels work in 32-bit windows: every code length must
        fit (true for any freshly fitted codec by encoder policy; crafted
        legacy tables can exceed it and stay on host)."""
        n = len(self.alphabet)
        return 0 < n < (1 << 31) and int(self.lengths.max()) <= 32

    def _device_tables(self):
        """Multi-symbol LUT split into parallel int32 arrays for the decode
        kernel (packed uint64 entries have no device analogue).  Cached;
        ``None`` when the codec is device-ineligible."""
        cached = getattr(self, "_dev_tables", None)
        if cached is not None:
            return cached or None
        if not self._device_eligible():
            self._dev_tables = False
            return None
        mt = self._multi_tables()
        t = mt.tables
        base = _id_shift0(mt.B)
        mask = np.uint64((1 << mt.B) - 1)
        lut_ids = np.stack([
            ((mt.mlut >> np.uint64(base + j * mt.B)) & mask).astype(np.int32)
            for j in range(mt.S)])
        # top-32 truncation is faithful: codes occupy the top <= 32 bits, so
        # interval boundaries only depend on the window's top 32 bits, and
        # the XOR maps unsigned order onto int32 for the kernel's compares
        cw32 = (t.cw_left >> np.uint64(32)).astype(np.uint32)
        dev = {
            "lut_count": (mt.mlut & np.uint64(0xFF)).astype(np.int32),
            "lut_bits": ((mt.mlut >> np.uint64(8)) & np.uint64(0xFF)).astype(np.int32),
            "lut_ids": lut_ids,
            "cw_map": (cw32 ^ np.uint32(0x80000000)).view(np.int32),
            "order": t.order.astype(np.int32),
            "len_sorted": t.L_sorted.astype(np.int32),
            "k": t.k,
        }
        self._dev_tables = dev
        return dev

    def _device_pack(self, flat: np.ndarray, chunk_size: int, *,
                     interpret: bool | None = None):
        """Device encode-pack: returns (stream bytes, chunk_bits int64, total)
        bit-identical to ``_encode_bits`` + the encode-side chunk table, or
        ``None`` when ineligible (caller falls back to the host pack)."""
        n = flat.size
        if n == 0 or not self._device_eligible() or chunk_size * 32 >= 1 << 31:
            return None
        import jax.numpy as jnp

        from repro.kernels import ops

        # same one-shot fit-time remap contract as _encode_bits
        inv = self.__dict__.pop("_inv", None)
        if inv is None or inv.size != n:
            inv = np.searchsorted(self.alphabet, flat)
        C = -(-n // chunk_size)
        pad = C * chunk_size - n
        lens = self.lengths[inv].astype(np.int32)
        cws = self.codes[inv].astype(np.uint32).view(np.int32)
        if pad:
            lens = np.concatenate([lens, np.zeros(pad, np.int32)])
            cws = np.concatenate([cws, np.zeros(pad, np.int32)])
        words, chunk_bits = ops.huffman_encode_op(
            jnp.asarray(lens.reshape(C, chunk_size)),
            jnp.asarray(cws.reshape(C, chunk_size)),
            use_pallas=True, interpret=interpret)
        stream, total = _splice_chunks(
            np.asarray(words).view(np.uint32),
            np.asarray(chunk_bits).astype(np.int64))
        return stream, np.asarray(chunk_bits).astype(np.int64), total

    def decode_chunked_device(
        self,
        stream: bytes,
        n_symbols: int,
        chunk_size: int,
        chunk_bits: np.ndarray,
        *,
        total_bits: int | None = None,
        chunk_range: tuple[int, int] | None = None,
        interpret: bool | None = None,
    ) -> np.ndarray | None:
        """Same contract as :meth:`decode_chunked`, running the lockstep
        multi-symbol LUT probe as a Pallas kernel.  Returns ``None`` when the
        codec or stream is device-ineligible (caller falls back to host)."""
        if n_symbols == 0:
            return self.alphabet[:0].copy()
        if self.alphabet.size == 0:
            raise ValueError("empty codec cannot decode a nonempty stream")
        chunk_bits = np.asarray(chunk_bits, np.int64)
        C = chunk_bits.size
        if C != -(-n_symbols // chunk_size):
            raise ValueError("chunk table size inconsistent with symbol count")
        ends = np.cumsum(chunk_bits)
        total = int(ends[-1])
        if total_bits is not None and total != total_bits:
            raise ValueError("chunk table inconsistent with stream length")
        dev = self._device_tables()
        # int32 bit positions bound the eligible stream/chunk size
        if dev is None or total >= 1 << 31 or chunk_size * 32 >= 1 << 31:
            return None
        if len(stream) < (total + 7) // 8:
            raise ValueError("truncated Huffman stream")
        offsets = (ends - chunk_bits).astype(np.int32)
        counts = np.full(C, chunk_size, np.int32)
        counts[-1] = n_symbols - chunk_size * (C - 1)
        if chunk_range is not None:
            c0, c1 = chunk_range
            if not 0 <= c0 < c1 <= C:
                raise ValueError(f"chunk range {chunk_range} outside [0, {C})")
            offsets, counts = offsets[c0:c1], counts[c0:c1]
            n_symbols = int(counts.sum())
        import jax.numpy as jnp

        from repro.kernels import ops

        raw = np.frombuffer(stream, np.uint8)
        # pad to a word boundary + 2 zero tail words for the wi+1 gather
        padded = np.zeros(raw.size + (-raw.size) % 4 + 8, np.uint8)
        padded[: raw.size] = raw
        words = padded.view(">u4").astype(np.uint32).view(np.int32)
        ids = ops.huffman_decode_op(
            jnp.asarray(words), jnp.asarray(offsets), jnp.asarray(counts),
            jnp.asarray(dev["lut_count"]), jnp.asarray(dev["lut_bits"]),
            jnp.asarray(dev["lut_ids"]), jnp.asarray(dev["cw_map"]),
            jnp.asarray(dev["order"]), jnp.asarray(dev["len_sorted"]),
            chunk_size=chunk_size, k=dev["k"],
            use_pallas=True, interpret=interpret)
        # only the last selected chunk can be short, so row-major flatten +
        # truncate is exactly the symbol stream
        flat_ids = np.asarray(ids).reshape(-1)[:n_symbols]
        return self.alphabet[flat_ids]

    # -- serialization --------------------------------------------------------
    def table_bytes(self) -> bytes:
        return (
            struct.pack("<I", len(self.alphabet))
            + self.alphabet.astype(np.int32).tobytes()
            + self.lengths.astype(np.uint8).tobytes()
        )

    @staticmethod
    def from_table(blob: bytes) -> tuple["HuffmanCodec", int]:
        (n,) = struct.unpack_from("<I", blob, 0)
        off = 4
        alphabet = np.frombuffer(blob, np.int32, n, offset=off).copy()
        off += 4 * n
        lengths = np.frombuffer(blob, np.uint8, n, offset=off).astype(np.int64)
        off += n
        return HuffmanCodec(alphabet, lengths, _canonical_codes(lengths)), off


# ---------------------------------------------------------------------------
# Backends
# ---------------------------------------------------------------------------


def encode_codes(
    codes: np.ndarray,
    backend: str = "huffman+zlib",
    *,
    chunk_size: int | None = None,
    use_accel: bool | None = None,
    use_pallas: bool | None = None,
) -> bytes:
    """Entropy-encode an int32 code tensor; returns a self-describing blob.

    Huffman backends emit the chunked ``hc``/``hcz`` format (see
    docs/ENTROPY_FORMAT.md); ``encode_codes_legacy`` still produces the seed
    ``hf``/``hz`` blobs for compatibility testing.

    ``use_pallas`` routes the bit-stream pack through the device encode
    kernel (``kernels/huffman_encode.py``): ``None`` auto-detects (device
    path on TPU only), ``True`` forces it (interpret mode off-TPU), ``False``
    keeps the host pack.  Bytes are bit-identical either way — device-
    ineligible codecs silently fall back to host."""
    flat = np.ascontiguousarray(codes, np.int32).ravel()
    if backend == "zlib":
        # int32 -> int16 when it fits (usual case): halves the zlib input
        if flat.size and abs(flat).max(initial=0) < 2**15:
            payload = zlib.compress(flat.astype(np.int16).tobytes(), 6)
            tag = b"z2"
        else:
            payload = zlib.compress(flat.tobytes(), 6)
            tag = b"z4"
        return _MAGIC + tag + struct.pack("<Q", flat.size) + payload
    if backend in ("huffman", "huffman+zlib"):
        codec = HuffmanCodec.fit(flat, use_accel=use_accel)
        cs = int(chunk_size) if chunk_size else DEFAULT_CHUNK
        n = flat.size
        n_chunks = -(-n // cs) if n else 0
        dev = _accel_default() if use_pallas is None else use_pallas
        got = codec._device_pack(flat, cs) if dev and n_chunks else None
        if got is not None:
            stream, chunk_bits, total = got
        else:
            packed, ends, total = codec._encode_bits(flat)
            if n_chunks:
                bnd = np.minimum(np.arange(1, n_chunks + 1, dtype=np.int64) * cs, n) - 1
                chunk_bits = np.diff(np.concatenate([[0], ends[bnd]]))
            else:
                chunk_bits = np.zeros(0, np.int64)
            stream = packed.tobytes()
        # chunk table + bit stream travel together so zlib sees both
        payload = chunk_bits.astype(_chunk_bits_dtype(cs)).tobytes() + stream
        if backend == "huffman+zlib":
            payload = zlib.compress(payload, 6)
            tag = b"hZ"
        else:
            tag = b"hc"
        table = codec.table_bytes()
        return (
            _MAGIC
            + tag
            + struct.pack("<QIII", n, cs, n_chunks, len(table))
            + table
            + struct.pack("<Q", total)
            + payload
        )
    raise ValueError(f"unknown entropy backend {backend!r}")


def encode_codes_legacy(codes: np.ndarray, backend: str = "huffman+zlib") -> bytes:
    """Seed (pre-chunking) encoder: emits ``hf``/``hz`` blobs.  Kept so tests
    and benchmarks can exercise the backward-compat decode path."""
    flat = np.ascontiguousarray(codes, np.int32).ravel()
    if backend not in ("huffman", "huffman+zlib"):
        raise ValueError(f"legacy encoder only supports huffman backends, got {backend!r}")
    codec = HuffmanCodec.fit(flat, use_accel=False)
    stream = codec.encode(flat)
    if backend == "huffman+zlib":
        stream = zlib.compress(stream, 6)
        tag = b"hz"
    else:
        tag = b"hf"
    table = codec.table_bytes()
    return _MAGIC + tag + struct.pack("<QI", flat.size, len(table)) + table + stream


_CODEC_CACHE: dict[bytes, HuffmanCodec] = {}


def _cached_codec(table: bytes) -> HuffmanCodec:
    """Decode-side codec cache: repeated decodes of the same artifact (the
    steady-state serving pattern) skip canonical-table and LUT rebuilds."""
    codec = _CODEC_CACHE.get(table)
    if codec is None:
        codec, _ = HuffmanCodec.from_table(table)
        if len(_CODEC_CACHE) >= 16:
            _CODEC_CACHE.pop(next(iter(_CODEC_CACHE)))
        _CODEC_CACHE[table] = codec
    return codec


def decode_codes(blob: bytes, shape: tuple[int, ...], *, workers: int | None = None,
                 use_pallas: bool | None = None) -> np.ndarray:
    """Decode an entropy blob back to int32 codes.

    ``use_pallas`` routes chunked hc/hZ streams through the device decode
    kernel (``kernels/huffman_decode.py``): ``None`` auto-detects (TPU only),
    ``True`` forces it (interpret mode off-TPU), ``False`` keeps the host
    walk.  Device-ineligible streams silently fall back to host."""
    assert blob[:4] == _MAGIC, "bad entropy blob"
    tag = blob[4:6]
    if tag in (b"z2", b"z4"):
        (n,) = struct.unpack_from("<Q", blob, 6)
        raw = zlib.decompress(blob[14:])
        dt = np.int16 if tag == b"z2" else np.int32
        return np.frombuffer(raw, dt).astype(np.int32).reshape(shape)
    if tag in (b"hc", b"hZ"):
        n, cs, n_chunks, tlen = struct.unpack_from("<QIII", blob, 6)
        off = 6 + 20
        codec = _cached_codec(blob[off : off + tlen])
        off += tlen
        (total,) = struct.unpack_from("<Q", blob, off)
        off += 8
        payload = blob[off:]
        if tag == b"hZ":
            payload = zlib.decompress(payload)
        cb_dtype = _chunk_bits_dtype(cs)
        chunk_bits = np.frombuffer(payload, cb_dtype, n_chunks)
        stream = payload[np.dtype(cb_dtype).itemsize * n_chunks :]
        dev = _accel_default() if use_pallas is None else use_pallas
        out = None
        if dev:
            out = codec.decode_chunked_device(stream, n, cs, chunk_bits,
                                              total_bits=total)
        if out is None:
            out = codec.decode_chunked(stream, n, cs, chunk_bits,
                                       total_bits=total, workers=workers)
        return out.astype(np.int32).reshape(shape)
    if tag in (b"hf", b"hz"):
        n, tlen = struct.unpack_from("<QI", blob, 6)
        off = 6 + 12
        codec, used = HuffmanCodec.from_table(blob[off : off + tlen])
        stream = blob[off + tlen :]
        if tag == b"hz":
            stream = zlib.decompress(stream)
        return codec.decode_bitwalk(stream, n).astype(np.int32).reshape(shape)
    raise ValueError(f"unknown entropy tag {tag!r}")


def decode_codes_range(blob: bytes, lo: int, hi: int, *, workers: int | None = None,
                       use_pallas: bool | None = None) -> np.ndarray:
    """Decode symbols ``[lo, hi)`` of an entropy blob as a flat int32 array.

    On the chunked ``hc``/``hZ`` formats this is a true partial read: only
    the chunks covering the range run the table-driven walk (the per-chunk
    bit table localizes them), so the cost is O(hi - lo) symbols — the
    sub-lane primitive for plane- or pencil-granular reads inside one tile
    lane.  ``hZ`` still pays one zlib pass over the lane (zlib has no
    random access); the legacy / zlib formats fall back to full decode +
    slice.  Equals ``decode_codes(blob, (n,))[lo:hi]`` bit-for-bit."""
    assert blob[:4] == _MAGIC, "bad entropy blob"
    tag = blob[4:6]
    if tag in (b"hc", b"hZ"):
        n, cs, n_chunks, tlen = struct.unpack_from("<QIII", blob, 6)
        if not 0 <= lo <= hi <= n:
            raise ValueError(f"symbol range [{lo}, {hi}) outside [0, {n})")
        if lo == hi:
            return np.zeros(0, np.int32)
        off = 6 + 20
        codec = _cached_codec(blob[off : off + tlen])
        off += tlen
        (total,) = struct.unpack_from("<Q", blob, off)
        off += 8
        payload = blob[off:]
        if tag == b"hZ":
            payload = zlib.decompress(payload)
        cb_dtype = _chunk_bits_dtype(cs)
        chunk_bits = np.frombuffer(payload, cb_dtype, n_chunks)
        stream = payload[np.dtype(cb_dtype).itemsize * n_chunks :]
        c0, c1 = lo // cs, -(-hi // cs)
        dev = _accel_default() if use_pallas is None else use_pallas
        out = None
        if dev:
            out = codec.decode_chunked_device(stream, n, cs, chunk_bits,
                                              total_bits=total,
                                              chunk_range=(c0, c1))
        if out is None:
            out = codec.decode_chunked(stream, n, cs, chunk_bits, total_bits=total,
                                       workers=workers, chunk_range=(c0, c1))
        return out.astype(np.int32)[lo - c0 * cs : hi - c0 * cs]
    flat = decode_codes(blob, (-1,), workers=workers).ravel()
    if not 0 <= lo <= hi <= flat.size:
        raise ValueError(f"symbol range [{lo}, {hi}) outside [0, {flat.size})")
    return flat[lo:hi]
