"""Common artifact protocol + container magic registry.

Every compressed container in the stack (`SZJX` monolithic, `GWTC` tiled —
and any future one) is a *self-describing* byte envelope: the first four
bytes name the container, and the container class knows how to rebuild
itself from the blob.  This module is the one place that mapping lives, so
consumers never switch on concrete artifact types:

* :class:`Artifact` is the structural protocol both containers satisfy
  (``shape`` / ``eb_abs`` / ``extras`` / ``to_bytes`` / ``nbytes`` /
  ``size_report``), the contract the ``repro.api`` façade programs against,
* :func:`register_container` is called by each container module at import
  time to claim its magic,
* :func:`from_bytes` sniffs the magic and dispatches to the right
  ``from_bytes`` — the self-sniffing half of the persistence layer
  (the multi-field ``GWDS`` dataset envelope, which holds these artifacts
  as fields, lives one level up in ``repro.api`` — docs/DATASET_FORMAT.md).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Protocol, runtime_checkable

from repro.errors import CorruptContainerError

MAGIC_LEN = 4


# ---------------------------------------------------------------------------
# Wire-tag registry — THE single home of container magic/version constants.
#
# Every magic byte string and format version number in the stack is defined
# here and imported (or aliased) by its consumers: the GWTC/SZJX parsers,
# the GWDS envelope (api.py + exec/writer.py), the commit journal, and the
# entropy blob header.  GWTC went v1->v3 and GWDS v1->v2 with the literals
# scattered per parser; centralizing them makes a format bump one edit and
# lets the RA005 static-analysis rule (repro.analysis.tags) reject any
# duplicated literal that could drift (docs/ANALYSIS.md).
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ContainerTag:
    """One container family's wire identity: its 4-byte magic, the current
    format version (None for unversioned headers), and the trailing footer
    sentinel when the layout has one."""

    name: str
    magic: bytes
    version: int | None = None
    sentinel: bytes | None = None


GWTC_MAGIC, GWTC_VERSION = b"GWTC", 3  # tiled container (docs/TILED_FORMAT.md)
SZJX_MAGIC = b"SZJX"                   # monolithic artifact (unversioned header)
GWDS_MAGIC, GWDS_VERSION = b"GWDS", 2  # multi-field dataset (docs/DATASET_FORMAT.md)
GWDS_SENTINEL = b"GWDX"                # GWDS v2 footer sentinel
JOURNAL_MAGIC, JOURNAL_VERSION = b"GWJL", 1  # commit journal (docs/ROBUSTNESS.md)
ENTROPY_MAGIC = b"RPRE"                # entropy lane blob (docs/ENTROPY_FORMAT.md)

CONTAINER_TAGS: dict[str, ContainerTag] = {
    "GWTC": ContainerTag("GWTC", GWTC_MAGIC, GWTC_VERSION),
    "SZJX": ContainerTag("SZJX", SZJX_MAGIC),
    "GWDS": ContainerTag("GWDS", GWDS_MAGIC, GWDS_VERSION, GWDS_SENTINEL),
    "GWJL": ContainerTag("GWJL", JOURNAL_MAGIC, JOURNAL_VERSION),
    "RPRE": ContainerTag("RPRE", ENTROPY_MAGIC),
}


@runtime_checkable
class Artifact(Protocol):
    """Structural contract every compressed container satisfies."""

    shape: tuple[int, ...]
    eb_abs: float
    extras: dict

    @property
    def nbytes(self) -> int: ...

    def to_bytes(self) -> bytes: ...

    def size_report(self) -> dict: ...

    @staticmethod
    def from_bytes(blob: bytes) -> "Artifact": ...


_CONTAINERS: dict[bytes, type] = {}


def register_container(magic: bytes, cls: type) -> None:
    """Claim a 4-byte magic for a container class (idempotent per class)."""
    if len(magic) != MAGIC_LEN:
        raise ValueError(f"container magic must be {MAGIC_LEN} bytes, got {magic!r}")
    existing = _CONTAINERS.get(magic)
    if existing is not None and existing is not cls:
        raise ValueError(f"magic {magic!r} already registered to {existing.__name__}")
    _CONTAINERS[magic] = cls


def container_magics() -> dict[bytes, type]:
    """Snapshot of the magic -> container-class registry."""
    return dict(_CONTAINERS)


def sniff_magic(blob: bytes) -> bytes:
    if len(blob) < MAGIC_LEN:
        raise CorruptContainerError(
            "blob too short to hold a container magic", offset=0,
            expected=f">= {MAGIC_LEN} bytes", actual=len(blob))
    return bytes(blob[:MAGIC_LEN])


def from_bytes(blob: bytes) -> Artifact:
    """Reconstruct whichever artifact the blob's magic names.

    Corrupt input raises :class:`repro.errors.CorruptContainerError` (a
    ``ValueError`` subclass) from the sniff or the container's own parser."""
    magic = sniff_magic(blob)
    cls = _CONTAINERS.get(magic)
    if cls is None:
        known = ", ".join(sorted(m.decode("ascii", "replace") for m in _CONTAINERS))
        raise CorruptContainerError(
            f"unknown container magic (registered: {known}; "
            f"multi-field GWDS datasets open through repro.api.open)",
            offset=0, actual=bytes(magic))
    return cls.from_bytes(blob)
