"""Tile-based compression engine with random-access decode (``GWTC``).

The monolithic SZ path materializes one volume end to end; this engine splits
the (padded) volume into a fixed tile grid and makes every tile a fully
independent compression domain:

* the per-tile prediction transform is *pluggable*: the tile batch dispatches
  through the predictor registry (``repro.sz.predictor.get_predictor``) —
  ``"lorenzo"`` (prequant + batched integer Lorenzo) or ``"interp"`` (SZ3-
  style multi-level interpolation, vmapped per tile).  Batched passes fan
  across the device mesh via ``repro.launch.sharding.map_tiles``,
* each tile entropy-encodes as an independent lane on the chunked ``hc``/
  ``hZ`` codec (docs/ENTROPY_FORMAT.md), so lanes decode independently and
  in parallel,
* the ``GWTC`` container stores a per-tile offset index, so
  :func:`decompress_region` entropy-decodes *only* the tiles intersecting
  the requested ROI — partial reads never pay for the whole blob.

Every predictor's batched decode is elementwise-exact in the batch axis
(each tile is an independent prediction domain), so region decode is
bit-identical to the full decode's crop whichever predictor produced the
artifact.  Container layout (``GWTC`` v2; v1 blobs still decode) is
specified in docs/TILED_FORMAT.md; the layered stack is described in
docs/ARCHITECTURE.md.
"""
from __future__ import annotations

import os
import struct
import threading
import zlib
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.errors import CorruptContainerError, CorruptLaneError
from repro.sz import artifact as A
from repro.sz.predictor import ORDER_IDS, ORDER_NAMES, PRED_IDS, PRED_NAMES, get_predictor
from repro.sz.quantizer import resolve_eb

_MAGIC = A.GWTC_MAGIC
_VERSION = A.GWTC_VERSION
# v1: magic, version, ndim, backend, pad, eb bits, n_tiles
_HDR_V1 = struct.Struct("<4sBBBBQQ")
# v2 adds the predictor layer: magic, version, ndim, backend, predictor,
# order, levels, pad, eb bits, n_tiles
_HDR_V2 = struct.Struct("<4sBBBBBBBQQ")
# v3 keeps the v2 header fields but moves the tile index (and extras) BEHIND
# the lanes so the container can be written append-only by a streaming
# encoder; a fixed-size footer at the end of the blob locates them
# (docs/STREAMING.md).  Layout: header | shape | tile | lanes... | extras |
# index | footer, where the index region is either
#   u64 lens[n_tiles]                                  (legacy, no checksums)
#   u64 lens[n_tiles] | u32 crcs[n_tiles] | u32 meta   (current)
# — distinguished by its byte extent, so pre-checksum v3 blobs keep parsing
# (docs/ROBUSTNESS.md).  ``crcs[i]`` covers lane i's bytes; ``meta`` covers
# header+shape+tile plus the extras blob, so every non-lane byte of the
# container is checksummed too.
_HDR_V3 = _HDR_V2
_FOOTER_V3 = struct.Struct("<QQ")  # (extras offset, index offset)
_BACKENDS = {"zlib": 0, "huffman": 1, "huffman+zlib": 2}
_BACKENDS_INV = {v: k for k, v in _BACKENDS.items()}


def lane_crc(data) -> int:
    """Container lane checksum: CRC-32 (IEEE 802.3, via the stdlib's C
    ``zlib.crc32``).  The format reserves the field for CRC-32C, but no
    Castagnoli implementation ships with the interpreter and this stack
    adds no dependencies — the polynomial choice is recorded in
    docs/ROBUSTNESS.md so a future native-codec swap is a deliberate
    format bump, not an accident."""
    return zlib.crc32(bytes(data)) & 0xFFFFFFFF


def _pack_extras(extras: dict) -> bytes:
    """Extras blob shared by the eager serializer and the streaming writer:
    count u32, then per entry klen u32 | vlen u32 | key | value, sorted."""
    items = sorted(extras.items())
    out = [struct.pack("<I", len(items))]
    for k, v in items:
        kb = k.encode()
        out.append(struct.pack("<II", len(kb), len(v)) + kb + bytes(v))
    return b"".join(out)


def _unpack_extras(blob, off: int) -> dict:
    (n_extras,) = struct.unpack_from("<I", blob, off)
    off += 4
    extras = {}
    for _ in range(n_extras):
        klen, vlen = struct.unpack_from("<II", blob, off)
        off += 8
        k = bytes(blob[off : off + klen]).decode()
        off += klen
        extras[k] = bytes(blob[off : off + vlen])
        off += vlen
    return extras


class LaneStore:
    """Lazy per-lane byte access over one backing buffer.

    Holds (buffer, per-lane offsets/lengths) instead of materialized lane
    copies, so opening an mmap-backed container reads *no* lane bytes until
    a decode asks for them — ``store[i]`` copies exactly lane ``i`` out of
    the buffer (a page-granular read on mmap).  ``release()`` drops the
    buffer reference so the owning mmap can close."""

    __slots__ = ("_buf", "_offs", "_lens")

    def __init__(self, buf, offsets: np.ndarray, lengths: np.ndarray):
        self._buf = buf
        self._offs = np.asarray(offsets, np.int64)
        self._lens = np.asarray(lengths, np.int64)

    def __len__(self) -> int:
        return int(self._lens.size)

    def __getitem__(self, i: int) -> bytes:
        if self._buf is None:
            raise ValueError("lane store is closed (volume was released)")
        o, n = int(self._offs[i]), int(self._lens[i])
        return bytes(self._buf[o : o + n])

    def __iter__(self):
        return (self[i] for i in range(len(self)))

    @property
    def nbytes(self) -> int:
        """Total lane bytes — computed from the index, no lane is read."""
        return int(self._lens.sum())

    def lane_nbytes(self, i: int) -> int:
        return int(self._lens[i])

    def release(self) -> None:
        self._buf = None


def lanes_nbytes(tile_blobs) -> int:
    """Total lane payload bytes without forcing lazy lanes into memory."""
    if isinstance(tile_blobs, LaneStore):
        return tile_blobs.nbytes
    return sum(len(b) for b in tile_blobs)


def _index_nbytes(n_tiles: int) -> int:
    """Byte extent of the checksummed v3 index region the writer emits:
    u64 lens | u32 crcs | u32 meta_crc."""
    return 8 * n_tiles + 4 * n_tiles + 4


def lane_offset(artifact: "TiledCompressed", i: int) -> int:
    """Container-relative byte offset of lane ``i`` — error-path helper so
    :class:`CorruptLaneError` can point at the damaged region on disk."""
    tb = artifact.tile_blobs
    if isinstance(tb, LaneStore):
        return int(tb._offs[i])
    base = _HDR_V3.size + 16 * len(artifact.shape)
    return base + sum(len(tb[j]) for j in range(i))

# DEPRECATED module-global mirror: how many lanes the last decode touched.
# Kept as a best-effort alias for existing tests/benchmarks — new code should
# read the per-handle ``repro.api.CompressedVolume.stats`` counters
# (tiles_decoded / tiles_total / cache_hits), which are per-volume and not
# clobbered by concurrent decodes of other artifacts.  Written under
# _STATS_LOCK; :func:`decode_lanes` also *returns* the lane count, which is
# the race-free way to consume it.
DECODE_STATS = {"tiles_decoded": 0, "tiles_total": 0}
_STATS_LOCK = threading.Lock()


def _mirror_stats(tiles_decoded: int, tiles_total: int) -> None:
    with _STATS_LOCK:
        DECODE_STATS["tiles_decoded"] = tiles_decoded
        DECODE_STATS["tiles_total"] = tiles_total


# ---------------------------------------------------------------------------
# bucketed dispatch + compile-cache accounting
# ---------------------------------------------------------------------------
#
# Every distinct decode batch size K compiles a fresh XLA executable for the
# float decode programs (interp decode chunks, the GWLZ enhancer's lax.map) —
# under a serving workload with arbitrary ROI lane counts that is an unbounded
# program cache and recompiles on the hot path.  Bucketing pads each batch to
# a small fixed set of widths (powers of two up to DEFAULT_BUCKET_CAP), so a
# bounded set of compiled programs serves every request after warmup.
#
# Padding is bit-safe by the same invariant that makes region == full decode
# exact: no per-tile program mixes tiles (vmap / lax.map over axis 0), so the
# padded rows cannot perturb the real rows — the pad rows are simply cropped
# off the output.  Pad rows repeat row 0, the established idiom from
# predictor._interp_decode_tiles_padded.
#
# DISPATCH_STATS / _PROGRAM_KEYS are process-wide observability for the
# serving layer's /metrics and the load test's "zero recompiles after warmup"
# assertion: a *program* is a distinct (semantic key, bucket width) pair seen
# for the first time; a *dispatch* is one device invocation of such a program.

DEFAULT_BUCKET_CAP = int(os.environ.get("REPRO_DECODE_BUCKET_CAP", 32))

_DISPATCH_LOCK = threading.Lock()
_PROGRAM_KEYS: set = set()
DISPATCH_STATS = {"dispatches": 0, "programs": 0, "padded_tiles": 0,
                  "batch_hist": {}}


def bucket_for(n: int, bucket_cap: int | None = None) -> int:
    """Smallest power-of-two bucket >= n, capped at ``bucket_cap``."""
    cap = DEFAULT_BUCKET_CAP if bucket_cap is None else int(bucket_cap)
    if n <= 0:
        return 0
    b = 1
    while b < n and b < cap:
        b <<= 1
    return min(b, cap)


def bucket_chunks(n: int, bucket_cap: int | None = None) -> list[int]:
    """Split a batch of ``n`` tiles into bucket widths: full-cap chunks plus
    one power-of-two tail bucket (e.g. n=70, cap=32 -> [32, 32, 8]).  A
    non-positive cap disables bucketing ([n] verbatim)."""
    cap = DEFAULT_BUCKET_CAP if bucket_cap is None else int(bucket_cap)
    if cap <= 0 or n <= 0:
        return [n] if n > 0 else []
    out = [cap] * (n // cap)
    rem = n % cap
    if rem:
        out.append(bucket_for(rem, cap))
    return out


def register_program_key(key) -> bool:
    """Record one compiled-program identity; True the first time (a compile),
    False on a warm hit.  The streaming executor registers its encode
    program here so StreamReport can report compile counts the same way."""
    with _DISPATCH_LOCK:
        fresh = key not in _PROGRAM_KEYS
        if fresh:
            _PROGRAM_KEYS.add(key)
            DISPATCH_STATS["programs"] += 1
        return fresh


def _record_dispatch(key, bucket: int, padded: int) -> None:
    with _DISPATCH_LOCK:
        if key not in _PROGRAM_KEYS:
            _PROGRAM_KEYS.add(key)
            DISPATCH_STATS["programs"] += 1
        DISPATCH_STATS["dispatches"] += 1
        DISPATCH_STATS["padded_tiles"] += padded
        hist = DISPATCH_STATS["batch_hist"]
        hist[bucket] = hist.get(bucket, 0) + 1


def dispatch_stats() -> dict:
    """Snapshot of the process-wide dispatch/compile counters."""
    with _DISPATCH_LOCK:
        out = dict(DISPATCH_STATS)
        out["batch_hist"] = dict(DISPATCH_STATS["batch_hist"])
        return out


def reset_dispatch_stats() -> None:
    """Test/bench hook: zero the counters AND forget seen program keys."""
    with _DISPATCH_LOCK:
        _PROGRAM_KEYS.clear()
        DISPATCH_STATS.update(dispatches=0, programs=0, padded_tiles=0,
                              batch_hist={})


def dispatch_bucketed(fn, tree, n: int, *, key=(), bucket_cap=None):
    """Run ``fn`` (a per-tile batched program) over a [n, ...] pytree through
    bucket-padded fixed-shape invocations.

    ``key`` names the program semantics (predictor, tile, levels, ...); the
    bucket width is appended so each (key, width) pair is one compiled
    executable.  Pad rows repeat row 0 and are cropped from the output —
    bit-safe because no per-tile program mixes batch rows.  ``bucket_cap=0``
    disables bucketing (single unpadded call, still counted)."""
    cap = DEFAULT_BUCKET_CAP if bucket_cap is None else int(bucket_cap)
    if cap <= 0 or n <= 0:
        if n > 0:
            _record_dispatch(tuple(key) + (int(n),), int(n), 0)
        return fn(tree)
    outs = []
    off = 0
    for width in bucket_chunks(n, cap):
        take = min(width, n - off)
        part = jax.tree.map(lambda a: a[off:off + take], tree)
        pad = width - take
        if pad:
            part = jax.tree.map(
                lambda a: jnp.concatenate([a, jnp.repeat(a[:1], pad, axis=0)]),
                part)
        _record_dispatch(tuple(key) + (width,), width, pad)
        outs.append(fn(part)[:take])
        off += take
    return outs[0] if len(outs) == 1 else jnp.concatenate(outs)


# ---------------------------------------------------------------------------
# tile grid geometry
# ---------------------------------------------------------------------------


def normalize_tile(tile, ndim: int) -> tuple[int, ...]:
    if isinstance(tile, int):
        tile = (tile,) * ndim
    tile = tuple(int(t) for t in tile)
    if len(tile) != ndim or any(t < 1 for t in tile):
        raise ValueError(f"tile {tile} invalid for a {ndim}-d volume")
    return tile


def tile_grid(shape: tuple[int, ...], tile: tuple[int, ...]) -> tuple[int, ...]:
    return tuple(-(-d // t) for d, t in zip(shape, tile))


def pad_to_tiles(x: jax.Array, tile: tuple[int, ...]) -> jax.Array:
    pshape = tuple(g * t for g, t in zip(tile_grid(x.shape, tile), tile))
    pads = [(0, p - d) for d, p in zip(x.shape, pshape)]
    return jnp.pad(x, pads, mode="edge")


def split_tiles(xp: jax.Array, tile: tuple[int, ...]) -> jax.Array:
    """[g0*t0, g1*t1, ...] -> [prod(g), t0, t1, ...] in row-major grid order."""
    grid = tuple(d // t for d, t in zip(xp.shape, tile))
    nd = len(tile)
    interleaved = xp.reshape(sum(((g, t) for g, t in zip(grid, tile)), ()))
    perm = tuple(range(0, 2 * nd, 2)) + tuple(range(1, 2 * nd, 2))
    return interleaved.transpose(perm).reshape((-1,) + tile)


def stitch_tiles(tiles: jax.Array, grid: tuple[int, ...]) -> jax.Array:
    """Inverse of :func:`split_tiles`: [prod(g), *tile] -> padded volume."""
    tile = tiles.shape[1:]
    nd = len(tile)
    blocks = tiles.reshape(grid + tile)
    perm = sum(((d, nd + d) for d in range(nd)), ())
    return blocks.transpose(perm).reshape(tuple(g * t for g, t in zip(grid, tile)))


# ---------------------------------------------------------------------------
# container
# ---------------------------------------------------------------------------


@dataclass
class TiledCompressed:
    """Self-describing tiled artifact (``GWTC`` v2, docs/TILED_FORMAT.md).

    ``tile_blobs[i]`` is an independent, self-describing lane for tile ``i``
    in row-major grid order (predictor-specific layout; for ``lorenzo`` a
    bare ``RPRE`` entropy blob, for ``interp`` outliers + ``RPRE`` codes).
    ``predictor``/``order``/``levels`` record the per-tile transform; v1
    blobs (always Lorenzo) still parse."""

    shape: tuple[int, ...]
    tile: tuple[int, ...]
    eb_abs: float
    backend: str
    tile_blobs: list[bytes]
    predictor: str = "lorenzo"
    order: str = "cubic"
    levels: int = 0
    extras: dict = field(default_factory=dict)
    # per-lane CRC32 from the container's footer index (None when the blob
    # predates checksums or the artifact was built in memory — verification
    # is then skipped), plus the runtime verification policy the opener
    # chose: ``verify`` in {"none","lazy","full"} and ``on_corrupt`` in
    # {"raise","quarantine"} (docs/ROBUSTNESS.md).  None of these affect
    # artifact identity, so they are excluded from equality.
    lane_crcs: np.ndarray | None = field(default=None, repr=False, compare=False)
    verify: str = field(default="lazy", repr=False, compare=False)
    on_corrupt: str = field(default="raise", repr=False, compare=False)
    fill_value: float = field(default=0.0, repr=False, compare=False)
    # lanes that already passed / failed their CRC — verification runs at
    # most once per lane under the lazy policy
    _verified: set = field(default_factory=set, init=False, repr=False, compare=False)
    quarantined: set = field(default_factory=set, init=False, repr=False, compare=False)
    # serialization cache keyed on the extras fingerprint (same scheme as
    # SZCompressed): GWLZ.compress_tiled asks for nbytes before and after
    # attaching the model, and size_report() asks again
    _blob_cache: tuple | None = field(default=None, init=False, repr=False, compare=False)

    @property
    def grid(self) -> tuple[int, ...]:
        return tile_grid(self.shape, self.tile)

    @property
    def padded_shape(self) -> tuple[int, ...]:
        return tuple(g * t for g, t in zip(self.grid, self.tile))

    @property
    def n_tiles(self) -> int:
        return int(np.prod(self.grid))

    @property
    def nbytes(self) -> int:
        """Serialized (v3) size, computed in O(index) from the lane index —
        never by materializing the container, so ``repr``/``size_report`` on
        an mmap-opened volume stay lazy."""
        return (_HDR_V3.size + 16 * len(self.shape)
                + lanes_nbytes(self.tile_blobs)
                + len(_pack_extras(self.extras))
                + _index_nbytes(len(self.tile_blobs)) + _FOOTER_V3.size)

    def size_report(self) -> dict:
        lanes = lanes_nbytes(self.tile_blobs)
        extras = len(_pack_extras(self.extras))
        index = _index_nbytes(len(self.tile_blobs)) + _FOOTER_V3.size
        header = _HDR_V3.size + 16 * len(self.shape)
        return {"lanes": lanes, "index": index, "extras": extras,
                "header": header, "total": header + lanes + extras + index}

    def to_bytes(self) -> bytes:
        key = tuple(sorted(self.extras.items()))
        if self._blob_cache is not None and self._blob_cache[0] == key:
            return self._blob_cache[1]
        blob = self._serialize()
        self._blob_cache = (key, blob)
        return blob

    def _serialize(self) -> bytes:
        """Eager v3 serialization — routed through the same incremental
        writer the streaming executor uses, so eager ``to_bytes`` and a
        finalized stream emit byte-identical containers."""
        import io

        from repro.exec.writer import GWTCWriter

        buf = io.BytesIO()
        w = GWTCWriter(buf, shape=self.shape, tile=self.tile, eb_abs=self.eb_abs,
                       backend=self.backend, predictor=self.predictor,
                       order=self.order, levels=self.levels)
        for lane in self.tile_blobs:
            w.append_lane(lane)
        w.extras.update(self.extras)
        w.finalize()
        return buf.getvalue()

    @staticmethod
    def from_bytes(blob) -> "TiledCompressed":
        """Rebuild from a container blob (``bytes`` or any buffer, e.g. a
        ``memoryview`` over an mmap).  Buffer inputs parse *lazily*: lanes
        stay in the backing buffer behind a :class:`LaneStore` and are only
        copied out when a decode touches them — the mmap-backed open path.

        Every structural failure raises :class:`CorruptContainerError` with
        the byte offset of the failed check; lane payloads are *not* read
        here — their CRCs (when the container carries them) are checked by
        :func:`decode_lanes` under the artifact's ``verify`` policy."""
        try:
            magic, ver = struct.unpack_from("<4sB", blob, 0)
        except struct.error as e:
            raise CorruptContainerError(
                f"truncated GWTC blob: {e}", offset=0) from e
        if magic != _MAGIC:
            raise CorruptContainerError(
                "bad GWTC magic", offset=0, expected=_MAGIC, actual=bytes(magic))
        try:
            if ver == 1:
                # v1 predates the predictor layer: lanes are always Lorenzo.
                _m, _v, nd, backend, _pad, ebbits, n_tiles = \
                    _HDR_V1.unpack_from(blob, 0)
                pred, order, levels = PRED_IDS["lorenzo"], ORDER_IDS["cubic"], 0
                off = _HDR_V1.size
            elif ver in (2, 3):
                (_m, _v, nd, backend, pred, order, levels, _pad, ebbits,
                 n_tiles) = _HDR_V2.unpack_from(blob, 0)
                off = _HDR_V2.size
            else:
                raise CorruptContainerError(
                    "unsupported GWTC version", offset=4,
                    expected="1..3", actual=int(ver))
            if not 1 <= nd <= 16:
                raise CorruptContainerError(
                    "implausible GWTC rank", offset=5, expected="1..16",
                    actual=int(nd))
            if backend not in _BACKENDS_INV:
                raise CorruptContainerError(
                    "unknown GWTC entropy backend id", offset=6,
                    expected=sorted(_BACKENDS_INV), actual=int(backend))
            if pred not in PRED_NAMES or order not in ORDER_NAMES:
                raise CorruptContainerError(
                    "unknown GWTC predictor/order id", offset=7,
                    actual=(int(pred), int(order)))
            shape = struct.unpack_from(f"<{nd}q", blob, off)
            off += 8 * nd
            tile = struct.unpack_from(f"<{nd}q", blob, off)
            off += 8 * nd
        except struct.error as e:
            raise CorruptContainerError(
                f"truncated GWTC header: {e}", offset=0) from e
        if any(d < 1 for d in shape) or any(t < 1 for t in tile):
            raise CorruptContainerError(
                "non-positive GWTC shape/tile dims", offset=_HDR_V3.size,
                actual=(tuple(map(int, shape)), tuple(map(int, tile))))
        want_tiles = int(np.prod(tile_grid(tuple(shape), tuple(tile))))
        if n_tiles != want_tiles:
            raise CorruptContainerError(
                "GWTC tile count disagrees with the shape/tile grid",
                offset=off - 16 * nd, expected=want_tiles, actual=int(n_tiles))
        lane_crcs = None
        if ver in (1, 2):
            # index-first layout: lane lengths precede the lane bytes
            if off + 8 * n_tiles > len(blob):
                raise CorruptContainerError(
                    "truncated GWTC index", offset=off,
                    expected=f">= {off + 8 * n_tiles} bytes", actual=len(blob))
            lens = np.frombuffer(blob, np.uint64, n_tiles, offset=off).astype(np.int64)
            # exact-int sum: garbage u64 lens must not wrap int64 past the
            # extent check and overflow the lane slicing below
            lens_sum = sum(map(int, np.frombuffer(
                blob, np.uint64, n_tiles, offset=off)))
            off += 8 * n_tiles
            lanes_start = off
            extras_off = lanes_start + lens_sum
            if (lens < 0).any() or extras_off + 4 > len(blob):
                raise CorruptContainerError(
                    "GWTC lane extent overruns the blob", offset=lanes_start,
                    expected=f"extras at byte {extras_off}", actual=len(blob))
        else:
            # v3 footer layout: lanes start right after the dims; the footer
            # locates the extras blob and the trailing index region, whose
            # byte extent tells us whether per-lane CRCs are present
            lanes_start = off
            if len(blob) < lanes_start + _FOOTER_V3.size:
                raise CorruptContainerError(
                    "truncated GWTC v3 blob (no footer)",
                    offset=max(0, len(blob) - _FOOTER_V3.size),
                    expected=f">= {lanes_start + _FOOTER_V3.size} bytes",
                    actual=len(blob))
            footer_off = len(blob) - _FOOTER_V3.size
            extras_off, index_off = _FOOTER_V3.unpack_from(blob, footer_off)
            if not lanes_start <= extras_off <= index_off <= footer_off:
                raise CorruptContainerError(
                    "corrupt GWTC v3 footer (offsets out of range)",
                    offset=footer_off,
                    actual=(int(extras_off), int(index_off)))
            region = footer_off - index_off
            if region == _index_nbytes(n_tiles):
                has_crcs = True
            elif region == 8 * n_tiles:
                has_crcs = False  # pre-checksum v3 container
            else:
                raise CorruptContainerError(
                    "GWTC v3 index region has an impossible extent",
                    offset=index_off,
                    expected=(_index_nbytes(n_tiles), 8 * n_tiles),
                    actual=int(region))
            lens = np.frombuffer(blob, np.uint64, n_tiles,
                                 offset=index_off).astype(np.int64)
            # exact-int sum: a damaged u64 len must not wrap int64 into a
            # coincidentally matching total
            lens_sum = sum(map(int, np.frombuffer(
                blob, np.uint64, n_tiles, offset=index_off)))
            if (lens < 0).any() or lanes_start + lens_sum != extras_off:
                raise CorruptContainerError(
                    "corrupt GWTC v3 blob (index / lane extent mismatch)",
                    offset=index_off,
                    expected=int(extras_off) - lanes_start,
                    actual=lens_sum)
            if has_crcs:
                lane_crcs = np.frombuffer(
                    blob, np.uint32, n_tiles, offset=index_off + 8 * n_tiles).copy()
                (meta_crc,) = struct.unpack_from(
                    "<I", blob, index_off + 12 * n_tiles)
                got = zlib.crc32(bytes(blob[extras_off:index_off]),
                                 zlib.crc32(bytes(blob[:lanes_start]))) & 0xFFFFFFFF
                if got != meta_crc:
                    raise CorruptContainerError(
                        "GWTC metadata checksum mismatch (header/shape/extras "
                        "bytes are damaged)", offset=index_off + 12 * n_tiles,
                        expected=f"0x{meta_crc:08x}", actual=f"0x{got:08x}")
        offs = lanes_start + np.concatenate([[0], np.cumsum(lens[:-1])]) \
            if n_tiles else np.zeros(0, np.int64)
        if isinstance(blob, (bytes, bytearray)):
            tile_blobs: "list[bytes] | LaneStore" = [
                bytes(blob[o : o + ln]) for o, ln in zip(offs, lens)]
        else:
            tile_blobs = LaneStore(blob, offs, lens)
        try:
            extras = _unpack_extras(blob, extras_off)
        except struct.error as e:
            raise CorruptContainerError(
                f"truncated GWTC extras blob: {e}", offset=int(extras_off)) from e
        return TiledCompressed(
            shape=tuple(shape), tile=tuple(tile),
            eb_abs=float(np.uint64(ebbits).view(np.float64)),
            backend=_BACKENDS_INV[backend], tile_blobs=tile_blobs,
            predictor=PRED_NAMES[pred], order=ORDER_NAMES[order],
            levels=int(levels), extras=extras, lane_crcs=lane_crcs,
        )


A.register_container(_MAGIC, TiledCompressed)


# ---------------------------------------------------------------------------
# lane dispatch (shared, size-capped executor)
# ---------------------------------------------------------------------------

_POOL_SIZE = max(1, min(os.cpu_count() or 1, 8))
_LANE_POOL: ThreadPoolExecutor | None = None
_LANE_POOL_LOCK = threading.Lock()


def _lane_pool() -> ThreadPoolExecutor:
    """One shared, size-capped executor for every encode/decode call — lane
    work is short and bursty, so per-call pool construction was pure churn."""
    global _LANE_POOL
    if _LANE_POOL is None:
        with _LANE_POOL_LOCK:
            if _LANE_POOL is None:
                _LANE_POOL = ThreadPoolExecutor(
                    _POOL_SIZE, thread_name_prefix="gwtc-lane")
    return _LANE_POOL


def _lane_workers(n_lanes: int, workers: int | None) -> int:
    if workers is not None:
        return max(1, min(workers, n_lanes))
    cores = os.cpu_count() or 1
    return max(1, min(cores, 8, n_lanes)) if cores > 2 else 1


def _map_lanes(fn, items, workers: int | None):
    """Run ``fn`` over lanes with at most ``workers`` concurrent lanes.

    The per-call concurrency cap is enforced by splitting the lane list into
    that many contiguous runs, each submitted as one serial task to the
    shared pool — order is preserved and no call ever spawns its own pool."""
    w = _lane_workers(len(items), workers)
    if w <= 1:
        return [fn(it) for it in items]
    bounds = np.linspace(0, len(items), w + 1).astype(int)
    chunks = [items[a:b] for a, b in zip(bounds[:-1], bounds[1:]) if b > a]
    futs = [_lane_pool().submit(lambda ch: [fn(it) for it in ch], ch)
            for ch in chunks]
    return [out for f in futs for out in f.result()]


# ---------------------------------------------------------------------------
# engine API
# ---------------------------------------------------------------------------


def compress_tiled(
    x: jax.Array,
    tile=(64, 64, 64),
    *,
    rel_eb: float | None = None,
    abs_eb: float | None = None,
    backend: str = "huffman+zlib",
    predictor: str = "lorenzo",
    order: str = "cubic",
    max_levels: int = 5,
    use_pallas: bool | None = None,
    workers: int | None = None,
) -> tuple[TiledCompressed, jax.Array]:
    """Tile-grid compress; returns (artifact, reconstruction).

    ``predictor`` selects the per-tile transform from the registry
    (``"lorenzo"`` or ``"interp"``; ``order``/``max_levels`` apply to interp
    only).  The reconstruction is the decode program's own output, cropped to
    ``x.shape`` — exactly what :func:`decompress_tiled` will produce."""
    if backend not in _BACKENDS:
        raise ValueError(f"unknown entropy backend {backend!r}")
    pred = get_predictor(predictor)
    x = jnp.asarray(x, jnp.float32)
    tile = normalize_tile(tile, x.ndim)
    eb = resolve_eb(x, rel_eb, abs_eb)
    levels = pred.plan(tile, max_levels)
    xp = pad_to_tiles(x, tile)
    tiles = split_tiles(xp, tile)
    payload, recon_tiles = pred.encode_tiles(
        tiles, eb, order=order, levels=levels, use_pallas=use_pallas)
    recon = stitch_tiles(recon_tiles, tile_grid(x.shape, tile))

    payload_np = jax.tree.map(np.asarray, payload)
    blobs = _map_lanes(
        lambda i: pred.lane_bytes(payload_np, i, backend, use_pallas=use_pallas),
        list(range(tiles.shape[0])), workers)
    artifact = TiledCompressed(
        shape=tuple(x.shape), tile=tile, eb_abs=eb, backend=backend,
        tile_blobs=blobs, predictor=predictor, order=order, levels=levels)
    return artifact, recon[tuple(slice(0, d) for d in x.shape)]


def _check_lane(artifact: TiledCompressed, i: int, blob) -> bool:
    """Verify lane ``i`` against its footer CRC (at most once per lane).

    Returns True when the lane is usable.  On mismatch: raises
    :class:`CorruptLaneError` under ``on_corrupt="raise"``, or records the
    lane in ``artifact.quarantined`` and returns False under
    ``on_corrupt="quarantine"``.  No-op (True) when the container carries no
    checksums or the policy is ``verify="none"``."""
    if i in artifact.quarantined:
        return False
    if (artifact.lane_crcs is None or artifact.verify == "none"
            or i in artifact._verified):
        return True
    expected = int(artifact.lane_crcs[i])
    actual = lane_crc(blob)
    if actual == expected:
        artifact._verified.add(i)
        return True
    if artifact.on_corrupt == "quarantine":
        artifact.quarantined.add(i)
        return False
    raise CorruptLaneError(i, lane_offset=lane_offset(artifact, i),
                           expected_crc=expected, actual_crc=actual)


def verify_lanes(artifact: TiledCompressed, lane_ids=None, *,
                 workers: int | None = None) -> list[int]:
    """Checksum the given lanes (all, by default) without decoding them —
    the ``verify="full"`` open policy.  Returns the quarantined lane ids
    (always empty under ``on_corrupt="raise"``, which raises instead);
    returns ``[]`` immediately when the container carries no checksums."""
    if artifact.lane_crcs is None or artifact.verify == "none":
        return []
    ids = list(range(artifact.n_tiles)) if lane_ids is None else list(lane_ids)
    _map_lanes(lambda i: _check_lane(artifact, i, artifact.tile_blobs[i]),
               ids, workers)
    return sorted(artifact.quarantined)


def decode_lanes(
    artifact: TiledCompressed, lane_ids, *, workers: int | None = None,
    with_mask: bool = False, use_pallas: bool | None = None,
    bucket_cap: int | None = None,
):
    """Decode the given lanes and reconstruct them; returns
    ``(recon [len(ids), *tile], lanes_decoded)`` — or, with
    ``with_mask=True``, ``(recon, lanes_decoded, bad_mask)`` where
    ``bad_mask[j]`` marks quarantined positions (filled with the artifact's
    ``fill_value``), so callers applying a tile transform can re-assert the
    fill afterwards.

    Only the named lanes are touched — this is the random-access primitive
    both :func:`decompress_tiled` and :func:`decompress_region` build on.
    When the container carries per-lane CRCs and the artifact's ``verify``
    policy is not ``"none"``, each lane is checksummed before its first
    decode; a mismatch raises :class:`CorruptLaneError` or — under
    ``on_corrupt="quarantine"`` — degrades that tile to ``fill_value``.
    The returned lane count is the race-free observability channel (the
    module-level ``DECODE_STATS`` mirror is best-effort, for convenience)."""
    pred = get_predictor(artifact.predictor)
    lane_ids = list(lane_ids)
    blobs = [artifact.tile_blobs[i] for i in lane_ids]
    good = [j for j, (i, b) in enumerate(zip(lane_ids, blobs))
            if _check_lane(artifact, i, b)]
    items = _map_lanes(
        lambda b: pred.parse_lane(b, tile=artifact.tile, levels=artifact.levels,
                                  use_pallas=use_pallas),
        [blobs[j] for j in good], workers)
    with _STATS_LOCK:
        DECODE_STATS["tiles_decoded"] = len(good)
        DECODE_STATS["tiles_total"] = artifact.n_tiles
    if good:
        payload = {k: jnp.asarray(np.stack([it[k] for it in items]))
                   for k in items[0]}
        key = pred.decode_program_key(tile=artifact.tile, order=artifact.order,
                                      levels=artifact.levels)
        recon = dispatch_bucketed(
            lambda p: pred.decode_tiles(
                p, artifact.eb_abs, tile=artifact.tile,
                order=artifact.order, levels=artifact.levels),
            payload, len(good), key=key, bucket_cap=bucket_cap)
    bad_mask = np.zeros(len(lane_ids), bool)
    if len(good) < len(lane_ids):
        good_set = set(good)
        bad_mask[[j for j in range(len(lane_ids)) if j not in good_set]] = True
        full = jnp.full((len(lane_ids),) + tuple(artifact.tile),
                        artifact.fill_value, jnp.float32)
        recon = full.at[jnp.asarray(good, jnp.int32)].set(recon) if good else full
    if with_mask:
        return recon, len(good), bad_mask
    return recon, len(good)


def apply_tile_transform(tile_transform, recon, *, bucket_cap=None):
    """Run a per-tile transform over a [K, *tile] batch, bucketed when the
    transform declares a ``program_key`` attribute naming its compiled
    program's identity (the GWLZ enhancer does).  Unkeyed transforms (ad-hoc
    callables) run in one unbucketed call — there is nothing safe to cache
    them under, and inflating the program counters with anonymous callables
    would poison the zero-recompile assertion."""
    key = getattr(tile_transform, "program_key", None)
    if key is None:
        return tile_transform(recon)
    return dispatch_bucketed(tile_transform, recon, int(recon.shape[0]),
                             key=tuple(key), bucket_cap=bucket_cap)


def decompress_tiled(
    artifact: TiledCompressed, *, workers: int | None = None, tile_transform=None,
    use_pallas: bool | None = None, bucket_cap: int | None = None,
) -> jax.Array:
    """Full decode: every lane, stitched and cropped to the original shape.

    ``tile_transform([K, *tile]) -> [K, *tile]`` post-processes decoded tiles
    before stitching (the GWLZ pipeline enhances per tile through it; it must
    act per-tile so region and full decode stay consistent)."""
    recon, _, bad = decode_lanes(artifact, range(artifact.n_tiles),
                                 workers=workers, with_mask=True,
                                 use_pallas=use_pallas, bucket_cap=bucket_cap)
    if tile_transform is not None:
        recon = apply_tile_transform(tile_transform, recon,
                                     bucket_cap=bucket_cap)
        recon = _refill_quarantined(recon, bad, artifact.fill_value)
    out = stitch_tiles(recon, artifact.grid)
    return out[tuple(slice(0, d) for d in artifact.shape)]


def _refill_quarantined(recon, bad_mask: np.ndarray, fill_value: float):
    """Re-assert the fill value on quarantined tile positions *after* a tile
    transform ran — an enhancer must not resurrect data for a tile whose
    lane failed its checksum."""
    if bad_mask.any():
        recon = recon.at[jnp.asarray(np.nonzero(bad_mask)[0], jnp.int32)].set(
            jnp.float32(fill_value))
    return recon


def normalize_roi(roi, shape: tuple[int, ...]) -> tuple[tuple[int, int], ...]:
    """ROI as slices or (start, stop) pairs -> clamped (start, stop) tuples."""
    if len(roi) != len(shape):
        raise ValueError(f"roi rank {len(roi)} != volume rank {len(shape)}")
    out = []
    for r, d in zip(roi, shape):
        if isinstance(r, slice):
            if r.step not in (None, 1):
                raise ValueError("roi slices must have step 1")
            start, stop, _ = r.indices(d)
        else:
            start, stop = r
            start = start + d if start < 0 else start
            stop = stop + d if stop < 0 else stop
            start, stop = max(0, min(start, d)), max(0, min(stop, d))
        if stop <= start:
            raise ValueError(f"empty roi extent {r} on a dim of size {d}")
        out.append((int(start), int(stop)))
    return tuple(out)


def region_tiles(artifact: TiledCompressed, roi) -> tuple[np.ndarray, tuple]:
    """(flat lane ids of tiles intersecting ``roi``, per-dim tile ranges)."""
    bounds = normalize_roi(roi, artifact.shape)
    ranges = tuple((lo // t, -(-hi // t))
                   for (lo, hi), t in zip(bounds, artifact.tile))
    axes = [np.arange(a, b) for a, b in ranges]
    coords = np.meshgrid(*axes, indexing="ij")
    ids = np.ravel_multi_index([c.ravel() for c in coords], artifact.grid)
    return ids, (bounds, ranges)


def assemble_region(recon, geom, tile: tuple[int, ...]):
    """Stitch + crop decoded region tiles: the pure-geometry back half of
    :func:`decompress_region`, shared with the façade's cached read path
    (``recon`` may be a jax array or a numpy stack of cached tiles —
    stitching is reshape/transpose either way)."""
    bounds, ranges = geom
    sub_grid = tuple(b - a for a, b in ranges)
    block = stitch_tiles(recon, sub_grid)
    crop = tuple(slice(lo - a * t, hi - a * t)
                 for (lo, hi), (a, _b), t in zip(bounds, ranges, tile))
    return block[crop]


def decompress_region(
    artifact: TiledCompressed, roi, *, workers: int | None = None,
    tile_transform=None, use_pallas: bool | None = None,
    bucket_cap: int | None = None,
) -> jax.Array:
    """Decode only the tiles intersecting ``roi``; returns the ROI's values.

    Bit-identical to ``decompress_tiled(artifact)[roi]`` — the per-tile
    transform is elementwise-exact, so the subset batch reconstructs the
    same values the full batch would (any ``tile_transform`` must preserve
    this by acting on each tile independently; bucket padding preserves it
    too, since pad rows are repeats of row 0 cropped from the output)."""
    ids, geom = region_tiles(artifact, roi)
    recon, _, bad = decode_lanes(artifact, ids.tolist(), workers=workers,
                                 with_mask=True, use_pallas=use_pallas,
                                 bucket_cap=bucket_cap)
    if tile_transform is not None:
        recon = apply_tile_transform(tile_transform, recon,
                                     bucket_cap=bucket_cap)
        recon = _refill_quarantined(recon, bad, artifact.fill_value)
    return assemble_region(recon, geom, artifact.tile)
