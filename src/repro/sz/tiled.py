"""Tile-based compression engine with random-access decode (``GWTC``).

The monolithic SZ path materializes one volume end to end; this engine splits
the (padded) volume into a fixed tile grid and makes every tile a fully
independent compression domain:

* the per-tile prediction transform is *pluggable*: the tile batch dispatches
  through the predictor registry (``repro.sz.predictor.get_predictor``) —
  ``"lorenzo"`` (prequant + batched integer Lorenzo) or ``"interp"`` (SZ3-
  style multi-level interpolation, vmapped per tile).  Batched passes fan
  across the device mesh via ``repro.launch.sharding.map_tiles``,
* each tile entropy-encodes as an independent lane on the chunked ``hc``/
  ``hZ`` codec (docs/ENTROPY_FORMAT.md), so lanes decode independently and
  in parallel,
* the ``GWTC`` container stores a per-tile offset index, so
  :func:`decompress_region` entropy-decodes *only* the tiles intersecting
  the requested ROI — partial reads never pay for the whole blob.

Every predictor's batched decode is elementwise-exact in the batch axis
(each tile is an independent prediction domain), so region decode is
bit-identical to the full decode's crop whichever predictor produced the
artifact.  Container layout (``GWTC`` v2; v1 blobs still decode) is
specified in docs/TILED_FORMAT.md; the layered stack is described in
docs/ARCHITECTURE.md.
"""
from __future__ import annotations

import os
import struct
import threading
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.sz import artifact as A
from repro.sz.predictor import ORDER_IDS, ORDER_NAMES, PRED_IDS, PRED_NAMES, get_predictor
from repro.sz.quantizer import resolve_eb

_MAGIC = b"GWTC"
_VERSION = 3
# v1: magic, version, ndim, backend, pad, eb bits, n_tiles
_HDR_V1 = struct.Struct("<4sBBBBQQ")
# v2 adds the predictor layer: magic, version, ndim, backend, predictor,
# order, levels, pad, eb bits, n_tiles
_HDR_V2 = struct.Struct("<4sBBBBBBBQQ")
# v3 keeps the v2 header fields but moves the tile index (and extras) BEHIND
# the lanes so the container can be written append-only by a streaming
# encoder; a fixed-size footer at the end of the blob locates them
# (docs/STREAMING.md).  Layout: header | shape | tile | lanes... | extras |
# index u64[n_tiles] | footer.
_HDR_V3 = _HDR_V2
_FOOTER_V3 = struct.Struct("<QQ")  # (extras offset, index offset)
_BACKENDS = {"zlib": 0, "huffman": 1, "huffman+zlib": 2}
_BACKENDS_INV = {v: k for k, v in _BACKENDS.items()}


def _pack_extras(extras: dict) -> bytes:
    """Extras blob shared by the eager serializer and the streaming writer:
    count u32, then per entry klen u32 | vlen u32 | key | value, sorted."""
    items = sorted(extras.items())
    out = [struct.pack("<I", len(items))]
    for k, v in items:
        kb = k.encode()
        out.append(struct.pack("<II", len(kb), len(v)) + kb + bytes(v))
    return b"".join(out)


def _unpack_extras(blob, off: int) -> dict:
    (n_extras,) = struct.unpack_from("<I", blob, off)
    off += 4
    extras = {}
    for _ in range(n_extras):
        klen, vlen = struct.unpack_from("<II", blob, off)
        off += 8
        k = bytes(blob[off : off + klen]).decode()
        off += klen
        extras[k] = bytes(blob[off : off + vlen])
        off += vlen
    return extras


class LaneStore:
    """Lazy per-lane byte access over one backing buffer.

    Holds (buffer, per-lane offsets/lengths) instead of materialized lane
    copies, so opening an mmap-backed container reads *no* lane bytes until
    a decode asks for them — ``store[i]`` copies exactly lane ``i`` out of
    the buffer (a page-granular read on mmap).  ``release()`` drops the
    buffer reference so the owning mmap can close."""

    __slots__ = ("_buf", "_offs", "_lens")

    def __init__(self, buf, offsets: np.ndarray, lengths: np.ndarray):
        self._buf = buf
        self._offs = np.asarray(offsets, np.int64)
        self._lens = np.asarray(lengths, np.int64)

    def __len__(self) -> int:
        return int(self._lens.size)

    def __getitem__(self, i: int) -> bytes:
        if self._buf is None:
            raise ValueError("lane store is closed (volume was released)")
        o, n = int(self._offs[i]), int(self._lens[i])
        return bytes(self._buf[o : o + n])

    def __iter__(self):
        return (self[i] for i in range(len(self)))

    @property
    def nbytes(self) -> int:
        """Total lane bytes — computed from the index, no lane is read."""
        return int(self._lens.sum())

    def lane_nbytes(self, i: int) -> int:
        return int(self._lens[i])

    def release(self) -> None:
        self._buf = None


def lanes_nbytes(tile_blobs) -> int:
    """Total lane payload bytes without forcing lazy lanes into memory."""
    if isinstance(tile_blobs, LaneStore):
        return tile_blobs.nbytes
    return sum(len(b) for b in tile_blobs)

# DEPRECATED module-global mirror: how many lanes the last decode touched.
# Kept as a best-effort alias for existing tests/benchmarks — new code should
# read the per-handle ``repro.api.CompressedVolume.stats`` counters
# (tiles_decoded / tiles_total / cache_hits), which are per-volume and not
# clobbered by concurrent decodes of other artifacts.  Written under
# _STATS_LOCK; :func:`decode_lanes` also *returns* the lane count, which is
# the race-free way to consume it.
DECODE_STATS = {"tiles_decoded": 0, "tiles_total": 0}
_STATS_LOCK = threading.Lock()


def _mirror_stats(tiles_decoded: int, tiles_total: int) -> None:
    with _STATS_LOCK:
        DECODE_STATS["tiles_decoded"] = tiles_decoded
        DECODE_STATS["tiles_total"] = tiles_total


# ---------------------------------------------------------------------------
# tile grid geometry
# ---------------------------------------------------------------------------


def normalize_tile(tile, ndim: int) -> tuple[int, ...]:
    if isinstance(tile, int):
        tile = (tile,) * ndim
    tile = tuple(int(t) for t in tile)
    if len(tile) != ndim or any(t < 1 for t in tile):
        raise ValueError(f"tile {tile} invalid for a {ndim}-d volume")
    return tile


def tile_grid(shape: tuple[int, ...], tile: tuple[int, ...]) -> tuple[int, ...]:
    return tuple(-(-d // t) for d, t in zip(shape, tile))


def pad_to_tiles(x: jax.Array, tile: tuple[int, ...]) -> jax.Array:
    pshape = tuple(g * t for g, t in zip(tile_grid(x.shape, tile), tile))
    pads = [(0, p - d) for d, p in zip(x.shape, pshape)]
    return jnp.pad(x, pads, mode="edge")


def split_tiles(xp: jax.Array, tile: tuple[int, ...]) -> jax.Array:
    """[g0*t0, g1*t1, ...] -> [prod(g), t0, t1, ...] in row-major grid order."""
    grid = tuple(d // t for d, t in zip(xp.shape, tile))
    nd = len(tile)
    interleaved = xp.reshape(sum(((g, t) for g, t in zip(grid, tile)), ()))
    perm = tuple(range(0, 2 * nd, 2)) + tuple(range(1, 2 * nd, 2))
    return interleaved.transpose(perm).reshape((-1,) + tile)


def stitch_tiles(tiles: jax.Array, grid: tuple[int, ...]) -> jax.Array:
    """Inverse of :func:`split_tiles`: [prod(g), *tile] -> padded volume."""
    tile = tiles.shape[1:]
    nd = len(tile)
    blocks = tiles.reshape(grid + tile)
    perm = sum(((d, nd + d) for d in range(nd)), ())
    return blocks.transpose(perm).reshape(tuple(g * t for g, t in zip(grid, tile)))


# ---------------------------------------------------------------------------
# container
# ---------------------------------------------------------------------------


@dataclass
class TiledCompressed:
    """Self-describing tiled artifact (``GWTC`` v2, docs/TILED_FORMAT.md).

    ``tile_blobs[i]`` is an independent, self-describing lane for tile ``i``
    in row-major grid order (predictor-specific layout; for ``lorenzo`` a
    bare ``RPRE`` entropy blob, for ``interp`` outliers + ``RPRE`` codes).
    ``predictor``/``order``/``levels`` record the per-tile transform; v1
    blobs (always Lorenzo) still parse."""

    shape: tuple[int, ...]
    tile: tuple[int, ...]
    eb_abs: float
    backend: str
    tile_blobs: list[bytes]
    predictor: str = "lorenzo"
    order: str = "cubic"
    levels: int = 0
    extras: dict = field(default_factory=dict)
    # serialization cache keyed on the extras fingerprint (same scheme as
    # SZCompressed): GWLZ.compress_tiled asks for nbytes before and after
    # attaching the model, and size_report() asks again
    _blob_cache: tuple | None = field(default=None, init=False, repr=False, compare=False)

    @property
    def grid(self) -> tuple[int, ...]:
        return tile_grid(self.shape, self.tile)

    @property
    def padded_shape(self) -> tuple[int, ...]:
        return tuple(g * t for g, t in zip(self.grid, self.tile))

    @property
    def n_tiles(self) -> int:
        return int(np.prod(self.grid))

    @property
    def nbytes(self) -> int:
        """Serialized (v3) size, computed in O(index) from the lane index —
        never by materializing the container, so ``repr``/``size_report`` on
        an mmap-opened volume stay lazy."""
        return (_HDR_V3.size + 16 * len(self.shape)
                + lanes_nbytes(self.tile_blobs)
                + len(_pack_extras(self.extras))
                + 8 * len(self.tile_blobs) + _FOOTER_V3.size)

    def size_report(self) -> dict:
        lanes = lanes_nbytes(self.tile_blobs)
        extras = len(_pack_extras(self.extras))
        index = 8 * len(self.tile_blobs) + _FOOTER_V3.size
        header = _HDR_V3.size + 16 * len(self.shape)
        return {"lanes": lanes, "index": index, "extras": extras,
                "header": header, "total": header + lanes + extras + index}

    def to_bytes(self) -> bytes:
        key = tuple(sorted(self.extras.items()))
        if self._blob_cache is not None and self._blob_cache[0] == key:
            return self._blob_cache[1]
        blob = self._serialize()
        self._blob_cache = (key, blob)
        return blob

    def _serialize(self) -> bytes:
        """Eager v3 serialization — routed through the same incremental
        writer the streaming executor uses, so eager ``to_bytes`` and a
        finalized stream emit byte-identical containers."""
        import io

        from repro.exec.writer import GWTCWriter

        buf = io.BytesIO()
        w = GWTCWriter(buf, shape=self.shape, tile=self.tile, eb_abs=self.eb_abs,
                       backend=self.backend, predictor=self.predictor,
                       order=self.order, levels=self.levels)
        for lane in self.tile_blobs:
            w.append_lane(lane)
        w.extras.update(self.extras)
        w.finalize()
        return buf.getvalue()

    @staticmethod
    def from_bytes(blob) -> "TiledCompressed":
        """Rebuild from a container blob (``bytes`` or any buffer, e.g. a
        ``memoryview`` over an mmap).  Buffer inputs parse *lazily*: lanes
        stay in the backing buffer behind a :class:`LaneStore` and are only
        copied out when a decode touches them — the mmap-backed open path."""
        magic, ver = struct.unpack_from("<4sB", blob, 0)
        assert magic == _MAGIC, "bad GWTC blob"
        if ver == 1:
            # v1 predates the predictor layer: lanes are always Lorenzo codes.
            _m, _v, nd, backend, _pad, ebbits, n_tiles = _HDR_V1.unpack_from(blob, 0)
            pred, order, levels = PRED_IDS["lorenzo"], ORDER_IDS["cubic"], 0
            off = _HDR_V1.size
        elif ver in (2, 3):
            (_m, _v, nd, backend, pred, order, levels, _pad, ebbits,
             n_tiles) = _HDR_V2.unpack_from(blob, 0)
            off = _HDR_V2.size
        else:
            raise AssertionError(f"unsupported GWTC version {ver}")
        shape = struct.unpack_from(f"<{nd}q", blob, off)
        off += 8 * nd
        tile = struct.unpack_from(f"<{nd}q", blob, off)
        off += 8 * nd
        if ver in (1, 2):
            # index-first layout: lane lengths precede the lane bytes
            lens = np.frombuffer(blob, np.uint64, n_tiles, offset=off).astype(np.int64)
            off += 8 * n_tiles
            lanes_start = off
            extras_off = lanes_start + int(lens.sum())
        else:
            # v3 footer layout: lanes start right after the dims; the footer
            # locates the extras blob and the trailing index
            lanes_start = off
            if len(blob) < _FOOTER_V3.size:
                raise ValueError("truncated GWTC v3 blob (no footer)")
            extras_off, index_off = _FOOTER_V3.unpack_from(
                blob, len(blob) - _FOOTER_V3.size)
            if index_off + 8 * n_tiles > len(blob) or extras_off > index_off:
                raise ValueError("corrupt GWTC v3 footer (offsets out of range)")
            lens = np.frombuffer(blob, np.uint64, n_tiles, offset=index_off).astype(np.int64)
            if lanes_start + int(lens.sum()) != extras_off:
                raise ValueError("corrupt GWTC v3 blob (index / lane extent mismatch)")
        offs = lanes_start + np.concatenate([[0], np.cumsum(lens[:-1])]) \
            if n_tiles else np.zeros(0, np.int64)
        if isinstance(blob, (bytes, bytearray)):
            tile_blobs: "list[bytes] | LaneStore" = [
                bytes(blob[o : o + ln]) for o, ln in zip(offs, lens)]
        else:
            tile_blobs = LaneStore(blob, offs, lens)
        extras = _unpack_extras(blob, extras_off)
        return TiledCompressed(
            shape=tuple(shape), tile=tuple(tile),
            eb_abs=float(np.uint64(ebbits).view(np.float64)),
            backend=_BACKENDS_INV[backend], tile_blobs=tile_blobs,
            predictor=PRED_NAMES[pred], order=ORDER_NAMES[order],
            levels=int(levels), extras=extras,
        )


A.register_container(_MAGIC, TiledCompressed)


# ---------------------------------------------------------------------------
# lane dispatch (shared, size-capped executor)
# ---------------------------------------------------------------------------

_POOL_SIZE = max(1, min(os.cpu_count() or 1, 8))
_LANE_POOL: ThreadPoolExecutor | None = None
_LANE_POOL_LOCK = threading.Lock()


def _lane_pool() -> ThreadPoolExecutor:
    """One shared, size-capped executor for every encode/decode call — lane
    work is short and bursty, so per-call pool construction was pure churn."""
    global _LANE_POOL
    if _LANE_POOL is None:
        with _LANE_POOL_LOCK:
            if _LANE_POOL is None:
                _LANE_POOL = ThreadPoolExecutor(
                    _POOL_SIZE, thread_name_prefix="gwtc-lane")
    return _LANE_POOL


def _lane_workers(n_lanes: int, workers: int | None) -> int:
    if workers is not None:
        return max(1, min(workers, n_lanes))
    cores = os.cpu_count() or 1
    return max(1, min(cores, 8, n_lanes)) if cores > 2 else 1


def _map_lanes(fn, items, workers: int | None):
    """Run ``fn`` over lanes with at most ``workers`` concurrent lanes.

    The per-call concurrency cap is enforced by splitting the lane list into
    that many contiguous runs, each submitted as one serial task to the
    shared pool — order is preserved and no call ever spawns its own pool."""
    w = _lane_workers(len(items), workers)
    if w <= 1:
        return [fn(it) for it in items]
    bounds = np.linspace(0, len(items), w + 1).astype(int)
    chunks = [items[a:b] for a, b in zip(bounds[:-1], bounds[1:]) if b > a]
    futs = [_lane_pool().submit(lambda ch: [fn(it) for it in ch], ch)
            for ch in chunks]
    return [out for f in futs for out in f.result()]


# ---------------------------------------------------------------------------
# engine API
# ---------------------------------------------------------------------------


def compress_tiled(
    x: jax.Array,
    tile=(64, 64, 64),
    *,
    rel_eb: float | None = None,
    abs_eb: float | None = None,
    backend: str = "huffman+zlib",
    predictor: str = "lorenzo",
    order: str = "cubic",
    max_levels: int = 5,
    use_pallas: bool | None = None,
    workers: int | None = None,
) -> tuple[TiledCompressed, jax.Array]:
    """Tile-grid compress; returns (artifact, reconstruction).

    ``predictor`` selects the per-tile transform from the registry
    (``"lorenzo"`` or ``"interp"``; ``order``/``max_levels`` apply to interp
    only).  The reconstruction is the decode program's own output, cropped to
    ``x.shape`` — exactly what :func:`decompress_tiled` will produce."""
    if backend not in _BACKENDS:
        raise ValueError(f"unknown entropy backend {backend!r}")
    pred = get_predictor(predictor)
    x = jnp.asarray(x, jnp.float32)
    tile = normalize_tile(tile, x.ndim)
    eb = resolve_eb(x, rel_eb, abs_eb)
    levels = pred.plan(tile, max_levels)
    xp = pad_to_tiles(x, tile)
    tiles = split_tiles(xp, tile)
    payload, recon_tiles = pred.encode_tiles(
        tiles, eb, order=order, levels=levels, use_pallas=use_pallas)
    recon = stitch_tiles(recon_tiles, tile_grid(x.shape, tile))

    payload_np = jax.tree.map(np.asarray, payload)
    blobs = _map_lanes(lambda i: pred.lane_bytes(payload_np, i, backend),
                       list(range(tiles.shape[0])), workers)
    artifact = TiledCompressed(
        shape=tuple(x.shape), tile=tile, eb_abs=eb, backend=backend,
        tile_blobs=blobs, predictor=predictor, order=order, levels=levels)
    return artifact, recon[tuple(slice(0, d) for d in x.shape)]


def decode_lanes(
    artifact: TiledCompressed, lane_ids, *, workers: int | None = None
) -> tuple[jax.Array, int]:
    """Decode the given lanes and reconstruct them; returns
    ``(recon [len(ids), *tile], lanes_decoded)``.

    Only the named lanes are touched — this is the random-access primitive
    both :func:`decompress_tiled` and :func:`decompress_region` build on.
    The returned lane count is the race-free observability channel (the
    module-level ``DECODE_STATS`` mirror is best-effort, for convenience)."""
    pred = get_predictor(artifact.predictor)
    lane_ids = list(lane_ids)
    blobs = [artifact.tile_blobs[i] for i in lane_ids]
    items = _map_lanes(
        lambda b: pred.parse_lane(b, tile=artifact.tile, levels=artifact.levels),
        blobs, workers)
    with _STATS_LOCK:
        DECODE_STATS["tiles_decoded"] = len(lane_ids)
        DECODE_STATS["tiles_total"] = artifact.n_tiles
    payload = {k: jnp.asarray(np.stack([it[k] for it in items])) for k in items[0]}
    recon = pred.decode_tiles(payload, artifact.eb_abs, tile=artifact.tile,
                              order=artifact.order, levels=artifact.levels)
    return recon, len(lane_ids)


def decompress_tiled(
    artifact: TiledCompressed, *, workers: int | None = None, tile_transform=None
) -> jax.Array:
    """Full decode: every lane, stitched and cropped to the original shape.

    ``tile_transform([K, *tile]) -> [K, *tile]`` post-processes decoded tiles
    before stitching (the GWLZ pipeline enhances per tile through it; it must
    act per-tile so region and full decode stay consistent)."""
    recon, _ = decode_lanes(artifact, range(artifact.n_tiles), workers=workers)
    if tile_transform is not None:
        recon = tile_transform(recon)
    out = stitch_tiles(recon, artifact.grid)
    return out[tuple(slice(0, d) for d in artifact.shape)]


def normalize_roi(roi, shape: tuple[int, ...]) -> tuple[tuple[int, int], ...]:
    """ROI as slices or (start, stop) pairs -> clamped (start, stop) tuples."""
    if len(roi) != len(shape):
        raise ValueError(f"roi rank {len(roi)} != volume rank {len(shape)}")
    out = []
    for r, d in zip(roi, shape):
        if isinstance(r, slice):
            if r.step not in (None, 1):
                raise ValueError("roi slices must have step 1")
            start, stop, _ = r.indices(d)
        else:
            start, stop = r
            start = start + d if start < 0 else start
            stop = stop + d if stop < 0 else stop
            start, stop = max(0, min(start, d)), max(0, min(stop, d))
        if stop <= start:
            raise ValueError(f"empty roi extent {r} on a dim of size {d}")
        out.append((int(start), int(stop)))
    return tuple(out)


def region_tiles(artifact: TiledCompressed, roi) -> tuple[np.ndarray, tuple]:
    """(flat lane ids of tiles intersecting ``roi``, per-dim tile ranges)."""
    bounds = normalize_roi(roi, artifact.shape)
    ranges = tuple((lo // t, -(-hi // t))
                   for (lo, hi), t in zip(bounds, artifact.tile))
    axes = [np.arange(a, b) for a, b in ranges]
    coords = np.meshgrid(*axes, indexing="ij")
    ids = np.ravel_multi_index([c.ravel() for c in coords], artifact.grid)
    return ids, (bounds, ranges)


def assemble_region(recon, geom, tile: tuple[int, ...]):
    """Stitch + crop decoded region tiles: the pure-geometry back half of
    :func:`decompress_region`, shared with the façade's cached read path
    (``recon`` may be a jax array or a numpy stack of cached tiles —
    stitching is reshape/transpose either way)."""
    bounds, ranges = geom
    sub_grid = tuple(b - a for a, b in ranges)
    block = stitch_tiles(recon, sub_grid)
    crop = tuple(slice(lo - a * t, hi - a * t)
                 for (lo, hi), (a, _b), t in zip(bounds, ranges, tile))
    return block[crop]


def decompress_region(
    artifact: TiledCompressed, roi, *, workers: int | None = None, tile_transform=None
) -> jax.Array:
    """Decode only the tiles intersecting ``roi``; returns the ROI's values.

    Bit-identical to ``decompress_tiled(artifact)[roi]`` — the per-tile
    transform is elementwise-exact, so the subset batch reconstructs the
    same values the full batch would (any ``tile_transform`` must preserve
    this by acting on each tile independently)."""
    ids, geom = region_tiles(artifact, roi)
    recon, _ = decode_lanes(artifact, ids.tolist(), workers=workers)
    if tile_transform is not None:
        recon = tile_transform(recon)
    return assemble_region(recon, geom, artifact.tile)
