"""Tile-based compression engine with random-access decode (``GWTC``).

The monolithic SZ path materializes one volume end to end; this engine splits
the (padded) volume into a fixed tile grid and makes every tile a fully
independent compression domain:

* the per-tile prediction transform is *pluggable*: the tile batch dispatches
  through the predictor registry (``repro.sz.predictor.get_predictor``) —
  ``"lorenzo"`` (prequant + batched integer Lorenzo) or ``"interp"`` (SZ3-
  style multi-level interpolation, vmapped per tile).  Batched passes fan
  across the device mesh via ``repro.launch.sharding.map_tiles``,
* each tile entropy-encodes as an independent lane on the chunked ``hc``/
  ``hZ`` codec (docs/ENTROPY_FORMAT.md), so lanes decode independently and
  in parallel,
* the ``GWTC`` container stores a per-tile offset index, so
  :func:`decompress_region` entropy-decodes *only* the tiles intersecting
  the requested ROI — partial reads never pay for the whole blob.

Every predictor's batched decode is elementwise-exact in the batch axis
(each tile is an independent prediction domain), so region decode is
bit-identical to the full decode's crop whichever predictor produced the
artifact.  Container layout (``GWTC`` v2; v1 blobs still decode) is
specified in docs/TILED_FORMAT.md; the layered stack is described in
docs/ARCHITECTURE.md.
"""
from __future__ import annotations

import os
import struct
import threading
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.sz import artifact as A
from repro.sz.predictor import ORDER_IDS, ORDER_NAMES, PRED_IDS, PRED_NAMES, get_predictor
from repro.sz.quantizer import resolve_eb

_MAGIC = b"GWTC"
_VERSION = 2
# v1: magic, version, ndim, backend, pad, eb bits, n_tiles
_HDR_V1 = struct.Struct("<4sBBBBQQ")
# v2 adds the predictor layer: magic, version, ndim, backend, predictor,
# order, levels, pad, eb bits, n_tiles
_HDR_V2 = struct.Struct("<4sBBBBBBBQQ")
_BACKENDS = {"zlib": 0, "huffman": 1, "huffman+zlib": 2}
_BACKENDS_INV = {v: k for k, v in _BACKENDS.items()}

# Observability for tests/benchmarks: how many lanes the last decode touched.
# Written under _STATS_LOCK (concurrent decodes do not interleave partial
# updates); :func:`decode_lanes` also *returns* the lane count, which is the
# race-free way to consume it.
DECODE_STATS = {"tiles_decoded": 0, "tiles_total": 0}
_STATS_LOCK = threading.Lock()


# ---------------------------------------------------------------------------
# tile grid geometry
# ---------------------------------------------------------------------------


def normalize_tile(tile, ndim: int) -> tuple[int, ...]:
    if isinstance(tile, int):
        tile = (tile,) * ndim
    tile = tuple(int(t) for t in tile)
    if len(tile) != ndim or any(t < 1 for t in tile):
        raise ValueError(f"tile {tile} invalid for a {ndim}-d volume")
    return tile


def tile_grid(shape: tuple[int, ...], tile: tuple[int, ...]) -> tuple[int, ...]:
    return tuple(-(-d // t) for d, t in zip(shape, tile))


def pad_to_tiles(x: jax.Array, tile: tuple[int, ...]) -> jax.Array:
    pshape = tuple(g * t for g, t in zip(tile_grid(x.shape, tile), tile))
    pads = [(0, p - d) for d, p in zip(x.shape, pshape)]
    return jnp.pad(x, pads, mode="edge")


def split_tiles(xp: jax.Array, tile: tuple[int, ...]) -> jax.Array:
    """[g0*t0, g1*t1, ...] -> [prod(g), t0, t1, ...] in row-major grid order."""
    grid = tuple(d // t for d, t in zip(xp.shape, tile))
    nd = len(tile)
    interleaved = xp.reshape(sum(((g, t) for g, t in zip(grid, tile)), ()))
    perm = tuple(range(0, 2 * nd, 2)) + tuple(range(1, 2 * nd, 2))
    return interleaved.transpose(perm).reshape((-1,) + tile)


def stitch_tiles(tiles: jax.Array, grid: tuple[int, ...]) -> jax.Array:
    """Inverse of :func:`split_tiles`: [prod(g), *tile] -> padded volume."""
    tile = tiles.shape[1:]
    nd = len(tile)
    blocks = tiles.reshape(grid + tile)
    perm = sum(((d, nd + d) for d in range(nd)), ())
    return blocks.transpose(perm).reshape(tuple(g * t for g, t in zip(grid, tile)))


# ---------------------------------------------------------------------------
# container
# ---------------------------------------------------------------------------


@dataclass
class TiledCompressed:
    """Self-describing tiled artifact (``GWTC`` v2, docs/TILED_FORMAT.md).

    ``tile_blobs[i]`` is an independent, self-describing lane for tile ``i``
    in row-major grid order (predictor-specific layout; for ``lorenzo`` a
    bare ``RPRE`` entropy blob, for ``interp`` outliers + ``RPRE`` codes).
    ``predictor``/``order``/``levels`` record the per-tile transform; v1
    blobs (always Lorenzo) still parse."""

    shape: tuple[int, ...]
    tile: tuple[int, ...]
    eb_abs: float
    backend: str
    tile_blobs: list[bytes]
    predictor: str = "lorenzo"
    order: str = "cubic"
    levels: int = 0
    extras: dict = field(default_factory=dict)
    # serialization cache keyed on the extras fingerprint (same scheme as
    # SZCompressed): GWLZ.compress_tiled asks for nbytes before and after
    # attaching the model, and size_report() asks again
    _blob_cache: tuple | None = field(default=None, init=False, repr=False, compare=False)

    @property
    def grid(self) -> tuple[int, ...]:
        return tile_grid(self.shape, self.tile)

    @property
    def padded_shape(self) -> tuple[int, ...]:
        return tuple(g * t for g, t in zip(self.grid, self.tile))

    @property
    def n_tiles(self) -> int:
        return int(np.prod(self.grid))

    @property
    def nbytes(self) -> int:
        return len(self.to_bytes())

    def size_report(self) -> dict:
        lanes = sum(len(b) for b in self.tile_blobs)
        extras = sum(len(v) for v in self.extras.values())
        index = 8 * len(self.tile_blobs)
        return {"lanes": lanes, "index": index, "extras": extras,
                "header": _HDR_V2.size + 16 * len(self.shape), "total": self.nbytes}

    def to_bytes(self) -> bytes:
        key = tuple(sorted(self.extras.items()))
        if self._blob_cache is not None and self._blob_cache[0] == key:
            return self._blob_cache[1]
        blob = self._serialize()
        self._blob_cache = (key, blob)
        return blob

    def _serialize(self) -> bytes:
        nd = len(self.shape)
        hdr = _HDR_V2.pack(_MAGIC, _VERSION, nd, _BACKENDS[self.backend],
                           PRED_IDS[self.predictor], ORDER_IDS[self.order],
                           self.levels, 0,
                           np.float64(self.eb_abs).view(np.uint64),
                           len(self.tile_blobs))
        dims = struct.pack(f"<{nd}q", *self.shape) + struct.pack(f"<{nd}q", *self.tile)
        index = np.asarray([len(b) for b in self.tile_blobs], np.uint64).tobytes()
        extras_items = sorted(self.extras.items())
        extras_blob = struct.pack("<I", len(extras_items))
        for k, v in extras_items:
            kb = k.encode()
            extras_blob += struct.pack("<II", len(kb), len(v)) + kb + v
        return hdr + dims + index + b"".join(self.tile_blobs) + extras_blob

    @staticmethod
    def from_bytes(blob: bytes) -> "TiledCompressed":
        magic, ver = struct.unpack_from("<4sB", blob, 0)
        assert magic == _MAGIC, "bad GWTC blob"
        if ver == 1:
            # v1 predates the predictor layer: lanes are always Lorenzo codes.
            _m, _v, nd, backend, _pad, ebbits, n_tiles = _HDR_V1.unpack_from(blob, 0)
            pred, order, levels = PRED_IDS["lorenzo"], ORDER_IDS["cubic"], 0
            off = _HDR_V1.size
        elif ver == _VERSION:
            (_m, _v, nd, backend, pred, order, levels, _pad, ebbits,
             n_tiles) = _HDR_V2.unpack_from(blob, 0)
            off = _HDR_V2.size
        else:
            raise AssertionError(f"unsupported GWTC version {ver}")
        shape = struct.unpack_from(f"<{nd}q", blob, off)
        off += 8 * nd
        tile = struct.unpack_from(f"<{nd}q", blob, off)
        off += 8 * nd
        lens = np.frombuffer(blob, np.uint64, n_tiles, offset=off)
        off += 8 * n_tiles
        tile_blobs = []
        for ln in lens.astype(np.int64):
            tile_blobs.append(blob[off : off + ln])
            off += int(ln)
        (n_extras,) = struct.unpack_from("<I", blob, off)
        off += 4
        extras = {}
        for _ in range(n_extras):
            klen, vlen = struct.unpack_from("<II", blob, off)
            off += 8
            k = blob[off : off + klen].decode()
            off += klen
            extras[k] = blob[off : off + vlen]
            off += vlen
        return TiledCompressed(
            shape=tuple(shape), tile=tuple(tile),
            eb_abs=float(np.uint64(ebbits).view(np.float64)),
            backend=_BACKENDS_INV[backend], tile_blobs=tile_blobs,
            predictor=PRED_NAMES[pred], order=ORDER_NAMES[order],
            levels=int(levels), extras=extras,
        )


A.register_container(_MAGIC, TiledCompressed)


# ---------------------------------------------------------------------------
# lane dispatch (shared, size-capped executor)
# ---------------------------------------------------------------------------

_POOL_SIZE = max(1, min(os.cpu_count() or 1, 8))
_LANE_POOL: ThreadPoolExecutor | None = None
_LANE_POOL_LOCK = threading.Lock()


def _lane_pool() -> ThreadPoolExecutor:
    """One shared, size-capped executor for every encode/decode call — lane
    work is short and bursty, so per-call pool construction was pure churn."""
    global _LANE_POOL
    if _LANE_POOL is None:
        with _LANE_POOL_LOCK:
            if _LANE_POOL is None:
                _LANE_POOL = ThreadPoolExecutor(
                    _POOL_SIZE, thread_name_prefix="gwtc-lane")
    return _LANE_POOL


def _lane_workers(n_lanes: int, workers: int | None) -> int:
    if workers is not None:
        return max(1, min(workers, n_lanes))
    cores = os.cpu_count() or 1
    return max(1, min(cores, 8, n_lanes)) if cores > 2 else 1


def _map_lanes(fn, items, workers: int | None):
    """Run ``fn`` over lanes with at most ``workers`` concurrent lanes.

    The per-call concurrency cap is enforced by splitting the lane list into
    that many contiguous runs, each submitted as one serial task to the
    shared pool — order is preserved and no call ever spawns its own pool."""
    w = _lane_workers(len(items), workers)
    if w <= 1:
        return [fn(it) for it in items]
    bounds = np.linspace(0, len(items), w + 1).astype(int)
    chunks = [items[a:b] for a, b in zip(bounds[:-1], bounds[1:]) if b > a]
    futs = [_lane_pool().submit(lambda ch: [fn(it) for it in ch], ch)
            for ch in chunks]
    return [out for f in futs for out in f.result()]


# ---------------------------------------------------------------------------
# engine API
# ---------------------------------------------------------------------------


def compress_tiled(
    x: jax.Array,
    tile=(64, 64, 64),
    *,
    rel_eb: float | None = None,
    abs_eb: float | None = None,
    backend: str = "huffman+zlib",
    predictor: str = "lorenzo",
    order: str = "cubic",
    max_levels: int = 5,
    use_pallas: bool | None = None,
    workers: int | None = None,
) -> tuple[TiledCompressed, jax.Array]:
    """Tile-grid compress; returns (artifact, reconstruction).

    ``predictor`` selects the per-tile transform from the registry
    (``"lorenzo"`` or ``"interp"``; ``order``/``max_levels`` apply to interp
    only).  The reconstruction is the decode program's own output, cropped to
    ``x.shape`` — exactly what :func:`decompress_tiled` will produce."""
    if backend not in _BACKENDS:
        raise ValueError(f"unknown entropy backend {backend!r}")
    pred = get_predictor(predictor)
    x = jnp.asarray(x, jnp.float32)
    tile = normalize_tile(tile, x.ndim)
    eb = resolve_eb(x, rel_eb, abs_eb)
    levels = pred.plan(tile, max_levels)
    xp = pad_to_tiles(x, tile)
    tiles = split_tiles(xp, tile)
    payload, recon_tiles = pred.encode_tiles(
        tiles, eb, order=order, levels=levels, use_pallas=use_pallas)
    recon = stitch_tiles(recon_tiles, tile_grid(x.shape, tile))

    payload_np = jax.tree.map(np.asarray, payload)
    blobs = _map_lanes(lambda i: pred.lane_bytes(payload_np, i, backend),
                       list(range(tiles.shape[0])), workers)
    artifact = TiledCompressed(
        shape=tuple(x.shape), tile=tile, eb_abs=eb, backend=backend,
        tile_blobs=blobs, predictor=predictor, order=order, levels=levels)
    return artifact, recon[tuple(slice(0, d) for d in x.shape)]


def decode_lanes(
    artifact: TiledCompressed, lane_ids, *, workers: int | None = None
) -> tuple[jax.Array, int]:
    """Decode the given lanes and reconstruct them; returns
    ``(recon [len(ids), *tile], lanes_decoded)``.

    Only the named lanes are touched — this is the random-access primitive
    both :func:`decompress_tiled` and :func:`decompress_region` build on.
    The returned lane count is the race-free observability channel (the
    module-level ``DECODE_STATS`` mirror is best-effort, for convenience)."""
    pred = get_predictor(artifact.predictor)
    lane_ids = list(lane_ids)
    blobs = [artifact.tile_blobs[i] for i in lane_ids]
    items = _map_lanes(
        lambda b: pred.parse_lane(b, tile=artifact.tile, levels=artifact.levels),
        blobs, workers)
    with _STATS_LOCK:
        DECODE_STATS["tiles_decoded"] = len(lane_ids)
        DECODE_STATS["tiles_total"] = artifact.n_tiles
    payload = {k: jnp.asarray(np.stack([it[k] for it in items])) for k in items[0]}
    recon = pred.decode_tiles(payload, artifact.eb_abs, tile=artifact.tile,
                              order=artifact.order, levels=artifact.levels)
    return recon, len(lane_ids)


def decompress_tiled(
    artifact: TiledCompressed, *, workers: int | None = None, tile_transform=None
) -> jax.Array:
    """Full decode: every lane, stitched and cropped to the original shape.

    ``tile_transform([K, *tile]) -> [K, *tile]`` post-processes decoded tiles
    before stitching (the GWLZ pipeline enhances per tile through it; it must
    act per-tile so region and full decode stay consistent)."""
    recon, _ = decode_lanes(artifact, range(artifact.n_tiles), workers=workers)
    if tile_transform is not None:
        recon = tile_transform(recon)
    out = stitch_tiles(recon, artifact.grid)
    return out[tuple(slice(0, d) for d in artifact.shape)]


def normalize_roi(roi, shape: tuple[int, ...]) -> tuple[tuple[int, int], ...]:
    """ROI as slices or (start, stop) pairs -> clamped (start, stop) tuples."""
    if len(roi) != len(shape):
        raise ValueError(f"roi rank {len(roi)} != volume rank {len(shape)}")
    out = []
    for r, d in zip(roi, shape):
        if isinstance(r, slice):
            if r.step not in (None, 1):
                raise ValueError("roi slices must have step 1")
            start, stop, _ = r.indices(d)
        else:
            start, stop = r
            start = start + d if start < 0 else start
            stop = stop + d if stop < 0 else stop
            start, stop = max(0, min(start, d)), max(0, min(stop, d))
        if stop <= start:
            raise ValueError(f"empty roi extent {r} on a dim of size {d}")
        out.append((int(start), int(stop)))
    return tuple(out)


def region_tiles(artifact: TiledCompressed, roi) -> tuple[np.ndarray, tuple]:
    """(flat lane ids of tiles intersecting ``roi``, per-dim tile ranges)."""
    bounds = normalize_roi(roi, artifact.shape)
    ranges = tuple((lo // t, -(-hi // t))
                   for (lo, hi), t in zip(bounds, artifact.tile))
    axes = [np.arange(a, b) for a, b in ranges]
    coords = np.meshgrid(*axes, indexing="ij")
    ids = np.ravel_multi_index([c.ravel() for c in coords], artifact.grid)
    return ids, (bounds, ranges)


def decompress_region(
    artifact: TiledCompressed, roi, *, workers: int | None = None, tile_transform=None
) -> jax.Array:
    """Decode only the tiles intersecting ``roi``; returns the ROI's values.

    Bit-identical to ``decompress_tiled(artifact)[roi]`` — the per-tile
    transform is elementwise-exact, so the subset batch reconstructs the
    same values the full batch would (any ``tile_transform`` must preserve
    this by acting on each tile independently)."""
    ids, (bounds, ranges) = region_tiles(artifact, roi)
    recon, _ = decode_lanes(artifact, ids.tolist(), workers=workers)
    if tile_transform is not None:
        recon = tile_transform(recon)
    sub_grid = tuple(b - a for a, b in ranges)
    block = stitch_tiles(recon, sub_grid)
    crop = tuple(slice(lo - a * t, hi - a * t)
                 for (lo, hi), (a, _b), t in zip(bounds, ranges, artifact.tile))
    return block[crop]
