"""Prediction transforms for the SZ substrate: Lorenzo and multi-level interpolation.

TPU adaptation (DESIGN.md §3):

* The Lorenzo path uses cuSZ-style *prequantization*: values are first snapped
  onto the 2*eb grid (the only lossy step), then an exact integer Lorenzo
  stencil decorrelates them.  Reconstruction is ``cumsum`` along each axis —
  no sequential sweep anywhere, unlike CPU SZ.
* The interpolation path follows SZ3's level-by-level spline predictor, but
  schedules each level as a fully vectorized slice/arith op; the only
  sequential dependence is across the ~log2(N) levels, which is negligible.

Both paths guarantee |x - x'| <= eb pointwise (interp handles float-rounding
stragglers through the outlier mechanism in :mod:`repro.sz.quantizer`).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.sz.quantizer import (
    dequantize_pre,
    prequantize,
    quantize_residual,
)

# ---------------------------------------------------------------------------
# Lorenzo (prequantized, integer-exact)
# ---------------------------------------------------------------------------


def _diff_along(q: jax.Array, axis: int) -> jax.Array:
    """First difference with implicit zero at the leading boundary."""
    shifted = jnp.roll(q, 1, axis=axis)
    idx = [slice(None)] * q.ndim
    idx[axis] = slice(0, 1)
    shifted = shifted.at[tuple(idx)].set(0)
    return q - shifted


def lorenzo_encode(x: jax.Array, eb) -> jax.Array:
    """x -> int32 Lorenzo deltas of the prequantized grid (lossy only in prequant)."""
    q = prequantize(x, eb)
    for ax in range(x.ndim):
        q = _diff_along(q, ax)
    return q


def lorenzo_decode(codes: jax.Array, eb, dtype=jnp.float32) -> jax.Array:
    """Exact inverse: integer cumsum along each axis, then dequantize."""
    q = codes
    for ax in range(codes.ndim):
        q = jnp.cumsum(q, axis=ax, dtype=jnp.int32)
    return dequantize_pre(q, eb, dtype)


# ---------------------------------------------------------------------------
# Multi-level interpolation (SZ3-style)
# ---------------------------------------------------------------------------


def _num_levels(shape: tuple[int, ...], max_levels: int = 5) -> int:
    m = min(shape)
    if m < 3:
        return 1
    return max(1, min(max_levels, int(math.floor(math.log2(m - 1)))))


def _padded_shape(shape: tuple[int, ...], levels: int) -> tuple[int, ...]:
    """Pad each dim to M * 2**levels + 1 so every interp neighbor exists."""
    s = 1 << levels
    return tuple(((max(d - 1, 1) + s - 1) // s) * s + 1 for d in shape)


def _pad_edge(x: jax.Array, pshape: tuple[int, ...]) -> jax.Array:
    pads = [(0, p - d) for d, p in zip(x.shape, pshape)]
    return jnp.pad(x, pads, mode="edge")


def _axis_slices(ndim: int, axis: int, step_axis: int, known_strides: list[int]):
    """Slicers for one interpolation sweep along ``axis`` at stride ``s``.

    ``known_strides[d]`` is the stride at which dimension ``d`` is already
    reconstructed.  Targets sit at odd multiples of ``s`` along ``axis``.
    """
    s = step_axis
    tgt = [slice(0, None, st) for st in known_strides]
    tgt[axis] = slice(s, None, 2 * s)
    return tuple(tgt)


def _even_grid(r: jax.Array, axis: int, s: int, known_strides: list[int]) -> jax.Array:
    sl = [slice(0, None, st) for st in known_strides]
    sl[axis] = slice(0, None, 2 * s)
    return r[tuple(sl)]


def _interp_pred(e: jax.Array, axis: int, order: str) -> jax.Array:
    """Predict odd-multiple targets from the even grid ``e`` along ``axis``.

    ``e`` has M+1 entries along ``axis``; output has M (one per target).
    """

    def ax_slice(a, start, stop):
        idx = [slice(None)] * a.ndim
        idx[axis] = slice(start, stop)
        return a[tuple(idx)]

    lin = 0.5 * (ax_slice(e, 0, -1) + ax_slice(e, 1, None))
    if order == "linear" or e.shape[axis] < 4:
        return lin
    # 4-point cubic (Lagrange) in the interior, linear at the two borders.
    cub = (
        -ax_slice(e, 0, -3) + 9.0 * ax_slice(e, 1, -2) + 9.0 * ax_slice(e, 2, -1) - ax_slice(e, 3, None)
    ) / 16.0
    first = ax_slice(lin, 0, 1)
    last = ax_slice(lin, -1, None)
    return jnp.concatenate([first, cub, last], axis=axis)


def _level_strides(levels: int) -> list[int]:
    return [1 << (lv - 1) for lv in range(levels, 0, -1)]  # S/2 ... 1 where S=2**levels


@partial(jax.jit, static_argnames=("levels", "order"))
def _interp_encode_padded(xp: jax.Array, eb, levels: int, order: str):
    """Encode an edge-padded volume. Returns (codes, omask, ovals, recon)."""
    ndim = xp.ndim
    S = 1 << levels
    eb = jnp.asarray(eb, xp.dtype)

    codes = jnp.zeros(xp.shape, jnp.int32)
    omask = jnp.zeros(xp.shape, bool)
    ovals = jnp.zeros(xp.shape, xp.dtype)
    recon = jnp.zeros(xp.shape, xp.dtype)

    # Coarse grid: prequantize + integer Lorenzo (exact, parallel).
    coarse_sl = tuple(slice(0, None, S) for _ in range(ndim))
    xc = xp[coarse_sl]
    cc = lorenzo_encode(xc, eb)
    rc = lorenzo_decode(cc, eb, xp.dtype)
    codes = codes.at[coarse_sl].set(cc)
    recon = recon.at[coarse_sl].set(rc)

    for s in _level_strides(levels):
        known = [2 * s] * ndim
        for axis in range(ndim):
            tgt = _axis_slices(ndim, axis, s, known)
            e = _even_grid(recon, axis, s, known)
            pred = _interp_pred(e, axis, order)
            sub = xp[tgt]
            code, rec, outl = quantize_residual(sub, pred, eb)
            codes = codes.at[tgt].set(code)
            omask = omask.at[tgt].set(outl)
            ovals = ovals.at[tgt].set(jnp.where(outl, sub, 0.0))
            recon = recon.at[tgt].set(rec)
            known[axis] = s  # this axis is now dense at stride s
    return codes, omask, ovals, recon


@partial(jax.jit, static_argnames=("levels", "order"))
def _interp_decode_padded(codes: jax.Array, omask: jax.Array, ovals: jax.Array, eb, levels: int, order: str):
    ndim = codes.ndim
    S = 1 << levels
    eb = jnp.asarray(eb, ovals.dtype)

    recon = jnp.zeros(codes.shape, ovals.dtype)
    coarse_sl = tuple(slice(0, None, S) for _ in range(ndim))
    recon = recon.at[coarse_sl].set(lorenzo_decode(codes[coarse_sl], eb, ovals.dtype))

    for s in _level_strides(levels):
        known = [2 * s] * ndim
        for axis in range(ndim):
            tgt = _axis_slices(ndim, axis, s, known)
            e = _even_grid(recon, axis, s, known)
            pred = _interp_pred(e, axis, order)
            rec = pred + codes[tgt].astype(ovals.dtype) * (2.0 * eb)
            rec = jnp.where(omask[tgt], ovals[tgt], rec)
            recon = recon.at[tgt].set(rec)
            known[axis] = s
    return recon


def interp_encode(x: jax.Array, eb, order: str = "cubic", max_levels: int = 5):
    """Multi-level interpolation encode.

    Returns ``(codes, omask, ovals, recon, meta)`` where arrays live on the
    padded grid and ``meta = (orig_shape, padded_shape, levels)``.  ``recon``
    cropped to ``orig_shape`` satisfies the error bound.

    ``recon`` is the *decode program's* output, not the encoder's internal
    reconstruction: the two are separately jitted, so fusion differences can
    drift a few ulps apart — enough to push points sitting exactly at the
    bound past it at decompression.  Running the decoder here and promoting
    any straggler to an outlier makes the bound hold by construction on the
    artifact the decompressor actually sees.
    """
    levels = _num_levels(x.shape, max_levels)
    pshape = _padded_shape(x.shape, levels)
    xp = _pad_edge(x, pshape)
    codes, omask, ovals, recon = _interp_encode_padded(xp, eb, levels, order)
    # The coarse grid bypasses the outlier mechanism (Lorenzo-coded; decode
    # never consults omask there), so only interp targets are promotable.
    S = 1 << levels
    coarse = jnp.zeros(pshape, bool).at[tuple(slice(0, None, S) for _ in pshape)].set(True)
    # Invariants on exit: recon == decode(codes, omask, ovals) AND the bound
    # holds on every promotable point.  The loop terminates: each iteration
    # strictly grows omask (promoted points decode exactly thereafter), which
    # is bounded by the volume size; in practice it runs 1-2 rounds.
    recon = _interp_decode_padded(codes, omask, ovals, eb, levels, order)
    while True:
        bad = (jnp.abs(recon - xp) > eb) & ~omask & ~coarse
        if not bool(bad.any()):
            break
        omask = omask | bad
        ovals = jnp.where(bad, xp, ovals)
        recon = _interp_decode_padded(codes, omask, ovals, eb, levels, order)
    meta = (tuple(x.shape), pshape, levels)
    return codes, omask, ovals, recon, meta


def interp_decode(codes, omask, ovals, eb, meta, order: str = "cubic"):
    orig_shape, _pshape, levels = meta
    recon = _interp_decode_padded(codes, omask, ovals, eb, levels, order)
    return recon[tuple(slice(0, d) for d in orig_shape)]
