"""Prediction transforms for the SZ substrate: Lorenzo and multi-level interpolation.

TPU adaptation (DESIGN.md §3):

* The Lorenzo path uses cuSZ-style *prequantization*: values are first snapped
  onto the 2*eb grid (the only lossy step), then an exact integer Lorenzo
  stencil decorrelates them.  Reconstruction is ``cumsum`` along each axis —
  no sequential sweep anywhere, unlike CPU SZ.
* The interpolation path follows SZ3's level-by-level spline predictor, but
  schedules each level as a fully vectorized slice/arith op; the only
  sequential dependence is across the ~log2(N) levels, which is negligible.

Both paths guarantee |x - x'| <= eb pointwise (interp handles float-rounding
stragglers through the outlier mechanism in :mod:`repro.sz.quantizer`).
"""
from __future__ import annotations

import math
import struct
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.sz.quantizer import (
    dequantize_pre,
    prequantize,
    quantize_residual,
)

# ---------------------------------------------------------------------------
# Lorenzo (prequantized, integer-exact)
# ---------------------------------------------------------------------------


def _diff_along(q: jax.Array, axis: int) -> jax.Array:
    """First difference with implicit zero at the leading boundary."""
    shifted = jnp.roll(q, 1, axis=axis)
    idx = [slice(None)] * q.ndim
    idx[axis] = slice(0, 1)
    shifted = shifted.at[tuple(idx)].set(0)
    return q - shifted


def lorenzo_encode(x: jax.Array, eb) -> jax.Array:
    """x -> int32 Lorenzo deltas of the prequantized grid (lossy only in prequant)."""
    q = prequantize(x, eb)
    for ax in range(x.ndim):
        q = _diff_along(q, ax)
    return q


def lorenzo_decode(codes: jax.Array, eb, dtype=jnp.float32) -> jax.Array:
    """Exact inverse: integer cumsum along each axis, then dequantize."""
    q = codes
    for ax in range(codes.ndim):
        q = jnp.cumsum(q, axis=ax, dtype=jnp.int32)
    return dequantize_pre(q, eb, dtype)


# ---------------------------------------------------------------------------
# Multi-level interpolation (SZ3-style)
# ---------------------------------------------------------------------------


def _num_levels(shape: tuple[int, ...], max_levels: int = 5) -> int:
    m = min(shape)
    if m < 3:
        return 1
    return max(1, min(max_levels, int(math.floor(math.log2(m - 1)))))


def _padded_shape(shape: tuple[int, ...], levels: int) -> tuple[int, ...]:
    """Pad each dim to M * 2**levels + 1 so every interp neighbor exists."""
    s = 1 << levels
    return tuple(((max(d - 1, 1) + s - 1) // s) * s + 1 for d in shape)


def _pad_edge(x: jax.Array, pshape: tuple[int, ...]) -> jax.Array:
    pads = [(0, p - d) for d, p in zip(x.shape, pshape)]
    return jnp.pad(x, pads, mode="edge")


def _axis_slices(ndim: int, axis: int, step_axis: int, known_strides: list[int]):
    """Slicers for one interpolation sweep along ``axis`` at stride ``s``.

    ``known_strides[d]`` is the stride at which dimension ``d`` is already
    reconstructed.  Targets sit at odd multiples of ``s`` along ``axis``.
    """
    s = step_axis
    tgt = [slice(0, None, st) for st in known_strides]
    tgt[axis] = slice(s, None, 2 * s)
    return tuple(tgt)


def _even_grid(r: jax.Array, axis: int, s: int, known_strides: list[int]) -> jax.Array:
    sl = [slice(0, None, st) for st in known_strides]
    sl[axis] = slice(0, None, 2 * s)
    return r[tuple(sl)]


def _interp_pred(e: jax.Array, axis: int, order: str) -> jax.Array:
    """Predict odd-multiple targets from the even grid ``e`` along ``axis``.

    ``e`` has M+1 entries along ``axis``; output has M (one per target).
    """

    def ax_slice(a, start, stop):
        idx = [slice(None)] * a.ndim
        idx[axis] = slice(start, stop)
        return a[tuple(idx)]

    lin = 0.5 * (ax_slice(e, 0, -1) + ax_slice(e, 1, None))
    if order == "linear" or e.shape[axis] < 4:
        return lin
    # 4-point cubic (Lagrange) in the interior, linear at the two borders.
    cub = (
        -ax_slice(e, 0, -3) + 9.0 * ax_slice(e, 1, -2) + 9.0 * ax_slice(e, 2, -1) - ax_slice(e, 3, None)
    ) / 16.0
    first = ax_slice(lin, 0, 1)
    last = ax_slice(lin, -1, None)
    return jnp.concatenate([first, cub, last], axis=axis)


def _level_strides(levels: int) -> list[int]:
    return [1 << (lv - 1) for lv in range(levels, 0, -1)]  # S/2 ... 1 where S=2**levels


@partial(jax.jit, static_argnames=("levels", "order"))
def _interp_encode_padded(xp: jax.Array, eb, levels: int, order: str):
    """Encode an edge-padded volume. Returns (codes, omask, ovals, recon)."""
    ndim = xp.ndim
    S = 1 << levels
    eb = jnp.asarray(eb, xp.dtype)

    codes = jnp.zeros(xp.shape, jnp.int32)
    omask = jnp.zeros(xp.shape, bool)
    ovals = jnp.zeros(xp.shape, xp.dtype)
    recon = jnp.zeros(xp.shape, xp.dtype)

    # Coarse grid: prequantize + integer Lorenzo (exact, parallel).
    coarse_sl = tuple(slice(0, None, S) for _ in range(ndim))
    xc = xp[coarse_sl]
    cc = lorenzo_encode(xc, eb)
    rc = lorenzo_decode(cc, eb, xp.dtype)
    codes = codes.at[coarse_sl].set(cc)
    recon = recon.at[coarse_sl].set(rc)

    for s in _level_strides(levels):
        known = [2 * s] * ndim
        for axis in range(ndim):
            tgt = _axis_slices(ndim, axis, s, known)
            e = _even_grid(recon, axis, s, known)
            pred = _interp_pred(e, axis, order)
            sub = xp[tgt]
            code, rec, outl = quantize_residual(sub, pred, eb)
            codes = codes.at[tgt].set(code)
            omask = omask.at[tgt].set(outl)
            ovals = ovals.at[tgt].set(jnp.where(outl, sub, 0.0))
            recon = recon.at[tgt].set(rec)
            known[axis] = s  # this axis is now dense at stride s
    return codes, omask, ovals, recon


@partial(jax.jit, static_argnames=("levels", "order"))
def _interp_decode_padded(codes: jax.Array, omask: jax.Array, ovals: jax.Array, eb, levels: int, order: str):
    ndim = codes.ndim
    S = 1 << levels
    eb = jnp.asarray(eb, ovals.dtype)

    recon = jnp.zeros(codes.shape, ovals.dtype)
    coarse_sl = tuple(slice(0, None, S) for _ in range(ndim))
    recon = recon.at[coarse_sl].set(lorenzo_decode(codes[coarse_sl], eb, ovals.dtype))

    for s in _level_strides(levels):
        known = [2 * s] * ndim
        for axis in range(ndim):
            tgt = _axis_slices(ndim, axis, s, known)
            e = _even_grid(recon, axis, s, known)
            pred = _interp_pred(e, axis, order)
            rec = pred + codes[tgt].astype(ovals.dtype) * (2.0 * eb)
            rec = jnp.where(omask[tgt], ovals[tgt], rec)
            recon = recon.at[tgt].set(rec)
            known[axis] = s
    return recon


def _promote_stragglers(xp, codes, omask, ovals, eb, coarse, decode_fn):
    """Bound enforcement shared by the monolithic and tiled interp encoders.

    Re-derives the recon the *decoder* will produce and promotes any point
    past the bound (outside the coarse grid, which is Lorenzo-coded and
    exact) to an exact-valued outlier, until clean.  The loop terminates:
    each iteration strictly grows ``omask`` (promoted points decode exactly
    thereafter), which is bounded by the volume size; in practice it runs
    1-2 rounds.  On exit ``recon == decode_fn(codes, omask, ovals)`` and the
    bound holds on every promotable point.
    """
    recon = decode_fn(codes, omask, ovals)
    while True:
        bad = (jnp.abs(recon - xp) > eb) & ~omask & ~coarse
        if not bool(bad.any()):
            break
        omask = omask | bad
        ovals = jnp.where(bad, xp, ovals)
        recon = decode_fn(codes, omask, ovals)
    return omask, ovals, recon


def interp_encode(x: jax.Array, eb, order: str = "cubic", max_levels: int = 5):
    """Multi-level interpolation encode.

    Returns ``(codes, omask, ovals, recon, meta)`` where arrays live on the
    padded grid and ``meta = (orig_shape, padded_shape, levels)``.  ``recon``
    cropped to ``orig_shape`` satisfies the error bound.

    ``recon`` is the *decode program's* output, not the encoder's internal
    reconstruction: the two are separately jitted, so fusion differences can
    drift a few ulps apart — enough to push points sitting exactly at the
    bound past it at decompression.  Running the decoder here and promoting
    any straggler to an outlier makes the bound hold by construction on the
    artifact the decompressor actually sees.
    """
    levels = _num_levels(x.shape, max_levels)
    pshape = _padded_shape(x.shape, levels)
    xp = _pad_edge(x, pshape)
    codes, omask, ovals, recon = _interp_encode_padded(xp, eb, levels, order)
    # The coarse grid bypasses the outlier mechanism (Lorenzo-coded; decode
    # never consults omask there), so only interp targets are promotable.
    S = 1 << levels
    coarse = jnp.zeros(pshape, bool).at[tuple(slice(0, None, S) for _ in pshape)].set(True)
    omask, ovals, recon = _promote_stragglers(
        xp, codes, omask, ovals, eb, coarse,
        lambda c, m, v: _interp_decode_padded(c, m, v, eb, levels, order))
    meta = (tuple(x.shape), pshape, levels)
    return codes, omask, ovals, recon, meta


def interp_decode(codes, omask, ovals, eb, meta, order: str = "cubic"):
    orig_shape, _pshape, levels = meta
    recon = _interp_decode_padded(codes, omask, ovals, eb, levels, order)
    return recon[tuple(slice(0, d) for d in orig_shape)]


# ---------------------------------------------------------------------------
# Tile-predictor registry (docs/ARCHITECTURE.md)
# ---------------------------------------------------------------------------
#
# The tiled engine (repro.sz.tiled) treats every tile as an independent
# prediction domain and dispatches the per-tile transform through this
# registry instead of hardwiring a predictor.  A tile predictor provides
#
#   * ``plan(tile, max_levels)``            -> static per-tile config (levels),
#   * ``encode_tiles(tiles, eb, ...)``      -> (payload pytree, recon tiles),
#   * ``decode_tiles(payload, eb, ...)``    -> recon tiles,
#   * ``lane_bytes`` / ``parse_lane``       -> per-tile lane (de)serialization,
#
# where all payload leaves carry the tile batch on axis 0.  Decoding any
# subset of tiles must reproduce the exact bits the full batch would — the
# region==full bit-identity contract random-access decode relies on.  No op
# may mix tiles, AND any float decode must run through a compiled program
# that does not vary with the batch size (integer transforms are exact under
# any batching; float ones pin a fixed-width executable — see
# ``_INTERP_DECODE_CHUNK``).  Batched encode passes fan across the device
# mesh via ``repro.launch.sharding.map_tiles``.

# Canonical wire ids shared by the SZJX and GWTC containers.
PRED_IDS = {"lorenzo": 0, "interp": 1}
PRED_NAMES = {v: k for k, v in PRED_IDS.items()}
ORDER_IDS = {"linear": 0, "cubic": 1}
ORDER_NAMES = {v: k for k, v in ORDER_IDS.items()}

PREDICTORS: dict[str, "TilePredictor"] = {}


def register_predictor(pred: "TilePredictor") -> "TilePredictor":
    PREDICTORS[pred.name] = pred
    return pred


def get_predictor(name: str) -> "TilePredictor":
    try:
        return PREDICTORS[name]
    except KeyError:
        raise ValueError(
            f"unknown predictor {name!r} (registered: {sorted(PREDICTORS)})"
        ) from None


class TilePredictor:
    """Protocol for per-tile prediction transforms (see module comment)."""

    name: str

    def plan(self, tile: tuple[int, ...], max_levels: int = 5) -> int:
        """Static per-tile-shape config: interp level count (0 when unused)."""
        raise NotImplementedError

    def encode_tiles(self, tiles, eb, *, order: str, levels: int,
                     use_pallas: bool | None = None):
        """[B, *tile] -> (payload pytree of [B, ...] arrays, recon [B, *tile]).

        ``recon`` must be the *decode program's own output* so the bound holds
        by construction on what the decompressor reconstructs."""
        raise NotImplementedError

    def decode_tiles(self, payload, eb, *, tile: tuple[int, ...], order: str,
                     levels: int):
        """Payload pytree ([B, ...]) -> recon [B, *tile] float32."""
        raise NotImplementedError

    def decode_program_key(self, *, tile: tuple[int, ...], order: str,
                           levels: int) -> tuple:
        """Identity of the compiled decode program for one artifact geometry.

        The bucketed dispatcher (``tiled.dispatch_bucketed``) appends the
        bucket width, so each (key, width) pair names exactly one XLA
        executable — the serving layer's compile-cache accounting hangs off
        this.  Every static argument that changes the traced program MUST be
        in the key; batch size must NOT be (that is the bucket's job)."""
        return ("decode", self.name, tuple(tile), order, int(levels))

    def lane_bytes(self, payload, i: int, backend: str, *,
                   use_pallas: bool | None = None) -> bytes:
        """Serialize tile ``i`` of a host-side (numpy) payload to one lane.

        ``use_pallas`` routes the entropy pack through the device encode
        kernel (bytes are bit-identical either way)."""
        raise NotImplementedError

    def lane_bytes_batch(self, payload, n: int, backend: str, *,
                         use_pallas: bool | None = None) -> list[bytes]:
        """Serialize all ``n`` tiles of a payload.  The default loops
        :meth:`lane_bytes`; the streaming executor's device stage calls this
        so a predictor can batch the device encode across lanes."""
        return [self.lane_bytes(payload, i, backend, use_pallas=use_pallas)
                for i in range(n)]

    def parse_lane(self, blob: bytes, *, tile: tuple[int, ...], levels: int,
                   use_pallas: bool | None = None) -> dict:
        """Inverse of :meth:`lane_bytes`: one lane -> unbatched payload dict."""
        raise NotImplementedError


@register_predictor
class _LorenzoTiles(TilePredictor):
    """Prequant + integer Lorenzo per tile (carry cut at tile boundaries).

    Payload: ``{"codes": int32 [B, *tile]}``.  The transform is lossless on
    the prequantized grid, so the tiled reconstruction is bit-identical to
    the untiled ``predictor="lorenzo"`` path."""

    name = "lorenzo"

    def plan(self, tile, max_levels=5):
        return 0

    def encode_tiles(self, tiles, eb, *, order, levels, use_pallas=None):
        from repro.kernels import ops
        from repro.launch import sharding

        codes = sharding.map_tiles(
            lambda t: ops.lorenzo_quant_tiles_op(t, eb, use_pallas=use_pallas), tiles)
        payload = {"codes": codes}
        recon = self.decode_tiles(payload, eb, tile=tuple(tiles.shape[1:]),
                                  order=order, levels=levels)
        return payload, recon

    def decode_tiles(self, payload, eb, *, tile, order, levels):
        from repro.kernels import ops
        from repro.launch import sharding

        return sharding.map_tiles(
            lambda c: ops.lorenzo_decode_tiles_op(c, eb), payload["codes"])

    def lane_bytes(self, payload, i, backend, *, use_pallas=None):
        from repro.sz import entropy

        return entropy.encode_codes(payload["codes"][i], backend,
                                    use_pallas=use_pallas)

    def parse_lane(self, blob, *, tile, levels, use_pallas=None):
        from repro.sz import entropy

        return {"codes": entropy.decode_codes(blob, tile, use_pallas=use_pallas)}


# Interp lane layout (inside the GWTC container, docs/TILED_FORMAT.md):
#   n_out u32 | zlen u32 | zlib(idx u32[n_out] + val f32[n_out]) | RPRE codes
# Codes live on the per-tile *interp-padded* shape, derived from the
# container's (tile, levels) as ``_padded_shape(tile, levels)``.
_INTERP_LANE_HDR = struct.Struct("<II")


# Fixed decode batch width.  The compiled program a float computation runs
# through must not depend on how many tiles are being decoded: XLA fuses the
# interp chains differently at different batch sizes (and unrolls trip-1
# scans), which drifts ulps between a 1-tile region decode and an n-tile full
# decode.  Padding every decode batch to this fixed width means ONE vmapped
# executable serves every decode — same machine code per tile, so region and
# full decode are bit-identical by construction.  (The Lorenzo decode needs
# none of this: integer cumsum + one multiply cannot reassociate.)
_INTERP_DECODE_CHUNK = 4


@partial(jax.jit, static_argnames=("levels", "order"))
def _interp_decode_chunk(codes, omask, ovals, eb, levels: int, order: str):
    return jax.vmap(
        lambda c, m, v: _interp_decode_padded(c, m, v, eb, levels, order)
    )(codes, omask, ovals)


def _interp_decode_tiles_padded(codes, omask, ovals, eb, levels: int, order: str):
    """Chunked fixed-width decode of a [K, *pshape] payload (see
    ``_INTERP_DECODE_CHUNK`` for why the width is pinned)."""
    B = _INTERP_DECODE_CHUNK
    K = codes.shape[0]
    pad = (-K) % B
    if pad:
        ext = lambda a: jnp.concatenate([a, jnp.repeat(a[:1], pad, axis=0)])
        codes, omask, ovals = ext(codes), ext(omask), ext(ovals)
    out = [
        _interp_decode_chunk(codes[i : i + B], omask[i : i + B],
                             ovals[i : i + B], eb, levels, order)
        for i in range(0, K + pad, B)
    ]
    recon = out[0] if len(out) == 1 else jnp.concatenate(out)
    return recon[:K]


@register_predictor
class _InterpTiles(TilePredictor):
    """SZ3-style multi-level interpolation, vmapped over the tile batch.

    Payload: ``{"codes": int32, "omask": bool, "ovals": f32}`` on the
    per-tile interp-padded grid ([B, *padded_tile]).  Each tile is an
    independent prediction domain, so interp tiles decode standalone and the
    random-access contract holds exactly like the Lorenzo path."""

    name = "interp"

    def plan(self, tile, max_levels=5):
        return _num_levels(tile, max_levels)

    def encode_tiles(self, tiles, eb, *, order, levels, use_pallas=None):
        from repro.launch import sharding

        tile = tuple(tiles.shape[1:])
        pshape = _padded_shape(tile, levels)
        pads = [(0, 0)] + [(0, p - d) for d, p in zip(tile, pshape)]
        xp = jnp.pad(tiles, pads, mode="edge")

        enc = jax.vmap(lambda t: _interp_encode_padded(t, eb, levels, order))
        codes, omask, ovals, _ = sharding.map_tiles(enc, xp)

        S = 1 << levels
        coarse = jnp.zeros(pshape, bool).at[
            tuple(slice(0, None, S) for _ in pshape)].set(True)
        # Shared straggler promotion, batched over all tiles at once; the
        # decode runs through the same fixed-width executable decompression
        # uses, NOT a sharded full-batch program, so the recon contract holds.
        omask, ovals, recon = _promote_stragglers(
            xp, codes, omask, ovals, eb, coarse[None],
            lambda c, m, v: _interp_decode_tiles_padded(c, m, v, eb, levels, order))
        payload = {"codes": codes, "omask": omask, "ovals": ovals}
        crop = (slice(None),) + tuple(slice(0, d) for d in tile)
        return payload, recon[crop]

    def decode_tiles(self, payload, eb, *, tile, order, levels):
        # Deliberately NOT fanned through sharding.map_tiles: the decode must
        # run through the one fixed-width executable (_INTERP_DECODE_CHUNK)
        # on every call, or region and full decode would compile different
        # programs and drift ulps apart.
        recon = _interp_decode_tiles_padded(
            payload["codes"], payload["omask"], payload["ovals"], eb, levels, order)
        return recon[(slice(None),) + tuple(slice(0, d) for d in tile)]

    def lane_bytes(self, payload, i, backend, *, use_pallas=None):
        import zlib

        from repro.sz import entropy

        omask = payload["omask"][i]
        idx = np.flatnonzero(omask.ravel()).astype(np.uint32)
        val = payload["ovals"][i].ravel()[idx].astype(np.float32)
        out = zlib.compress(idx.tobytes() + val.tobytes(), 6)
        return (_INTERP_LANE_HDR.pack(idx.size, len(out)) + out
                + entropy.encode_codes(payload["codes"][i], backend,
                                       use_pallas=use_pallas))

    def parse_lane(self, blob, *, tile, levels, use_pallas=None):
        import zlib

        from repro.sz import entropy

        pshape = _padded_shape(tile, levels)
        n_out, zlen = _INTERP_LANE_HDR.unpack_from(blob, 0)
        off = _INTERP_LANE_HDR.size
        raw = zlib.decompress(blob[off : off + zlen])
        idx = np.frombuffer(raw, np.uint32, n_out).astype(np.int64)
        val = np.frombuffer(raw, np.float32, n_out, offset=4 * n_out)
        n = int(np.prod(pshape))
        omask = np.zeros(n, bool)
        ovals = np.zeros(n, np.float32)
        omask[idx] = True
        ovals[idx] = val
        return {
            "codes": entropy.decode_codes(blob[off + zlen :], pshape,
                                          use_pallas=use_pallas),
            "omask": omask.reshape(pshape),
            "ovals": ovals.reshape(pshape),
        }


# Instantiate the registered classes (the decorator stored the class; replace
# with a singleton instance so callers get bound methods).
for _name, _cls in list(PREDICTORS.items()):
    if isinstance(_cls, type):
        PREDICTORS[_name] = _cls()
del _name, _cls
