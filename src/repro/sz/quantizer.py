"""Error-controlled quantization primitives.

Two schemes, both guaranteeing |x - x'| <= eb pointwise:

1. *Prequantization* (cuSZ-style, used by the Lorenzo path): quantize the
   value itself onto a uniform grid of pitch 2*eb.  All downstream transforms
   (integer Lorenzo / cumsum) are lossless, so the bound holds exactly and
   every stage is embarrassingly parallel — this is the TPU adaptation of
   SZ's sequential reconstruction sweep (see DESIGN.md §3.1).

2. *Residual quantization* (used by the interpolation path): quantize the
   difference between the true value and a prediction computed from already-
   reconstructed values.  Codes outside ``[-OUTLIER_RADIUS, OUTLIER_RADIUS]``
   are flagged as outliers and their exact values stored verbatim.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# SZ-style quantization radius: codes live in (-R, R); |code| >= R means
# "unpredictable" -> store exact value.  2^15 keeps the Huffman alphabet sane.
OUTLIER_RADIUS = 1 << 15


def resolve_eb(x: jax.Array, rel_eb: float | None, abs_eb: float | None) -> float:
    """Resolve the absolute error bound from exactly one of rel_eb / abs_eb.

    Relative bounds scale by the value range; the guard rejects bounds so
    tight the prequantized grid index overflows f32-exact integers (shared
    by every compressor front end — untiled and tiled resolve identically)."""
    if (rel_eb is None) == (abs_eb is None):
        raise ValueError("pass exactly one of rel_eb / abs_eb")
    if rel_eb is not None:
        vrange = float(jnp.max(x) - jnp.min(x))
        abs_eb = rel_eb * max(vrange, np.finfo(np.float32).tiny)
    abs_eb = float(abs_eb)
    max_q = float(jnp.max(jnp.abs(x))) / (2.0 * abs_eb)
    if max_q >= 2**30:
        raise ValueError(
            f"eb={abs_eb:g} too small for data magnitude (q={max_q:.3g} >= 2^30)")
    return abs_eb


def prequantize(x: jax.Array, eb: float | jax.Array) -> jax.Array:
    """Quantize values onto a uniform grid of pitch ``2 * eb``.

    Returns int32 codes ``q`` with ``|x - 2*eb*q| <= eb``.  The caller must
    ensure ``max|x| / (2*eb) < 2**30`` (checked in :mod:`repro.sz.szjax`).
    """
    eb = jnp.asarray(eb, x.dtype)
    return jnp.rint(x / (2.0 * eb)).astype(jnp.int32)


def dequantize_pre(q: jax.Array, eb: float | jax.Array, dtype=jnp.float32) -> jax.Array:
    """Inverse of :func:`prequantize`."""
    eb = jnp.asarray(eb, dtype)
    return q.astype(dtype) * (2.0 * eb)


def quantize_residual(
    x: jax.Array, pred: jax.Array, eb: float | jax.Array
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Quantize ``x - pred`` with bound ``eb``.

    Returns ``(code, recon, is_outlier)``:
      * ``code``  int32 in (-R, R); 0 where outlier (outliers are coded
        separately so the entropy stage sees a dense alphabet),
      * ``recon`` the decompressor-visible reconstruction (``pred + 2*eb*code``
        in-bound, exact ``x`` at outliers — SZ stores outliers verbatim),
      * ``is_outlier`` bool mask.
    """
    eb = jnp.asarray(eb, x.dtype)
    diff = x - pred
    code = jnp.rint(diff / (2.0 * eb))
    is_outlier = jnp.abs(code) >= OUTLIER_RADIUS
    code = jnp.where(is_outlier, 0.0, code).astype(jnp.int32)
    recon = pred + code.astype(x.dtype) * (2.0 * eb)
    # Float rounding can nudge recon just past the bound; fall back to exact
    # storage there too (same mechanism, negligible count).
    bad = jnp.abs(recon - x) > eb
    is_outlier = is_outlier | bad
    code = jnp.where(bad, 0, code)
    recon = jnp.where(is_outlier, x, recon)
    return code, recon, is_outlier
