"""Error-bounded lossy compressor substrate (SZ3-class), JAX-native.

The transform core (prediction + error-controlled quantization) runs as pure
JAX; the entropy stage (Huffman / zlib bitstreams) runs on host, as in real
SZ GPU pipelines.
"""
from repro.sz.artifact import (
    Artifact,
    container_magics,
    from_bytes,
    register_container,
    sniff_magic,
)
from repro.sz.quantizer import (
    prequantize,
    dequantize_pre,
    quantize_residual,
    OUTLIER_RADIUS,
)
from repro.sz.predictor import (
    lorenzo_encode,
    lorenzo_decode,
    interp_encode,
    interp_decode,
    get_predictor,
    register_predictor,
    PREDICTORS,
)
from repro.sz.szjax import SZCompressor, SZCompressed, compress, decompress
from repro.sz.tiled import (
    TiledCompressed,
    compress_tiled,
    decompress_tiled,
    decompress_region,
)

__all__ = [
    "Artifact",
    "container_magics",
    "from_bytes",
    "register_container",
    "sniff_magic",
    "prequantize",
    "dequantize_pre",
    "quantize_residual",
    "OUTLIER_RADIUS",
    "lorenzo_encode",
    "lorenzo_decode",
    "interp_encode",
    "interp_decode",
    "get_predictor",
    "register_predictor",
    "PREDICTORS",
    "SZCompressor",
    "SZCompressed",
    "compress",
    "decompress",
    "TiledCompressed",
    "compress_tiled",
    "decompress_tiled",
    "decompress_region",
]
