"""rwkv6-7b [ssm]: 32L d4096 (attention-free) channel-mix ff 14336,
vocab 65536, head 64, data-dependent decay (Finch). [arXiv:2404.05892]"""
from repro.configs.base import LayerSpec, ModelConfig, RWKV6Config

FAMILY = "decoder"
LONG_CONTEXT_OK = True  # O(1) recurrent state


def get_config(reduced: bool = False) -> ModelConfig:
    if reduced:
        rwkv = RWKV6Config(d_model=64, head_dim=16, d_ff=128, lora_mix=8, lora_decay=8)
        return ModelConfig(
            name="rwkv6-smoke", n_layers=2, d_model=64, d_ff=128, vocab=512,
            rwkv=rwkv, pattern=tuple(LayerSpec(kind="rwkv6") for _ in range(2)),
        )
    rwkv = RWKV6Config(d_model=4096, head_dim=64, d_ff=14336)
    return ModelConfig(
        name="rwkv6-7b", n_layers=32, d_model=4096, d_ff=14336, vocab=65536,
        rwkv=rwkv, pattern=tuple(LayerSpec(kind="rwkv6") for _ in range(32)),
    )
