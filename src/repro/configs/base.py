"""Config helpers shared by the per-architecture files."""
from __future__ import annotations

from repro.models.attention import MASK_CAUSAL, MASK_CHUNKED, MASK_SLIDING, AttnConfig, MLAConfig
from repro.models.decoder import LayerSpec, ModelConfig, default_pattern
from repro.models.mlp import MoEConfig
from repro.models.ssm import Mamba2Config, RWKV6Config

__all__ = [
    "AttnConfig", "MLAConfig", "MoEConfig", "Mamba2Config", "RWKV6Config",
    "LayerSpec", "ModelConfig", "default_pattern",
    "MASK_CAUSAL", "MASK_SLIDING", "MASK_CHUNKED",
]
