"""llama4-scout-17b-a16e [moe]: 48L d5120 40H (GQA kv=8), MoE 16e top-1 +
shared expert (ff 8192 each), iRoPE: every 4th layer NoPE-global, others
chunked-local(8192).  Early-fusion frontend is outside the assigned backbone.
[hf:meta-llama/Llama-4-Scout-17B-16E]"""
from repro.configs.base import (
    MASK_CAUSAL, MASK_CHUNKED, AttnConfig, LayerSpec, ModelConfig, MoEConfig,
)

FAMILY = "decoder"
LONG_CONTEXT_OK = True  # chunked-local dominant; sparse NoPE-global layers
                        # sequence-sharded at long context


def _pattern(n_layers: int, chunk: int) -> tuple:
    specs = []
    for i in range(n_layers):
        if i % 4 == 3:  # NoPE global
            specs.append(LayerSpec(mask_mode=MASK_CAUSAL, rope_on=False, moe=True))
        else:
            specs.append(LayerSpec(mask_mode=MASK_CHUNKED, window=chunk, rope_theta=5e5, moe=True))
    return tuple(specs)


def get_config(reduced: bool = False) -> ModelConfig:
    if reduced:
        attn = AttnConfig(n_heads=4, n_kv_heads=2, head_dim=16, d_model=64)
        moe = MoEConfig(n_experts=4, top_k=1, d_ff=64, n_shared=1, shared_d_ff=64,
                        capacity_factor=4.0)
        return ModelConfig(
            name="llama4-scout-smoke", n_layers=4, d_model=64, d_ff=64, vocab=512,
            attn=attn, moe=moe, pattern=_pattern(4, 8),
        )
    attn = AttnConfig(n_heads=40, n_kv_heads=8, head_dim=128, d_model=5120)
    moe = MoEConfig(n_experts=16, top_k=1, d_ff=8192, n_shared=1, shared_d_ff=8192)
    return ModelConfig(
        name="llama4-scout-17b-a16e", n_layers=48, d_model=5120, d_ff=8192, vocab=202048,
        attn=attn, moe=moe, pattern=_pattern(48, 8192),
    )
