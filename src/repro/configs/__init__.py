"""Assigned-architecture registry: --arch <id> resolves here."""
from __future__ import annotations

import importlib

ARCH_IDS = (
    "granite-3-8b",
    "yi-9b",
    "gemma3-1b",
    "llama3-405b",
    "deepseek-v3-671b",
    "llama4-scout-17b-a16e",
    "rwkv6-7b",
    "whisper-small",
    "zamba2-1.2b",
    "qwen2-vl-7b",
)

_MODULES = {
    "granite-3-8b": "granite_3_8b",
    "yi-9b": "yi_9b",
    "gemma3-1b": "gemma3_1b",
    "llama3-405b": "llama3_405b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "rwkv6-7b": "rwkv6_7b",
    "whisper-small": "whisper_small",
    "zamba2-1.2b": "zamba2_1_2b",
    "qwen2-vl-7b": "qwen2_vl_7b",
}


def arch_module(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")


def get_config(arch_id: str, reduced: bool = False):
    return arch_module(arch_id).get_config(reduced=reduced)


def get_family(arch_id: str) -> str:
    return arch_module(arch_id).FAMILY


def long_context_ok(arch_id: str) -> bool:
    return arch_module(arch_id).LONG_CONTEXT_OK


def build_model(arch_id: str, reduced: bool = False):
    """Returns (model, cfg) for the arch."""
    cfg = get_config(arch_id, reduced=reduced)
    fam = get_family(arch_id)
    if fam == "decoder":
        from repro.models.decoder import DecoderLM

        return DecoderLM(cfg), cfg
    if fam == "encdec":
        from repro.models.encdec import EncDecLM

        return EncDecLM(cfg), cfg
    raise ValueError(fam)
