"""zamba2-1.2b [hybrid]: 38 Mamba2 layers (d2048, ssm_state=64) + ONE shared
transformer block (32H kv32 + ff8192) invoked every 6th layer with shared
weights (per-invocation LoRA omitted; DESIGN.md §4). [arXiv:2411.15242]"""
from repro.configs.base import AttnConfig, LayerSpec, Mamba2Config, ModelConfig

FAMILY = "decoder"
LONG_CONTEXT_OK = True  # Mamba2 state + sequence-sharded shared-attn KV


def _pattern(n_layers: int, every: int) -> tuple:
    specs = []
    for i in range(n_layers):
        if (i + 1) % every == 0:
            specs.append(LayerSpec(kind="shared_attn"))
        else:
            specs.append(LayerSpec(kind="mamba2", has_ffn=False))
    return tuple(specs)


def get_config(reduced: bool = False) -> ModelConfig:
    if reduced:
        mamba = Mamba2Config(d_model=64, d_state=16, head_dim=16)
        attn = AttnConfig(n_heads=4, n_kv_heads=4, head_dim=16, d_model=64)
        return ModelConfig(
            name="zamba2-smoke", n_layers=4, d_model=64, d_ff=128, vocab=512,
            mamba=mamba, attn=attn, shared_block=True, shared_d_ff=128,
            pattern=_pattern(4, 2),
        )
    mamba = Mamba2Config(d_model=2048, d_state=64, head_dim=64)
    attn = AttnConfig(n_heads=32, n_kv_heads=32, head_dim=64, d_model=2048)
    return ModelConfig(
        name="zamba2-1.2b", n_layers=38, d_model=2048, d_ff=8192, vocab=32000,
        mamba=mamba, attn=attn, shared_block=True, shared_d_ff=8192,
        pattern=_pattern(38, 6),
    )
