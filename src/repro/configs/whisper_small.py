"""whisper-small [audio]: 12L enc + 12L dec, d768 12H ff3072 vocab 51865.
Conv/mel frontend is a STUB: input_specs() provides precomputed frame
embeddings [B, 1500, 768]. [arXiv:2212.04356]"""
from repro.configs.base import AttnConfig, ModelConfig, default_pattern

FAMILY = "encdec"
LONG_CONTEXT_OK = False
ENC_SEQ = 1500


def get_config(reduced: bool = False) -> ModelConfig:
    if reduced:
        attn = AttnConfig(n_heads=4, n_kv_heads=4, head_dim=16, d_model=64)
        return ModelConfig(
            name="whisper-small-smoke", n_layers=2, d_model=64, d_ff=128, vocab=512,
            attn=attn, act="gelu", norm="layer", enc_layers=2, enc_seq=32,
            pattern=default_pattern(2),
        )
    attn = AttnConfig(n_heads=12, n_kv_heads=12, head_dim=64, d_model=768)
    return ModelConfig(
        name="whisper-small", n_layers=12, d_model=768, d_ff=3072, vocab=51865,
        attn=attn, act="gelu", norm="layer", enc_layers=12, enc_seq=ENC_SEQ,
        pattern=default_pattern(12),
    )
