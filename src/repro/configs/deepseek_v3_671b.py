"""deepseek-v3-671b [moe]: 61L d7168 128H MLA, 1 shared + 256 routed top-8,
expert ff 2048, first 3 layers dense (ff 18432), sigmoid router, vocab 129280.
MTP head available behind a config flag (off in dry-run shapes).
[arXiv:2412.19437]"""
from repro.configs.base import AttnConfig, LayerSpec, MLAConfig, ModelConfig, MoEConfig

FAMILY = "decoder"
LONG_CONTEXT_OK = False  # MLA is still dense softmax over all positions


def _pattern(n_layers: int, first_dense: int) -> tuple:
    return tuple(
        LayerSpec(kind="mla", moe=(i >= first_dense)) for i in range(n_layers)
    )


def get_config(reduced: bool = False) -> ModelConfig:
    if reduced:
        mla = MLAConfig(q_lora=32, kv_lora=16, rope_dim=8, nope_dim=16, v_dim=16)
        attn = AttnConfig(n_heads=4, n_kv_heads=4, head_dim=24, d_model=64, mla=mla)
        moe = MoEConfig(n_experts=4, top_k=2, d_ff=32, n_shared=1, shared_d_ff=32,
                        router="sigmoid", first_dense=1, capacity_factor=4.0)
        return ModelConfig(
            name="deepseek-v3-smoke", n_layers=3, d_model=64, d_ff=128, vocab=512,
            attn=attn, moe=moe, pattern=_pattern(3, 1),
        )
    mla = MLAConfig(q_lora=1536, kv_lora=512, rope_dim=64, nope_dim=128, v_dim=128)
    attn = AttnConfig(n_heads=128, n_kv_heads=128, head_dim=192, d_model=7168, mla=mla)
    moe = MoEConfig(n_experts=256, top_k=8, d_ff=2048, n_shared=1, shared_d_ff=2048,
                    router="sigmoid", first_dense=3)
    return ModelConfig(
        name="deepseek-v3-671b", n_layers=61, d_model=7168, d_ff=18432, vocab=129280,
        attn=attn, moe=moe, pattern=_pattern(61, 3),
    )
