"""gemma3-1b [dense]: 26L d1152 4H (GQA kv=1) ff6912 vocab 262144.
5:1 sliding(512):global pattern, qk-norm, dual rope theta (10k local / 1M
global), tied embeddings, sqrt(d) embedding scale. [hf:google/gemma-3-1b-pt]"""
from repro.configs.base import (
    MASK_CAUSAL, MASK_SLIDING, AttnConfig, LayerSpec, ModelConfig,
)

FAMILY = "decoder"
LONG_CONTEXT_OK = True  # sliding-window dominant; sparse global layers are
                        # sequence-sharded at long context (DESIGN.md §4)

_WINDOW = 512


def _pattern(n_layers: int, window: int) -> tuple:
    specs = []
    for i in range(n_layers):
        if (i + 1) % 6 == 0:  # every 6th layer: global full attention
            specs.append(LayerSpec(mask_mode=MASK_CAUSAL, rope_theta=1e6))
        else:
            specs.append(LayerSpec(mask_mode=MASK_SLIDING, window=window, rope_theta=1e4))
    return tuple(specs)


def get_config(reduced: bool = False) -> ModelConfig:
    if reduced:
        attn = AttnConfig(n_heads=4, n_kv_heads=1, head_dim=16, d_model=64, qk_norm=True)
        return ModelConfig(
            name="gemma3-1b-smoke", n_layers=6, d_model=64, d_ff=128, vocab=512,
            attn=attn, tie_embeddings=True, emb_scale=True, pattern=_pattern(6, 8),
        )
    attn = AttnConfig(n_heads=4, n_kv_heads=1, head_dim=256, d_model=1152, qk_norm=True)
    return ModelConfig(
        name="gemma3-1b", n_layers=26, d_model=1152, d_ff=6912, vocab=262144,
        attn=attn, tie_embeddings=True, emb_scale=True, pattern=_pattern(26, _WINDOW),
    )
