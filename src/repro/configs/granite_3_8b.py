"""granite-3-8b [dense]: 40L d4096 32H (GQA kv=8) ff12800 vocab 49155.
[hf:ibm-granite/granite-3.0; GQA, tied embeddings]"""
from repro.configs.base import AttnConfig, ModelConfig, default_pattern

FAMILY = "decoder"
LONG_CONTEXT_OK = False  # pure full attention -> skip long_500k (DESIGN.md §4)


def get_config(reduced: bool = False) -> ModelConfig:
    if reduced:
        attn = AttnConfig(n_heads=4, n_kv_heads=2, head_dim=16, d_model=64, rope_theta=1e4)
        return ModelConfig(
            name="granite-3-8b-smoke", n_layers=2, d_model=64, d_ff=128, vocab=512,
            attn=attn, tie_embeddings=True,
            pattern=default_pattern(2, rope_theta=1e4),
        )
    attn = AttnConfig(n_heads=32, n_kv_heads=8, head_dim=128, d_model=4096, rope_theta=1e4)
    return ModelConfig(
        name="granite-3-8b", n_layers=40, d_model=4096, d_ff=12800, vocab=49155,
        attn=attn, tie_embeddings=True,
        pattern=default_pattern(40, rope_theta=1e4),
    )
