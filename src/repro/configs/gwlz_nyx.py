"""gwlz-nyx: the paper's own workload as a production-mesh cell.

512^3 Nyx field, 32 enhancer groups (paper uses 20; padded to the model-axis
multiple), group axis -> "model", slice batch -> "data"/"pod".  Used by
``python -m repro.launch.dryrun --arch gwlz-nyx`` and hillclimbed in
EXPERIMENTS.md §Perf cell 4.
"""
from repro.launch.gwlz_dist import DistGWLZConfig

FAMILY = "gwlz"
LONG_CONTEXT_OK = False


def get_config(reduced: bool = False) -> DistGWLZConfig:
    if reduced:
        return DistGWLZConfig(n_groups=4, volume=32, batch_slices=8)
    return DistGWLZConfig(n_groups=32, volume=512, batch_slices=512)
