"""llama3-405b [dense]: 126L d16384 128H (GQA kv=8) ff53248 vocab 128256.
[arXiv:2407.21783]"""
from repro.configs.base import AttnConfig, ModelConfig, default_pattern

FAMILY = "decoder"
LONG_CONTEXT_OK = False


def get_config(reduced: bool = False) -> ModelConfig:
    if reduced:
        attn = AttnConfig(n_heads=8, n_kv_heads=2, head_dim=16, d_model=128, rope_theta=5e5)
        return ModelConfig(
            name="llama3-405b-smoke", n_layers=3, d_model=128, d_ff=256, vocab=512,
            attn=attn, pattern=default_pattern(3, rope_theta=5e5),
        )
    attn = AttnConfig(n_heads=128, n_kv_heads=8, head_dim=128, d_model=16384, rope_theta=5e5)
    return ModelConfig(
        name="llama3-405b", n_layers=126, d_model=16384, d_ff=53248, vocab=128256,
        attn=attn, pattern=default_pattern(126, rope_theta=5e5),
    )
