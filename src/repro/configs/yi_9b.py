"""yi-9b [dense]: 48L d4096 32H (GQA kv=4) ff11008 vocab 64000 (llama arch).
[arXiv:2403.04652]"""
from repro.configs.base import AttnConfig, ModelConfig, default_pattern

FAMILY = "decoder"
LONG_CONTEXT_OK = False


def get_config(reduced: bool = False) -> ModelConfig:
    if reduced:
        attn = AttnConfig(n_heads=4, n_kv_heads=2, head_dim=16, d_model=64, rope_theta=5e6)
        return ModelConfig(
            name="yi-9b-smoke", n_layers=2, d_model=64, d_ff=128, vocab=512,
            attn=attn, pattern=default_pattern(2, rope_theta=5e6),
        )
    attn = AttnConfig(n_heads=32, n_kv_heads=4, head_dim=128, d_model=4096, rope_theta=5e6)
    return ModelConfig(
        name="yi-9b", n_layers=48, d_model=4096, d_ff=11008, vocab=64000,
        attn=attn, pattern=default_pattern(48, rope_theta=5e6),
    )
