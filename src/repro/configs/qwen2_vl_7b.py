"""qwen2-vl-7b [vlm]: 28L d3584 28H (GQA kv=4) ff18944 vocab 152064, M-RoPE
sections (16,24,24).  Vision frontend is a STUB: input_specs() provides the
3-stream positions; patch embeddings enter as ordinary tokens.
[arXiv:2409.12191]"""
from repro.configs.base import AttnConfig, ModelConfig, default_pattern

FAMILY = "decoder"
LONG_CONTEXT_OK = False
MROPE = (16, 24, 24)


def get_config(reduced: bool = False) -> ModelConfig:
    if reduced:
        attn = AttnConfig(n_heads=4, n_kv_heads=2, head_dim=16, d_model=64,
                          mrope_sections=(2, 3, 3), mrope_theta=1e6)
        return ModelConfig(
            name="qwen2-vl-smoke", n_layers=2, d_model=64, d_ff=128, vocab=512,
            attn=attn, pattern=default_pattern(2),
        )
    attn = AttnConfig(n_heads=28, n_kv_heads=4, head_dim=128, d_model=3584,
                      mrope_sections=MROPE, mrope_theta=1e6)
    return ModelConfig(
        name="qwen2-vl-7b", n_layers=28, d_model=3584, d_ff=18944, vocab=152064,
        attn=attn, pattern=default_pattern(28),
    )
