"""Shell front door over the ``repro.api`` façade.

    python -m repro.cli compress   IN OUT [--eb 1e-3 | --abs-eb X] [--tiled]
                                   [--tile 32] [--predictor interp|lorenzo]
                                   [--order linear|cubic] [--backend ...]
                                   [--enhance --groups 8 --epochs 60]
                                   [--stream --mem-budget 256M]
    python -m repro.cli decompress IN OUT.npy [--field NAME]
    python -m repro.cli info       PATH
    python -m repro.cli region     PATH --roi "8:40,:,16:32" [--out OUT.npy]
                                   [--field NAME]
    python -m repro.cli verify     PATH [--field NAME]
    python -m repro.cli serve      [NAME=]PATH ... [--port 8177]
                                   [--cache-bytes 256M] [--mem-budget 256M]
                                   [--on-corrupt raise|quarantine] [--smoke]
    python -m repro.cli lint       [--json] [--rule RAnnn ...] [--root DIR]
                                   [--baseline PATH [--write-baseline]]

``compress IN`` takes a ``.npy`` volume, or the sentinel
``synthetic:<field>[:<side>]`` (e.g. ``synthetic:temperature:24``) for a
generated Nyx-like field — the form CI's smoke step uses.  ``--stream``
routes through the bounded-memory out-of-core executor
(docs/STREAMING.md): ``.npy`` inputs are memory-mapped and compressed
tile-batch by tile-batch against the ``--mem-budget`` byte cap, always into
the tiled ``GWTC`` container; ``--retries`` sets the per-batch retry
budget for transient faults and ``--resume`` continues an interrupted
stream from its commit journal (docs/ROBUSTNESS.md).  ``verify`` checks a
container end to end — envelope structure, metadata checksum, and every
lane CRC — and exits nonzero on the first corruption.  Every subcommand
works on whatever envelope ``api.open`` can sniff
(``SZJX``/``GWTC``/``GWDS``); ``--field`` selects a field from multi-field
datasets.  ``serve`` runs the multi-tenant region-decode daemon over the
named volumes behind one shared tile cache (docs/SERVING.md).  ``lint``
runs the AST static-analysis suite (RA001–RA005, docs/ANALYSIS.md) over
the repro tree and is CI's tier-1 analysis gate.

Exit codes are uniform across subcommands: **0** success, **1** integrity
failure (corrupt container / failed CRC), **2** usage error (bad
arguments, missing files or fields, invalid ROI).
"""
from __future__ import annotations

import argparse
import sys

import numpy as np

from repro import api

# Uniform exit codes (see module docstring): raise SystemExit(EXIT_*) via
# _fail so every subcommand reports failures the same way.
EXIT_OK = 0
EXIT_INTEGRITY = 1
EXIT_USAGE = 2


def _fail(what: str, msg, code: int = EXIT_USAGE) -> SystemExit:
    """Print a clean one-line error and return the SystemExit to raise."""
    print(f"{what}: {msg}", file=sys.stderr)
    return SystemExit(code)


def _open(path, what: str, **kw):
    """api.open with CLI-grade errors: missing/unreadable files are usage
    errors (exit 2), corrupt containers are integrity errors (exit 1)."""
    from repro.errors import IntegrityError

    try:
        return api.open(path, **kw)
    except OSError as e:
        raise _fail(what, f"cannot open {path!r}: {e.strerror or e}")
    except IntegrityError as e:
        print(f"CORRUPT: {e}", file=sys.stderr)
        raise SystemExit(EXIT_INTEGRITY) from None


def parse_size(text: str) -> int:
    """'256M' / '64K' / '2G' / '1048576' -> bytes."""
    t = text.strip().upper().removesuffix("B")
    mult = {"K": 1 << 10, "M": 1 << 20, "G": 1 << 30}.get(t[-1:] or "", None)
    if mult is not None:
        t = t[:-1]
    try:
        return int(float(t) * (mult or 1))
    except ValueError:
        raise ValueError(f"bad size {text!r} (expected e.g. 256M, 64K, 1G)") from None


def parse_roi(text: str) -> tuple:
    """'8:40,:,16:32' -> tuple of slices/ints (start:stop:step per axis)."""
    out = []
    for tok in text.split(","):
        tok = tok.strip()
        if ":" in tok:
            parts = [p.strip() for p in tok.split(":")]
            if len(parts) > 3:
                raise ValueError(f"bad roi axis {tok!r}")
            vals = [int(p) if p else None for p in parts] + [None] * (3 - len(parts))
            out.append(slice(*vals))
        elif tok:
            out.append(int(tok))
        else:
            raise ValueError(f"empty roi axis in {text!r}")
    return tuple(out)


def _load_volume(spec: str) -> np.ndarray:
    if spec.startswith("synthetic:"):
        parts = spec.split(":")
        field = parts[1] if len(parts) > 1 and parts[1] else "temperature"
        side = int(parts[2]) if len(parts) > 2 else 32
        from repro.data import nyx_like_field

        return np.asarray(nyx_like_field((side,) * 3, field, seed=1))
    try:
        return np.load(spec)
    except OSError as e:
        raise _fail("compress", f"cannot load {spec!r}: {e}") from None


def _select(obj, field: str | None, what: str):
    """Resolve api.open output (+ optional --field) to one volume handle."""
    if isinstance(obj, api.Dataset):
        if field is None:
            if len(obj) == 1:
                return obj[next(iter(obj))]
            raise _fail(what, f"GWDS dataset has fields {list(obj)}; "
                              "pick one with --field")
        if field not in obj:
            raise _fail(what, f"no field {field!r} in dataset "
                              f"(fields: {list(obj)})")
        return obj[field]
    if field is not None:
        raise _fail(what, "--field only applies to GWDS datasets")
    return obj


def cmd_compress(args) -> int:
    enhance: bool | object = False
    if args.enhance:
        from repro.core.trainer import GWLZTrainConfig

        enhance = GWLZTrainConfig(n_groups=args.groups, epochs=args.epochs,
                                  min_group_pixels=args.min_group_pixels)
    if args.stream:
        try:
            budget = parse_size(args.mem_budget)
        except ValueError as e:
            raise _fail("compress", e) from None
        # .npy paths stream straight off the memmap; synthetic fields are
        # generated in memory (they exist for smoke tests, not scale)
        source = args.input if args.input.endswith(".npy") else _load_volume(args.input)
        from repro.exec import as_source

        src = as_source(source)
        retry = None
        if args.retries is not None:
            from repro.runtime.fault import RetryPolicy

            retry = RetryPolicy(max_attempts=max(1, args.retries))
        rep = api.compress_stream(
            src, args.output, eb=args.eb, abs_eb=args.abs_eb,
            tile=(args.tile,) * len(src.shape), mem_budget=budget,
            predictor=args.predictor, order=args.order, backend=args.backend,
            enhance=enhance, resume=args.resume, retry=retry)
        raw = int(np.prod(rep.shape)) * 4
        fault = ""
        if rep.retries:
            fault = (f"; {rep.retries} retr"
                     f"{'y' if rep.retries == 1 else 'ies'} on batches "
                     f"{list(rep.failed_batches)}")
        if rep.resumed_batches:
            fault += f"; resumed past {rep.resumed_batches} committed batches"
        print(f"streamed {args.output}: {rep.nbytes} bytes "
              f"(cr {raw / rep.nbytes:.1f}x) in {rep.n_batches} batches of "
              f"{rep.batch_tiles} tiles; peak {rep.peak_tracked_bytes / 2**20:.1f} "
              f"MiB tracked of {rep.mem_budget / 2**20:.1f} MiB budget"
              + (", enhanced" if rep.enhanced else "") + fault)
        return 0
    if args.resume:
        raise _fail("compress", "--resume requires --stream")
    x = _load_volume(args.input)
    vol = api.compress(
        x, eb=args.eb, abs_eb=args.abs_eb, tiled=args.tiled,
        tile=(args.tile,) * x.ndim, enhance=enhance,
        predictor=args.predictor, order=args.order, backend=args.backend)
    n = api.save(args.output, vol)
    print(f"wrote {args.output}: {n} bytes ({vol!r}, cr {x.nbytes / n:.1f}x)")
    if vol.train_stats is not None:
        s = vol.train_stats
        print(f"enhanced: PSNR {s.psnr_sz:.2f} -> {s.psnr_gwlz:.2f} dB "
              f"(overhead {s.overhead:.4f}x)")
    return 0


def cmd_decompress(args) -> int:
    vol = _select(_open(args.input, "decompress"), args.field, "decompress")
    arr = np.asarray(vol)
    np.save(args.output, arr)
    print(f"wrote {args.output}: shape {arr.shape} dtype {arr.dtype} "
          f"(eb_abs {vol.eb_abs:.4g})")
    return 0


def cmd_info(args) -> int:
    obj = _open(args.path, "info")
    if isinstance(obj, api.Dataset):
        print(f"GWDS dataset: {len(obj)} fields, {obj.nbytes} bytes "
              f"(index {obj.size_report()['index']} B)")
        for name in obj:
            print(f"  {name}: {obj[name]!r}")
        return 0
    print(repr(obj))
    art = obj.artifact
    if obj.tiled:
        print(f"  tile {art.tile} grid {art.grid} ({art.n_tiles} lanes), "
              f"predictor {art.predictor}, backend {art.backend}")
    else:
        print(f"  predictor {art.predictor}, order {art.order}, "
              f"levels {art.levels}")
    for k, v in obj.size_report().items():
        print(f"  {k}: {v}")
    return 0


def cmd_region(args) -> int:
    from repro.errors import IntegrityError

    vol = _select(_open(args.path, "region"), args.field, "region")
    try:
        roi = parse_roi(args.roi)
    except ValueError as e:
        raise _fail("region", f"bad --roi {args.roi!r}: {e}") from None
    try:
        lanes, total = api.region_lane_count(vol, roi)
        block = vol[roi]
    except IntegrityError as e:
        print(f"CORRUPT: {e}", file=sys.stderr)
        return EXIT_INTEGRITY
    except (IndexError, ValueError) as e:
        # covers out-of-bounds ROIs and reads through a closed handle — a
        # clean one-line usage error, never a traceback
        raise _fail("region", f"--roi {args.roi!r} invalid for shape "
                              f"{vol.shape}: {e}") from None
    rng = (f"min {block.min():.5g} max {block.max():.5g}" if block.size
           else "empty")
    print(f"roi {args.roi} -> shape {block.shape}, decoded {lanes}/{total} lanes, "
          f"{rng}")
    if args.out:
        np.save(args.out, block)
        print(f"wrote {args.out}")
    return 0


def cmd_verify(args) -> int:
    from repro.errors import IntegrityError

    obj = _open(args.path, "verify", verify="full")
    with obj:
        if isinstance(obj, api.Dataset):
            names = [args.field] if args.field else list(obj)
            try:
                for name in names:
                    if name not in obj:
                        raise _fail("verify", f"no field {name!r} in dataset "
                                              f"(fields: {list(obj)})")
                    vol = obj[name]  # field parse + full lane verification
                    lanes = vol.stats.tiles_total if vol.tiled else 1
                    print(f"ok: field {name!r} ({lanes} lanes)")
            except IntegrityError as e:
                print(f"CORRUPT: field {name!r}: {e}", file=sys.stderr)
                return EXIT_INTEGRITY
            return EXIT_OK
        if args.field is not None:
            raise _fail("verify", "--field only applies to GWDS datasets")
        art = obj.artifact
        checked = getattr(art, "lane_crcs", None)
        note = (f"{art.n_tiles} lane CRCs checked" if checked is not None
                else "no per-lane checksums (pre-checksum container); "
                     "structural checks only")
        print(f"ok: {args.path} ({note})")
    return EXIT_OK


def cmd_serve(args) -> int:
    from repro import serve as _serve

    volumes: dict[str, str] = {}
    for spec in args.volumes:
        name, sep, path = spec.partition("=")
        if not sep:
            name, path = None, spec
        if name is None:  # default name: file stem ("nyx.gwtc" -> "nyx")
            import os

            name = os.path.splitext(os.path.basename(path))[0]
        if not name:
            raise _fail("serve", f"empty volume name in {spec!r}")
        if name in volumes:
            raise _fail("serve", f"duplicate volume name {name!r} "
                                 "(use NAME=PATH to disambiguate)")
        volumes[name] = path
    try:
        cache_bytes = parse_size(args.cache_bytes)
        mem_budget = parse_size(args.mem_budget)
    except ValueError as e:
        raise _fail("serve", e) from None
    try:
        server = _serve.RegionServer(
            volumes, host=args.host, port=args.port, cache_bytes=cache_bytes,
            mem_budget=mem_budget, max_queue=args.max_queue,
            on_corrupt=args.on_corrupt,
            batch_wait_ms=None if args.no_batcher else args.batch_wait_ms)
    except OSError as e:
        raise _fail("serve", f"cannot start: {e.strerror or e}")
    except api.IntegrityError as e:
        print(f"CORRUPT: {e}", file=sys.stderr)
        return EXIT_INTEGRITY
    with server:
        print(f"serving {sorted(server.pool.names)} on {server.url} "
              f"(cache {cache_bytes >> 20} MiB, budget {mem_budget >> 20} MiB)",
              flush=True)
        if args.smoke:
            return _serve_smoke(server)
        try:
            server._thread.join()
        except KeyboardInterrupt:
            print("shutting down", file=sys.stderr)
        return EXIT_OK


def _serve_smoke(server) -> int:
    """--smoke: exercise every endpoint over real HTTP from this process —
    a repeated ROI must be served from the shared cache — then exit.  CI's
    serve smoke step and the tests run this instead of a daemonized run."""
    from repro.serve import fetch_json, fetch_region

    url = server.url
    assert fetch_json(url, "/healthz")["status"] == "ok"
    name = sorted(server.pool.names)[0]
    info = fetch_json(url, f"/v/{name}/info")
    hi = min(8, info["shape"][0])
    roi = f"0:{hi}" + ",:" * (len(info["shape"]) - 1)
    a, meta1 = fetch_region(url, name, roi)
    b, meta2 = fetch_region(url, name, roi)  # identical ROI: cache must hit
    if not np.array_equal(a, b):
        print("smoke: repeated ROI decoded differently", file=sys.stderr)
        return EXIT_INTEGRITY
    m = fetch_json(url, "/metrics")
    hit_rate = m["cache"]["hit_rate"]
    if not (hit_rate > 0):
        print(f"smoke: expected cache hits on a repeated ROI, got {m['cache']}",
              file=sys.stderr)
        return EXIT_INTEGRITY
    print(f"smoke ok: {meta2['lanes']}/{meta2['lanes_total']} lanes, "
          f"hit_rate {hit_rate:.2f}, p99 "
          f"{m['latency_ms'].get('p99', 0):.1f} ms over {m['requests']} requests")
    return EXIT_OK


def cmd_lint(args) -> int:
    from pathlib import Path

    from repro.analysis import run_analysis
    from repro.analysis.engine import all_rules, default_root
    from repro.analysis.report import (apply_baseline, load_baseline,
                                       render_json, render_text)

    root = Path(args.root).resolve() if args.root else default_root()
    try:
        findings = run_analysis(root=root, rules=args.rule or None)
    except ValueError as e:
        raise _fail("lint", e) from None
    rules = list(dict.fromkeys(args.rule)) if args.rule else sorted(all_rules())
    files = sum(1 for p in root.rglob("*.py") if "__pycache__" not in p.parts)

    if args.baseline and args.write_baseline:
        Path(args.baseline).write_text(render_json(
            findings, root=str(root), files=files, rules=rules) + "\n")
        print(f"lint: wrote baseline with {len(findings)} finding(s) "
              f"to {args.baseline}", file=sys.stderr)
        return EXIT_OK
    if args.write_baseline:
        raise _fail("lint", "--write-baseline needs --baseline PATH")
    if args.baseline:
        try:
            accepted = load_baseline(Path(args.baseline).read_text())
        except (OSError, ValueError, KeyError, TypeError) as e:
            raise _fail("lint", f"cannot read baseline {args.baseline!r}: {e}")
        findings = apply_baseline(findings, accepted)

    render = render_json if args.json else render_text
    print(render(findings, root=str(root), files=files, rules=rules))
    return EXIT_INTEGRITY if findings else EXIT_OK


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(prog="repro.cli", description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    sub = ap.add_subparsers(dest="cmd", required=True)

    c = sub.add_parser("compress", help="compress a .npy (or synthetic:) volume")
    c.add_argument("input", help=".npy path or synthetic:<field>[:<side>]")
    c.add_argument("output")
    c.add_argument("--eb", type=float, default=None, help="relative error bound")
    c.add_argument("--abs-eb", type=float, default=None, help="absolute error bound")
    c.add_argument("--tiled", action="store_true", help="GWTC tiled container")
    c.add_argument("--tile", type=int, default=64, help="tile side (tiled only)")
    c.add_argument("--predictor", default="interp", choices=["interp", "lorenzo"])
    c.add_argument("--order", default="cubic", choices=["linear", "cubic"])
    c.add_argument("--backend", default="huffman+zlib",
                   choices=["zlib", "huffman", "huffman+zlib"])
    c.add_argument("--stream", action="store_true",
                   help="bounded-memory out-of-core compress (GWTC container)")
    c.add_argument("--mem-budget", default="256M",
                   help="streaming byte budget, e.g. 64M / 512K / 1G")
    c.add_argument("--resume", action="store_true",
                   help="continue an interrupted --stream run from its "
                        "commit journal (<output>.journal)")
    c.add_argument("--retries", type=int, default=None,
                   help="per-batch retry attempts for transient faults "
                        "(default: 3)")
    c.add_argument("--enhance", action="store_true",
                   help="train + attach group-wise GWLZ enhancers"
                        " (streamed runs train on a reservoir tile sample)")
    c.add_argument("--groups", type=int, default=8)
    c.add_argument("--epochs", type=int, default=60)
    c.add_argument("--min-group-pixels", type=int, default=256)
    c.set_defaults(fn=cmd_compress)

    d = sub.add_parser("decompress", help="full decode to a .npy file")
    d.add_argument("input")
    d.add_argument("output")
    d.add_argument("--field", default=None, help="field name (GWDS datasets)")
    d.set_defaults(fn=cmd_decompress)

    i = sub.add_parser("info", help="envelope + size breakdown")
    i.add_argument("path")
    i.set_defaults(fn=cmd_info)

    r = sub.add_parser("region", help="random-access ROI decode")
    r.add_argument("path")
    r.add_argument("--roi", required=True, help='e.g. "8:40,:,16:32"')
    r.add_argument("--out", default=None, help="write the ROI to a .npy file")
    r.add_argument("--field", default=None, help="field name (GWDS datasets)")
    r.set_defaults(fn=cmd_region)

    v = sub.add_parser("verify", help="end-to-end integrity check "
                                      "(structure + metadata + lane CRCs)")
    v.add_argument("path")
    v.add_argument("--field", default=None, help="field name (GWDS datasets)")
    v.set_defaults(fn=cmd_verify)

    s = sub.add_parser("serve", help="multi-tenant region-decode daemon "
                                     "(docs/SERVING.md)")
    s.add_argument("volumes", nargs="+", metavar="[NAME=]PATH",
                   help="volumes to serve (default name: the file stem)")
    s.add_argument("--host", default="127.0.0.1")
    s.add_argument("--port", type=int, default=8177,
                   help="listen port (0 binds an ephemeral port)")
    s.add_argument("--cache-bytes", default="256M",
                   help="shared decoded-tile cache budget, e.g. 64M / 1G")
    s.add_argument("--mem-budget", default="256M",
                   help="admission-control working-set budget")
    s.add_argument("--max-queue", type=int, default=1024,
                   help="max requests waiting on admission before 503")
    s.add_argument("--on-corrupt", default="raise",
                   choices=["raise", "quarantine"],
                   help="per-lane CRC failure policy for served volumes")
    s.add_argument("--batch-wait-ms", type=float, default=2.0,
                   help="decode micro-batcher max wait: how long the first "
                        "request of a round holds the dispatch open for "
                        "concurrent requests to join (docs/SERVING.md)")
    s.add_argument("--no-batcher", action="store_true",
                   help="disable cross-request decode batching (each request "
                        "dispatches its own claimed lanes)")
    s.add_argument("--smoke", action="store_true",
                   help="start, self-exercise every endpoint over HTTP "
                        "(asserting cache hits on a repeated ROI), then exit")
    s.set_defaults(fn=cmd_serve)

    lint = sub.add_parser("lint", help="AST static-analysis gate over the "
                                       "repro tree (docs/ANALYSIS.md)")
    lint.add_argument("--json", action="store_true",
                      help="machine-readable report (the CI artifact shape)")
    lint.add_argument("--rule", action="append", metavar="RAnnn",
                      help="run only these rule ids (repeatable)")
    lint.add_argument("--root", default=None,
                      help="tree to analyze (default: the installed repro "
                           "package — src/repro in a checkout)")
    lint.add_argument("--baseline", default=None, metavar="PATH",
                      help="JSON report of accepted findings to subtract")
    lint.add_argument("--write-baseline", action="store_true",
                      help="write current findings to --baseline and exit 0")
    lint.set_defaults(fn=cmd_lint)

    args = ap.parse_args(argv)
    if args.cmd == "compress" and (args.eb is None) == (args.abs_eb is None):
        ap.error("pass exactly one of --eb / --abs-eb")
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
