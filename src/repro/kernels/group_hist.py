"""Fused group-assignment + histogram Pallas kernel (GWLZ grouping pass).

Computes per-element group ids from value-range edges and the global group
histogram in one sweep over the volume (flattened to [N, 128] lanes).  The
histogram accumulates in a VMEM-resident output block revisited by every grid
step (TPU grid steps are sequential), initialized at step 0.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, edges_ref, ids_ref, hist_ref, *, n_groups: int):
    i = pl.program_id(0)
    x = x_ref[...]  # [BB, 128]
    edges = edges_ref[...]  # [G+1]
    ge = (x[:, :, None] >= edges[None, None, :-1]).astype(jnp.int32)  # [BB,128,G]
    ids = jnp.clip(ge.sum(-1) - 1, 0, n_groups - 1)
    ids_ref[...] = ids.astype(jnp.int32)

    onehot = (ids[:, :, None] == jax.lax.broadcasted_iota(jnp.int32, (1, 1, n_groups), 2)).astype(jnp.int32)
    partial_hist = onehot.sum((0, 1))  # [G]

    @pl.when(i == 0)
    def _():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    hist_ref[...] += partial_hist


@partial(jax.jit, static_argnames=("n_groups", "block_rows", "interpret"))
def group_hist(x: jax.Array, edges: jax.Array, *, n_groups: int,
               block_rows: int = 256, interpret: bool = True):
    """x: [N, 128] float32; edges: [G+1] -> (ids [N,128] int32, hist [G] int32)."""
    N = x.shape[0]
    bb = min(block_rows, N)
    assert N % bb == 0, (N, bb)
    G = n_groups
    ids, hist = pl.pallas_call(
        partial(_kernel, n_groups=G),
        grid=(N // bb,),
        in_specs=[
            pl.BlockSpec((bb, 128), lambda i: (i, 0)),
            pl.BlockSpec((G + 1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bb, 128), lambda i: (i, 0)),
            pl.BlockSpec((G,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, 128), jnp.int32),
            jax.ShapeDtypeStruct((G,), jnp.int32),
        ],
        interpret=interpret,
    )(x, edges)
    return ids, hist
