"""Fused group-assignment + histogram Pallas kernels (GWLZ grouping pass +
entropy-stage symbol counting).

``group_hist`` computes per-element group ids from value-range edges and the
global group histogram in one sweep over the volume (flattened to [N, 128]
lanes).  ``symbol_hist`` is the general integer-symbol histogram the entropy
stage uses for Huffman frequency counting (``HuffmanCodec.fit``), so code
tensors never go through a host-side sort.  Both accumulate in a
VMEM-resident output block revisited by every grid step (TPU grid steps are
sequential), initialized at step 0.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, edges_ref, ids_ref, hist_ref, *, n_groups: int):
    i = pl.program_id(0)
    x = x_ref[...]  # [BB, 128]
    edges = edges_ref[...]  # [G+1]
    ge = (x[:, :, None] >= edges[None, None, :-1]).astype(jnp.int32)  # [BB,128,G]
    ids = jnp.clip(ge.sum(-1) - 1, 0, n_groups - 1)
    ids_ref[...] = ids.astype(jnp.int32)

    onehot = (ids[:, :, None] == jax.lax.broadcasted_iota(jnp.int32, (1, 1, n_groups), 2)).astype(jnp.int32)
    partial_hist = onehot.sum((0, 1))  # [G]

    @pl.when(i == 0)
    def _():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    hist_ref[...] += partial_hist


def _symbol_kernel(s_ref, hist_ref, *, n_bins: int):
    i = pl.program_id(0)
    s = s_ref[...]  # [BB, 128] int32 bin ids in [0, n_bins)
    onehot = (s[:, :, None] == jax.lax.broadcasted_iota(jnp.int32, (1, 1, n_bins), 2)).astype(jnp.int32)
    partial_hist = onehot.sum((0, 1))  # [B]

    @pl.when(i == 0)
    def _():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    hist_ref[...] += partial_hist


@partial(jax.jit, static_argnames=("n_bins", "block_rows", "interpret"))
def symbol_hist(s: jax.Array, *, n_bins: int, block_rows: int = 8,
                interpret: bool = True) -> jax.Array:
    """s: [N, 128] int32 with values in [0, n_bins) -> hist [n_bins] int32.

    ``block_rows`` trades VMEM for grid steps: the one-hot intermediate is
    [BB, 128, n_bins] int32, so callers shrink BB as the alphabet grows."""
    N = s.shape[0]
    bb = min(block_rows, N)
    assert N % bb == 0, (N, bb)
    return pl.pallas_call(
        partial(_symbol_kernel, n_bins=n_bins),
        grid=(N // bb,),
        in_specs=[pl.BlockSpec((bb, 128), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((n_bins,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((n_bins,), jnp.int32),
        interpret=interpret,
    )(s)


@partial(jax.jit, static_argnames=("n_groups", "block_rows", "interpret"))
def group_hist(x: jax.Array, edges: jax.Array, *, n_groups: int,
               block_rows: int = 256, interpret: bool = True):
    """x: [N, 128] float32; edges: [G+1] -> (ids [N,128] int32, hist [G] int32)."""
    N = x.shape[0]
    bb = min(block_rows, N)
    assert N % bb == 0, (N, bb)
    G = n_groups
    ids, hist = pl.pallas_call(
        partial(_kernel, n_groups=G),
        grid=(N // bb,),
        in_specs=[
            pl.BlockSpec((bb, 128), lambda i: (i, 0)),
            pl.BlockSpec((G + 1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bb, 128), lambda i: (i, 0)),
            pl.BlockSpec((G,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, 128), jnp.int32),
            jax.ShapeDtypeStruct((G,), jnp.int32),
        ],
        interpret=interpret,
    )(x, edges)
    return ids, hist
