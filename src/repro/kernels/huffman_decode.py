"""Device-side chunked canonical-Huffman decode probe (Pallas).

Mirrors the host ``_decode_lanes`` walk (``sz/entropy.py``): every chunk is an
independent lane, all lanes step in lockstep, and one step performs a single
k-bit multi-symbol LUT probe per lane — decoding *all* complete codes inside
the window (up to S).  Codes longer than k bits resolve through the escape
path: a fixed-iteration binary search over the left-aligned canonical
codewords (the device form of the host's ``searchsorted``).

Device-specific reformulations:

* 32-bit windows instead of 64-bit: the encoder caps code lengths at 32, so
  code boundaries only depend on the window's top 32 bits and the host
  searchsorted escape resolves identically (dispatch gates deeper legacy
  tables back to the host codec);
* the window gather is two word loads combined with logical shifts (two-step
  shifts keep every amount in [0, 31]);
* unsigned codeword comparison runs in int32 through the order-preserving
  ``x ^ 0x80000000`` map;
* decoded ids land in the output via a one-hot accumulate over the chunk's
  symbol axis (ADD == OR on disjoint slots), not a scatter;
* the lockstep loop is ``fori_loop`` over the worst case (chunk_size steps,
  every probe yields >= 1 symbol) with a ``cond`` early-exit once all lanes
  in the block hit their symbol targets.

Probe overshoot past a lane's symbol target is clamped exactly like the host
path clamps in ``_expand_entries``; finished lanes stop advancing, so the
word stream only needs two tail pad words.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_MININT = -2147483648  # x ^ MININT maps unsigned order onto int32 (weak literal)


def _decode_block(words, offsets, counts, lut_count, lut_bits, lut_ids,
                  cw_map, order, len_sorted, *, chunk_size: int, k: int):
    bb = offsets.shape[0]
    S = lut_ids.shape[0]
    n = order.shape[0]

    def probe(state):
        pos, cur, out = state
        wi = pos >> 5
        sh = pos & 31
        h = jnp.take(words, wi)
        nxt = jnp.take(words, wi + 1)
        w = (h << sh) | jax.lax.shift_right_logical(
            jax.lax.shift_right_logical(nxt, 31 - sh), 1)
        idx = jax.lax.shift_right_logical(w, 32 - k)
        cnt = jnp.take(lut_count, idx)
        nb = jnp.take(lut_bits, idx)
        # escape: first code in the window is longer than k bits.  The first
        # canonical code is 0 (maps to MININT <= any window), so low >= 1.
        # mid clamps to n-1 so the fixed-count loop is a no-op once
        # low == high == n (a window at/above the last codeword would
        # otherwise probe index n and walk low past it).
        wm = w ^ _MININT
        low = jnp.zeros((bb,), jnp.int32)
        high = jnp.full((bb,), n, jnp.int32)
        for _ in range(max(n.bit_length(), 1)):
            mid = jnp.minimum((low + high) >> 1, n - 1)
            go = jnp.take(cw_map, mid) <= wm
            low = jnp.where(go, mid + 1, low)
            high = jnp.where(go, high, mid)
        e_idx = low - 1
        esc = cnt == 0
        cnt = jnp.where(esc, 1, cnt)
        nb = jnp.where(esc, jnp.take(len_sorted, e_idx), nb)
        active = cur < counts
        take_n = jnp.where(active, jnp.minimum(cnt, counts - cur), 0)
        slot = jax.lax.broadcasted_iota(jnp.int32, (bb, chunk_size), 1)
        for j in range(S):
            idj = jnp.take(lut_ids[j], idx)
            if j == 0:
                idj = jnp.where(esc, jnp.take(order, e_idx), idj)
            hit = (slot == (cur + j)[:, None]) & (take_n > j)[:, None]
            out = out + jnp.where(hit, idj[:, None], 0)
        pos = pos + jnp.where(active, nb, 0)
        return pos, cur + take_n, out

    def body(_, state):
        return jax.lax.cond(jnp.any(state[1] < counts), probe, lambda s: s, state)

    init = (offsets, jnp.zeros((bb,), jnp.int32),
            jnp.zeros((bb, chunk_size), jnp.int32))
    _, _, out = jax.lax.fori_loop(0, chunk_size, body, init)
    return out


def _kernel(words_ref, offsets_ref, counts_ref, lut_count_ref, lut_bits_ref,
            lut_ids_ref, cw_map_ref, order_ref, len_sorted_ref, out_ref, *,
            chunk_size: int, k: int):
    out_ref[...] = _decode_block(
        words_ref[...], offsets_ref[...], counts_ref[...], lut_count_ref[...],
        lut_bits_ref[...], lut_ids_ref[...], cw_map_ref[...], order_ref[...],
        len_sorted_ref[...], chunk_size=chunk_size, k=k)


@partial(jax.jit, static_argnames=("chunk_size", "k", "block_chunks", "interpret"))
def huffman_decode_probe(words: jax.Array, offsets: jax.Array, counts: jax.Array,
                         lut_count: jax.Array, lut_bits: jax.Array,
                         lut_ids: jax.Array, cw_map: jax.Array,
                         order: jax.Array, len_sorted: jax.Array, *,
                         chunk_size: int, k: int, block_chunks: int = 8,
                         interpret: bool = True) -> jax.Array:
    """words: [NW] int32 (big-endian u32 stream words, >= 2 zero tail pad);
    offsets/counts: [C] int32 per-chunk bit offsets / symbol targets.  Tables
    are the codec's multi-symbol LUT split into parallel int32 arrays
    (``HuffmanCodec._device_tables``).  Returns alphabet ids [C, chunk_size]
    int32 (rows zero-padded past each chunk's count)."""
    C = offsets.shape[0]
    bb = min(block_chunks, C)
    Cp = -(-C // bb) * bb
    if Cp != C:
        offsets = jnp.pad(offsets, (0, Cp - C))
        counts = jnp.pad(counts, (0, Cp - C))  # count 0 => lane never activates
    full = lambda a: pl.BlockSpec(a.shape, lambda i: (0,) * a.ndim)
    out = pl.pallas_call(
        partial(_kernel, chunk_size=chunk_size, k=k),
        grid=(Cp // bb,),
        in_specs=[full(words),
                  pl.BlockSpec((bb,), lambda i: (i,)),
                  pl.BlockSpec((bb,), lambda i: (i,)),
                  full(lut_count), full(lut_bits), full(lut_ids),
                  full(cw_map), full(order), full(len_sorted)],
        out_specs=pl.BlockSpec((bb, chunk_size), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Cp, chunk_size), jnp.int32),
        interpret=interpret,
    )(words, offsets, counts, lut_count, lut_bits, lut_ids, cw_map, order,
      len_sorted)
    return out[:C]
