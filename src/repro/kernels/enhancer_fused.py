"""Fused GWLZ enhancer forward (inference hot path) as a Pallas kernel.

The whole model (two 3x3 convs, 9 channels, BN, ReLU — ~200 params) fits in
VMEM next to one slice, so the fused kernel runs slice-in/slice-out with zero
intermediate HBM traffic (4 round-trips saved vs the layer-by-layer XLA path).
Convs are expressed as 9 shifted taps feeding one [H*W, 9]x[9, C] MXU dot —
the same shift+matmul form the trainer uses (see repro.core.enhancer._conv).

Grid: one step per slice in the batch.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _shift2d(a: jax.Array, dy: int, dx: int) -> jax.Array:
    """Zero-padded shift of a [H, W] plane."""
    out = a
    if dy:
        out = jnp.roll(out, dy, axis=0)
        pos = jax.lax.broadcasted_iota(jnp.int32, out.shape, 0)
        out = jnp.where((pos < dy) if dy > 0 else (pos >= out.shape[0] + dy), 0.0, out)
    if dx:
        out = jnp.roll(out, dx, axis=1)
        pos = jax.lax.broadcasted_iota(jnp.int32, out.shape, 1)
        out = jnp.where((pos < dx) if dx > 0 else (pos >= out.shape[1] + dx), 0.0, out)
    return out


def _taps(x: jax.Array) -> jax.Array:
    """[H, W] -> [H*W, 9] neighborhood matrix (tap order = (dy, dx) row-major
    matching repro.core.enhancer._shifts3x3: shifted slice at offset (dy, dx)
    reads x at (y + 1 - dy, x + 1 - dx))."""
    H, W = x.shape
    cols = [_shift2d(x, 1 - dy, 1 - dx).reshape(H * W) for dy in range(3) for dx in range(3)]
    return jnp.stack(cols, axis=1)


def _kernel(x_ref, w1_ref, b1_ref, scale_ref, shift_ref, w2_ref, b2_ref, out_ref):
    x = x_ref[0]  # [H, W]
    H, W = x.shape
    p = _taps(x)  # [H*W, 9]
    w1 = w1_ref[...].reshape(9, -1)  # [9, C]
    h = jnp.dot(p, w1, preferred_element_type=jnp.float32) + b1_ref[...]
    # BN folded into (scale, shift) on the host side
    h = h * scale_ref[...] + shift_ref[...]
    h = jnp.maximum(h, 0.0)
    C = h.shape[-1]
    h = h.reshape(H, W, C)
    # conv2: 9 taps x C channels -> [H*W, 9*C] @ [9*C, 1]
    taps2 = [
        _shift2d(h[:, :, c], 1 - dy, 1 - dx).reshape(H * W)
        for dy in range(3)
        for dx in range(3)
        for c in range(C)
    ]
    p2 = jnp.stack(taps2, axis=1)  # [H*W, 9C] (tap-major, channel-minor)
    w2 = w2_ref[...].reshape(9 * C, 1)
    out = jnp.dot(p2, w2, preferred_element_type=jnp.float32) + b2_ref[...]
    out_ref[0] = out.reshape(H, W)


@partial(jax.jit, static_argnames=("interpret",))
def enhancer_fused(x, w1, b1, gamma, beta, mean, var, w2, b2, *, interpret: bool = True):
    """x: [B, H, W] -> [B, H, W] predicted (normalized) residual."""
    B, H, W = x.shape
    C = w1.shape[-1]
    # fold BN statistics into an affine pair (host-side, once per volume)
    inv = jax.lax.rsqrt(var + 1e-5) * gamma
    scale, shift = inv, beta - mean * inv
    full = lambda s: pl.BlockSpec(s, lambda i: (0,) * len(s))
    return pl.pallas_call(
        _kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, H, W), lambda i: (i, 0, 0)),
            full(w1.shape), full(b1.shape), full(scale.shape), full(shift.shape),
            full(w2.shape), full(b2.shape),
        ],
        out_specs=pl.BlockSpec((1, H, W), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, W), jnp.float32),
        interpret=interpret,
    )(x, w1, b1, scale, shift, w2, b2)
