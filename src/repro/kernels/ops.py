"""Dispatch layer: Pallas kernels on TPU, jnp reference on other backends.

``use_pallas=None`` auto-detects; the CPU dry-run path always lowers the pure
JAX reference (Pallas TPU kernels can't lower on the host platform), while
tests exercise the kernels in interpret mode.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.enhancer_fused import enhancer_fused
from repro.kernels.group_hist import group_hist, symbol_hist
from repro.kernels.huffman_decode import huffman_decode_probe
from repro.kernels.huffman_encode import huffman_encode_pack
from repro.kernels.lorenzo_quant import lorenzo_quant, lorenzo_quant_tiles


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def lorenzo_quant_op(x, eb, *, use_pallas: bool | None = None, interpret: bool | None = None):
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return lorenzo_quant(x, eb, interpret=not _on_tpu() if interpret is None else interpret)
    return ref.lorenzo_quant_ref(x, eb)


def lorenzo_quant_tiles_op(x, eb, *, use_pallas: bool | None = None,
                           interpret: bool | None = None):
    """Tile-batched Lorenzo codes: x is [B, *tile] with axis 0 the tile batch.

    The Pallas kernel covers the 3D-tile case ([B, Z, Y, X]); other tile
    ranks run the jnp reference (the transform is identical per axis)."""
    use = _on_tpu() if use_pallas is None else use_pallas
    if use and x.ndim == 4:
        return lorenzo_quant_tiles(
            x, eb, interpret=not _on_tpu() if interpret is None else interpret)
    return ref.lorenzo_quant_tiles_ref(x, eb)


@partial(jax.jit, static_argnames=("eb",))
def _lorenzo_decode_tiles(codes, eb):
    from repro.sz.predictor import lorenzo_decode

    return jax.vmap(lambda c: lorenzo_decode(c, eb, jnp.float32))(codes)


def lorenzo_decode_tiles_op(codes, eb):
    """Batched exact inverse of :func:`lorenzo_quant_tiles_op`: integer cumsum
    per tile + dequantize ([B, *tile] int32 -> float32).

    Elementwise-exact in the batch axis (integer cumsums are exact, the
    dequantize multiply is per-element), so any subset of tiles reconstructs
    the bits the full batch would — the contract random-access region decode
    relies on.  Pure vectorized jnp on every backend (cumsum lowers well
    everywhere; no Pallas variant is needed)."""
    return _lorenzo_decode_tiles(codes, float(eb))


def enhancer_fused_op(x, params, bn_state, *, use_pallas: bool | None = None,
                      interpret: bool | None = None):
    """params/bn_state: single-group enhancer pytrees (no G axis)."""
    args = (
        x, params["w1"], params["b1"], params["gamma"], params["beta"],
        bn_state["mean"], bn_state["var"], params["w2"], params["b2"],
    )
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return enhancer_fused(*args, interpret=not _on_tpu() if interpret is None else interpret)
    return ref.enhancer_fused_ref(*args)


def symbol_hist_op(symbols, *, n_bins: int, use_pallas: bool | None = None,
                   interpret: bool | None = None):
    """Integer-symbol histogram over any-shaped int32 input.

    Values outside [0, n_bins) are ignored (they land in an internal
    sentinel bin, along with lane padding). Returns hist int32 [n_bins]."""
    flat = jnp.reshape(symbols, (-1,))
    sentinel = n_bins
    bins = n_bins + 1
    flat = jnp.where((flat >= 0) & (flat < n_bins), flat, sentinel).astype(jnp.int32)
    # block size bounds the [BB, 128, bins] one-hot intermediate to ~1M cells
    bb = max(1, min(256, 8192 // bins))
    rows = -(-max(int(flat.shape[0]), 1) // 128)
    rows = -(-rows // bb) * bb
    pad = rows * 128 - flat.shape[0]
    flat = jnp.concatenate([flat, jnp.full((pad,), sentinel, jnp.int32)])
    x2 = flat.reshape(rows, 128)
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        hist = symbol_hist(x2, n_bins=bins, block_rows=bb,
                           interpret=not _on_tpu() if interpret is None else interpret)
    else:
        hist = ref.symbol_hist_ref(x2, bins)
    return hist[:n_bins]


def huffman_encode_op(lens, codes, *, use_pallas: bool | None = None,
                      interpret: bool | None = None):
    """Chunk-parallel canonical-Huffman encode pack.

    lens/codes: [C, CS] int32 per-chunk code lengths / codewords (0-length
    marks the pad slots of a short last chunk).  Returns (words [C, CS]
    int32 — each chunk's bit stream MSB-first across big-endian u32 lanes,
    chunk_bits [C] int32).  The entropy layer splices chunks into the
    continuous hc/hZ stream on host (``sz/entropy.py``)."""
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return huffman_encode_pack(
            lens, codes, interpret=not _on_tpu() if interpret is None else interpret)
    return ref.huffman_encode_ref(lens, codes)


def huffman_decode_op(words, offsets, counts, lut_count, lut_bits, lut_ids,
                      cw_map, order, len_sorted, *, chunk_size: int, k: int,
                      use_pallas: bool | None = None,
                      interpret: bool | None = None):
    """Lockstep multi-symbol-LUT Huffman decode probe.

    words: [NW] int32 big-endian u32 stream words (>= 2 zero tail words);
    offsets/counts: [C] int32; tables from
    ``HuffmanCodec._device_tables``.  Returns alphabet ids [C, chunk_size]
    int32 (zero-padded past each chunk's count)."""
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return huffman_decode_probe(
            words, offsets, counts, lut_count, lut_bits, lut_ids, cw_map,
            order, len_sorted, chunk_size=chunk_size, k=k,
            interpret=not _on_tpu() if interpret is None else interpret)
    return ref.huffman_decode_ref(words, offsets, counts, lut_count, lut_bits,
                                  lut_ids, cw_map, order, len_sorted,
                                  chunk_size=chunk_size, k=k)


def group_hist_op(x, edges, *, n_groups: int, use_pallas: bool | None = None,
                  interpret: bool | None = None):
    """x: any shape with size % 128 == 0 (host pads); returns (ids, hist)."""
    shape = x.shape
    x2 = x.reshape(-1, 128)
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        ids, hist = group_hist(x2, edges, n_groups=n_groups,
                               interpret=not _on_tpu() if interpret is None else interpret)
    else:
        ids, hist = ref.group_hist_ref(x2, edges)
    return ids.reshape(shape), hist
