"""Device-side canonical-Huffman encode pack (Pallas).

The host codec (``sz/entropy.py``) packs the code stream with a bit-level
scatter over ``np.packbits`` — byte-sequential work with no TPU analogue.
This kernel reformulates the pack as chunk-parallel word assembly so it maps
onto the VPU:

* every chunk (``chunk_size`` symbols, the hc/hZ decode unit) is an
  independent bit stream, so chunks are grid-parallel;
* per-symbol bit offsets inside a chunk come from a Hillis-Steele prefix sum
  over the code lengths (log2(CS) roll+mask steps — ``jnp.cumsum`` is not
  relied on inside Mosaic);
* each codeword is left-aligned into a 32-bit lane (``code << (32 - len)``)
  and split into the two words it can straddle with logical shifts (two-step
  shifts keep every shift amount in [0, 31]);
* the word-level scatter/OR is a one-hot accumulate over the chunk's word
  axis — disjoint bit ranges make integer ADD equal OR, the same trick the
  ``symbol_hist`` kernel uses instead of scatter.

Each chunk's total bit count (the hc/hZ per-chunk bit table) falls out of the
prefix sum for free.  The cross-chunk splice into one continuous bit stream
(chunks are *not* byte-aligned in the wire format) stays on host — it is one
vectorized shift + bincount over word indices (``sz/entropy.py``).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _encode_block(lens, codes, chunk_size: int):
    """Shared block body: [BB, CS] int32 lens/codes -> ([BB, CS] words, [BB, 1]
    totals).  ``lens == 0`` marks pad slots (last chunk short); real code
    lengths are in [1, 32]."""
    bb, cs = lens.shape
    # chunk-local inclusive prefix sum of code lengths (bit end per symbol)
    ends = lens
    d = 1
    while d < cs:
        pos = jax.lax.broadcasted_iota(jnp.int32, ends.shape, 1)
        ends = ends + jnp.where(pos >= d, jnp.roll(ends, d, axis=1), 0)
        d *= 2
    totals = ends[:, -1:]
    starts = ends - lens
    # left-align each codeword at bit 31; pad slots contribute nothing
    sh_align = jnp.where(lens > 0, 32 - lens, 0)
    aligned = jnp.where(lens > 0, codes << sh_align, 0)
    w0 = starts >> 5
    sh = starts & 31
    hi = jax.lax.shift_right_logical(aligned, sh)
    # spill into the next word; (x << (31-sh)) << 1 == x << (32-sh) without
    # ever shifting by 32 (sh == 0 -> spill is exactly 0)
    lo = (aligned << (31 - sh)) << 1
    # one-hot word accumulate: disjoint bit ranges => ADD == OR, and the full
    # [BB, W] assignment zero-fills words past each chunk's bit count
    wi = jax.lax.broadcasted_iota(jnp.int32, (bb, cs, cs), 2)
    contrib = (jnp.where(w0[..., None] == wi, hi[..., None], 0)
               + jnp.where((w0[..., None] + 1) == wi, lo[..., None], 0))
    return contrib.sum(axis=1), totals


def _kernel(lens_ref, codes_ref, words_ref, totals_ref, *, chunk_size: int):
    words, totals = _encode_block(lens_ref[...], codes_ref[...], chunk_size)
    words_ref[...] = words
    totals_ref[...] = totals


@partial(jax.jit, static_argnames=("interpret",))
def huffman_encode_pack(lens: jax.Array, codes: jax.Array, *,
                        interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """lens/codes: [C, CS] int32 (0-len = pad) -> (words [C, CS] int32 with the
    chunk bit stream MSB-first across big-endian u32 lanes, chunk_bits [C]
    int32).

    The one-hot intermediate is [BB, CS, CS] int32, so the block height BB is
    sized to keep it around ~1M cells (mirrors ``symbol_hist``'s bound).
    """
    C, cs = lens.shape
    bb = max(1, min(C, 1_000_000 // max(cs * cs, 1)))
    Cp = -(-C // bb) * bb
    if Cp != C:
        pad = ((0, Cp - C), (0, 0))
        lens = jnp.pad(lens, pad)
        codes = jnp.pad(codes, pad)
    words, totals = pl.pallas_call(
        partial(_kernel, chunk_size=cs),
        grid=(Cp // bb,),
        in_specs=[pl.BlockSpec((bb, cs), lambda i: (i, 0)),
                  pl.BlockSpec((bb, cs), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((bb, cs), lambda i: (i, 0)),
                   pl.BlockSpec((bb, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((Cp, cs), jnp.int32),
                   jax.ShapeDtypeStruct((Cp, 1), jnp.int32)],
        interpret=interpret,
    )(lens, codes)
    return words[:C], totals[:C, 0]
