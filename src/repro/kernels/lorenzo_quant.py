"""Fused prequantize + 3D Lorenzo stencil Pallas kernel.

TPU design (DESIGN.md §3.1): the grid walks z-slabs in order; each step holds
one [BZ, Y, X] slab in VMEM, computes q = rint(x / 2eb) and the three
directional differences entirely on the VPU, and carries the slab's last
q-plane to the next step in VMEM scratch (TPU grid steps are sequential, so
the carry is exact — no halo reloads from HBM).  y/x boundaries are real
volume boundaries because those axes are kept at full extent per slab.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _shift_zero(a: jax.Array, axis: int) -> jax.Array:
    """roll-by-one with a zero boundary row (iota+where, TPU-safe)."""
    rolled = jnp.roll(a, 1, axis=axis)
    pos = jax.lax.broadcasted_iota(jnp.int32, a.shape, axis)
    return jnp.where(pos == 0, jnp.zeros_like(a), rolled)


def _kernel(x_ref, codes_ref, carry_ref, *, two_eb: float):
    i = pl.program_id(0)
    x = x_ref[...]
    # divide (not multiply-by-reciprocal): must round identically to the
    # production quantizer at .5 ties
    q = jnp.rint(x / two_eb)  # f32 grid values (exact for |q| < 2^24)

    prev = jnp.where(i == 0, jnp.zeros_like(carry_ref[...]), carry_ref[...])  # [1, Y, X]
    carry_ref[...] = q[-1:, :, :]

    # z-difference with cross-slab carry
    qz_shift = jnp.roll(q, 1, axis=0)
    pos_z = jax.lax.broadcasted_iota(jnp.int32, q.shape, 0)
    qz_shift = jnp.where(pos_z == 0, jnp.broadcast_to(prev, q.shape), qz_shift)
    d = q - qz_shift
    # y and x differences (full-extent axes -> zero boundary is the real one)
    d = d - _shift_zero(d, 1)
    d = d - _shift_zero(d, 2)
    codes_ref[...] = d.astype(jnp.int32)


@partial(jax.jit, static_argnames=("eb", "block_z", "interpret"))
def lorenzo_quant(x: jax.Array, eb: float, *, block_z: int = 8, interpret: bool = True) -> jax.Array:
    """x: [Z, Y, X] float32 -> int32 Lorenzo codes (cuSZ-style prequantized).

    VMEM budget: (1 input + 1 output + carry) * BZ*Y*X*4B; BZ=8 with 512^2
    planes is ~16 MB -> choose block_z to fit (benchmarks sweep this).
    """
    Z, Y, X = x.shape
    bz = min(block_z, Z)
    assert Z % bz == 0, (Z, bz)
    return pl.pallas_call(
        partial(_kernel, two_eb=float(2.0 * eb)),
        grid=(Z // bz,),
        in_specs=[pl.BlockSpec((bz, Y, X), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((bz, Y, X), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((Z, Y, X), jnp.int32),
        scratch_shapes=[_vmem((1, Y, X), jnp.float32)],
        interpret=interpret,
    )(x)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)
