"""Fused prequantize + 3D Lorenzo stencil Pallas kernel.

TPU design (DESIGN.md §3.1): the grid walks z-slabs in order; each step holds
one [BZ, Y, X] slab in VMEM, computes q = rint(x / 2eb) and the three
directional differences entirely on the VPU, and carries the slab's last
q-plane to the next step in VMEM scratch (TPU grid steps are sequential, so
the carry is exact — no halo reloads from HBM).  y/x boundaries are real
volume boundaries because those axes are kept at full extent per slab.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _shift_zero(a: jax.Array, axis: int) -> jax.Array:
    """roll-by-one with a zero boundary row (iota+where, TPU-safe)."""
    rolled = jnp.roll(a, 1, axis=axis)
    pos = jax.lax.broadcasted_iota(jnp.int32, a.shape, axis)
    return jnp.where(pos == 0, jnp.zeros_like(a), rolled)


def _lorenzo_slab(x: jax.Array, prev: jax.Array, two_eb: float):
    """Shared slab body: prequantize + 3-axis stencil on one [BZ, Y, X] slab.

    ``prev`` is the previous slab's last q-plane ([1, Y, X]; zeros at a
    domain start).  Returns (codes, carry).  Divide (not multiply-by-
    reciprocal): must round identically to the production quantizer at .5
    ties; q stays in f32 (exact for |q| < 2^24)."""
    q = jnp.rint(x / two_eb)
    carry = q[-1:, :, :]
    # z-difference with cross-slab carry
    qz_shift = jnp.roll(q, 1, axis=0)
    pos_z = jax.lax.broadcasted_iota(jnp.int32, q.shape, 0)
    qz_shift = jnp.where(pos_z == 0, jnp.broadcast_to(prev, q.shape), qz_shift)
    d = q - qz_shift
    # y and x differences (full-extent axes -> zero boundary is the real one)
    d = d - _shift_zero(d, 1)
    d = d - _shift_zero(d, 2)
    return d.astype(jnp.int32), carry


def _kernel(x_ref, codes_ref, carry_ref, *, two_eb: float):
    i = pl.program_id(0)
    prev = jnp.where(i == 0, jnp.zeros_like(carry_ref[...]), carry_ref[...])  # [1, Y, X]
    codes, carry = _lorenzo_slab(x_ref[...], prev, two_eb)
    carry_ref[...] = carry
    codes_ref[...] = codes


@partial(jax.jit, static_argnames=("eb", "block_z", "interpret"))
def lorenzo_quant(x: jax.Array, eb: float, *, block_z: int = 8, interpret: bool = True) -> jax.Array:
    """x: [Z, Y, X] float32 -> int32 Lorenzo codes (cuSZ-style prequantized).

    VMEM budget: (1 input + 1 output + carry) * BZ*Y*X*4B; BZ=8 with 512^2
    planes is ~16 MB -> choose block_z to fit (benchmarks sweep this).
    """
    Z, Y, X = x.shape
    bz = min(block_z, Z)
    assert Z % bz == 0, (Z, bz)
    return pl.pallas_call(
        partial(_kernel, two_eb=float(2.0 * eb)),
        grid=(Z // bz,),
        in_specs=[pl.BlockSpec((bz, Y, X), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((bz, Y, X), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((Z, Y, X), jnp.int32),
        scratch_shapes=[_vmem((1, Y, X), jnp.float32)],
        interpret=interpret,
    )(x)


def _tiles_kernel(x_ref, codes_ref, carry_ref, *, two_eb: float):
    """Tile-batched variant: grid is (tile, z-slab); the z-carry resets at the
    first slab of every tile, so each tile sees its own zero boundary (the
    tiled container's prediction domain ends at the tile edge)."""
    i = pl.program_id(1)
    prev = jnp.where(i == 0, jnp.zeros_like(carry_ref[...]), carry_ref[...])  # [1, Y, X]
    codes, carry = _lorenzo_slab(x_ref[0], prev, two_eb)
    carry_ref[...] = carry
    codes_ref[0] = codes


@partial(jax.jit, static_argnames=("eb", "block_z", "interpret"))
def lorenzo_quant_tiles(x: jax.Array, eb: float, *, block_z: int = 8,
                        interpret: bool = True) -> jax.Array:
    """x: [B, Z, Y, X] float32 tile batch -> int32 per-tile Lorenzo codes.

    Same fused prequant+stencil as :func:`lorenzo_quant`, with a leading
    tile-batch grid dimension.  TPU grid steps are sequential in row-major
    order, so slabs of tile b run back-to-back and the VMEM carry is exact
    within a tile; the carry reset at slab 0 makes tiles independent (codes
    match per-tile :func:`lorenzo_quant` exactly).  Tile z-extents are user
    chosen, so the slab height snaps to the largest divisor of Z <= block_z
    instead of asserting divisibility."""
    B, Z, Y, X = x.shape
    bz = next(b for b in range(min(block_z, Z), 0, -1) if Z % b == 0)
    return pl.pallas_call(
        partial(_tiles_kernel, two_eb=float(2.0 * eb)),
        grid=(B, Z // bz),
        in_specs=[pl.BlockSpec((1, bz, Y, X), lambda b, i: (b, i, 0, 0))],
        out_specs=pl.BlockSpec((1, bz, Y, X), lambda b, i: (b, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Z, Y, X), jnp.int32),
        scratch_shapes=[_vmem((1, Y, X), jnp.float32)],
        interpret=interpret,
    )(x)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)
