"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lorenzo_quant_ref(x: jax.Array, eb: float) -> jax.Array:
    """Fused prequantize + 3D integer Lorenzo stencil (compression hot loop)."""
    q = jnp.rint(x / (2.0 * jnp.asarray(eb, x.dtype))).astype(jnp.int32)
    for ax in range(x.ndim):
        shifted = jnp.roll(q, 1, axis=ax)
        idx = [slice(None)] * q.ndim
        idx[ax] = slice(0, 1)
        shifted = shifted.at[tuple(idx)].set(0)
        q = q - shifted
    return q


def lorenzo_quant_tiles_ref(x: jax.Array, eb: float) -> jax.Array:
    """Tile-batched Lorenzo codes: axis 0 is the tile batch, each tile gets
    the per-volume stencil with its own zero boundary (independent domains).
    vmap of the single-volume oracle, so the stencil exists in one place."""
    return jax.vmap(lambda t: lorenzo_quant_ref(t, eb))(x)


def enhancer_fused_ref(x: jax.Array, w1, b1, gamma, beta, mean, var, w2, b2) -> jax.Array:
    """Conv3x3(1->C) + BN(inference) + ReLU + Conv3x3(C->1), zero-pad SAME.

    x: [B, H, W]; returns [B, H, W]."""
    from repro.core.enhancer import _conv

    h = _conv(x[..., None], w1, b1)
    h = (h - mean) * jax.lax.rsqrt(var + 1e-5) * gamma + beta
    h = jax.nn.relu(h)
    out = _conv(h, w2, b2)
    return out[..., 0]


def symbol_hist_ref(s: jax.Array, n_bins: int) -> jax.Array:
    """Integer-symbol histogram. s: [N, 128] int32 in [0, n_bins).

    Returns hist int32 [n_bins]."""
    return jnp.zeros((n_bins,), jnp.int32).at[s.ravel()].add(1)


def huffman_encode_ref(lens: jax.Array, codes: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Chunk-parallel Huffman encode pack. lens/codes: [C, CS] int32 (0-len =
    pad slot).  Returns (words [C, CS] int32, chunk_bits [C] int32) — the
    same block body the Pallas kernel runs, applied to the whole batch."""
    from repro.kernels.huffman_encode import _encode_block

    words, totals = _encode_block(lens, codes, lens.shape[1])
    return words, totals[:, 0]


def huffman_decode_ref(words, offsets, counts, lut_count, lut_bits, lut_ids,
                       cw_map, order, len_sorted, *, chunk_size: int,
                       k: int) -> jax.Array:
    """Lockstep multi-symbol LUT decode probe over all chunks at once.
    Returns alphabet ids [C, chunk_size] int32."""
    from repro.kernels.huffman_decode import _decode_block

    return _decode_block(words, offsets, counts, lut_count, lut_bits, lut_ids,
                         cw_map, order, len_sorted, chunk_size=chunk_size, k=k)


def group_hist_ref(x: jax.Array, edges: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Group-id assignment + histogram. x: [N, 128]; edges: [G+1].

    Returns (ids int32 [N,128], hist int32 [G])."""
    G = edges.shape[0] - 1
    ids = (x[..., None] >= edges[:-1]).sum(-1).astype(jnp.int32) - 1
    ids = jnp.clip(ids, 0, G - 1)
    hist = jnp.zeros((G,), jnp.int32).at[ids.ravel()].add(1)
    return ids, hist
