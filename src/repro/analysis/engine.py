"""Checker engine: one ``ast.parse`` + one walk per file, shared by all rules.

Each source file under the analysis root is read and parsed exactly once
into a :class:`ModuleInfo` — the shared visitor walks the tree a single
time, recording a parent map plus typed node buckets (classes, functions,
excepts, raises, calls, assignments, bytes literals).  Rules consume those
buckets instead of re-walking, which is what keeps a full-tree lint in the
single-digit-second range (asserted in ``tests/test_analysis.py``).

Two rule shapes exist: *module* rules (:meth:`Rule.check_module`, run per
file) and *project* rules (:meth:`Rule.check_project`, run once over every
parsed module — kernel-triple parity needs the cross-file view).  Findings
on a line carrying (or directly below) a ``# lint: allow <RULE> --
<reason>`` annotation are suppressed; reasonless annotations are reported
as ``RA000`` so a suppression can never silently lose its justification.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from pathlib import Path
from typing import Iterable, Iterator

from repro.analysis.findings import ENGINE_RULE, Finding, parse_suppressions

__all__ = ["ModuleInfo", "ProjectContext", "Rule", "analyze_source",
           "default_root", "default_tests_dir", "load_modules", "run_analysis"]


class ModuleInfo:
    """One parsed source file + the shared single-pass AST index."""

    def __init__(self, path: Path | None, rel: str, text: str):
        self.path = path
        self.rel = rel
        self.text = text
        self.lines = text.splitlines()
        self.tree = ast.parse(text)  # SyntaxError handled by the loader
        self.allow, self.malformed_suppressions = parse_suppressions(self.lines)
        # -- typed buckets filled by the one shared walk ---------------------
        self.classes: list[ast.ClassDef] = []
        self.functions: list[ast.FunctionDef | ast.AsyncFunctionDef] = []
        self.lambdas: list[ast.Lambda] = []
        self.excepts: list[ast.ExceptHandler] = []
        self.raises: list[ast.Raise] = []
        self.asserts: list[ast.Assert] = []
        self.calls: list[ast.Call] = []
        self.assigns: list[ast.Assign | ast.AnnAssign | ast.AugAssign] = []
        self.bytes_consts: list[ast.Constant] = []
        self._parents: dict[ast.AST, ast.AST] = {}
        self._walk()

    def _walk(self) -> None:
        stack: list[ast.AST] = [self.tree]
        while stack:
            node = stack.pop()
            for child in ast.iter_child_nodes(node):
                self._parents[child] = node
                stack.append(child)
            if isinstance(node, ast.ClassDef):
                self.classes.append(node)
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.functions.append(node)
            elif isinstance(node, ast.Lambda):
                self.lambdas.append(node)
            elif isinstance(node, ast.ExceptHandler):
                self.excepts.append(node)
            elif isinstance(node, ast.Raise):
                self.raises.append(node)
            elif isinstance(node, ast.Assert):
                self.asserts.append(node)
            elif isinstance(node, ast.Call):
                self.calls.append(node)
            elif isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
                self.assigns.append(node)
            elif isinstance(node, ast.Constant) and isinstance(node.value, bytes):
                self.bytes_consts.append(node)

    # -- tree navigation -----------------------------------------------------

    def parent(self, node: ast.AST) -> ast.AST | None:
        return self._parents.get(node)

    def ancestors(self, node: ast.AST) -> Iterator[ast.AST]:
        cur = self._parents.get(node)
        while cur is not None:
            yield cur
            cur = self._parents.get(cur)

    def enclosing_function(self, node: ast.AST):
        for anc in self.ancestors(node):
            if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return anc
        return None

    def enclosing_class(self, node: ast.AST) -> ast.ClassDef | None:
        for anc in self.ancestors(node):
            if isinstance(anc, ast.ClassDef):
                return anc
        return None

    def line(self, lineno: int) -> str:
        return self.lines[lineno - 1] if 1 <= lineno <= len(self.lines) else ""

    def suppressed(self, lineno: int, rule: str) -> bool:
        """True when the finding line (or a standalone comment directly
        above it) carries a ``# lint: allow`` for this rule."""
        if rule in self.allow.get(lineno, ()):
            return True
        above = self.allow.get(lineno - 1)
        if above and rule in above and self.line(lineno - 1).lstrip().startswith("#"):
            return True
        return False


@dataclass
class ProjectContext:
    """Cross-file facts project rules need: where the tree and tests live."""

    root: Path
    tests_dir: Path | None = None
    _tests_text: str | None = field(default=None, repr=False)

    def tests_text(self) -> str:
        """Concatenated source of every ``tests/*.py`` (lazily read once):
        the haystack kernel-parity searches for op coverage."""
        if self._tests_text is None:
            chunks = []
            if self.tests_dir is not None and self.tests_dir.is_dir():
                for p in sorted(self.tests_dir.glob("*.py")):
                    try:
                        chunks.append(p.read_text())
                    except OSError:
                        pass
            self._tests_text = "\n".join(chunks)
        return self._tests_text


class Rule:
    """Base class: subclasses set ``id``/``name``/``severity`` and override
    one (or both) of the check hooks."""

    id = "RA000"
    name = "unnamed"
    severity = "error"

    def check_module(self, mod: ModuleInfo) -> Iterable[Finding]:
        return ()

    def check_project(self, mods: list[ModuleInfo],
                      ctx: ProjectContext) -> Iterable[Finding]:
        return ()

    def finding(self, mod_or_rel, lineno: int, message: str) -> Finding:
        rel = mod_or_rel.rel if isinstance(mod_or_rel, ModuleInfo) else str(mod_or_rel)
        return Finding(rel, int(lineno), self.id, self.severity, message)


def all_rules() -> dict[str, Rule]:
    """Fresh instances of every registered rule, keyed by id."""
    from repro.analysis.hygiene import ExceptionHygiene
    from repro.analysis.locks import LockDiscipline
    from repro.analysis.parity import KernelParity
    from repro.analysis.tags import ContainerTagDrift
    from repro.analysis.tracer import TracerSafety

    rules = [LockDiscipline(), TracerSafety(), KernelParity(),
             ExceptionHygiene(), ContainerTagDrift()]
    return {r.id: r for r in rules}


def default_root() -> Path:
    """The installed ``repro`` package directory — the tree the CI gate
    lints (``src/repro`` in a checkout)."""
    import repro

    # repro is a namespace package: __file__ is None, __path__ is not
    return Path(next(iter(repro.__path__))).resolve()


def default_tests_dir(root: Path) -> Path | None:
    """Find the test suite next to the analysis root: ``<repo>/tests`` for
    a ``src/repro`` root, or ``<root>/tests`` for fixture trees."""
    candidates = []
    if len(root.parents) >= 2:
        candidates.append(root.parents[1] / "tests")
    candidates += [root / "tests", root.parent / "tests"]
    for c in candidates:
        if c.is_dir():
            return c
    return None


def load_modules(root: Path) -> tuple[list[ModuleInfo], list[Finding]]:
    """Read + parse every ``*.py`` under root ONCE.  Unreadable or
    syntactically broken files become ``RA000`` findings, not crashes."""
    mods: list[ModuleInfo] = []
    findings: list[Finding] = []
    for path in sorted(root.rglob("*.py")):
        rel = path.relative_to(root).as_posix()
        if "__pycache__" in path.parts:
            continue
        try:
            text = path.read_text()
        except OSError as e:
            findings.append(Finding(rel, 1, ENGINE_RULE, "error",
                                    f"unreadable source file: {e}"))
            continue
        try:
            mods.append(ModuleInfo(path, rel, text))
        except SyntaxError as e:
            findings.append(Finding(rel, int(e.lineno or 1), ENGINE_RULE,
                                    "error", f"syntax error: {e.msg}"))
    return mods, findings


def run_analysis(root=None, rules: Iterable[str] | None = None,
                 tests_dir=None) -> list[Finding]:
    """Run the selected rules over every module under ``root``.

    Returns the sorted, suppression-filtered findings.  ``rules`` selects a
    subset by id (unknown ids raise ``ValueError`` — the CLI maps that to
    exit code 2); the default runs everything.
    """
    root = Path(root).resolve() if root is not None else default_root()
    if not root.is_dir():
        raise ValueError(f"analysis root {str(root)!r} is not a directory")
    registry = all_rules()
    if rules is None:
        selected = list(registry.values())
    else:
        unknown = [r for r in rules if r not in registry]
        if unknown:
            raise ValueError(
                f"unknown rule id(s) {unknown} (known: {sorted(registry)})")
        selected = [registry[r] for r in dict.fromkeys(rules)]
    mods, findings = load_modules(root)
    ctx = ProjectContext(
        root=root,
        tests_dir=Path(tests_dir) if tests_dir is not None
        else default_tests_dir(root))
    by_rel = {m.rel: m for m in mods}
    for mod in mods:
        for lineno, ids in mod.malformed_suppressions:
            findings.append(Finding(
                mod.rel, lineno, ENGINE_RULE, "error",
                f"suppression for {ids} is missing its required reason "
                "(write '# lint: allow RAnnn -- <why this is intended>')"))
    for rule in selected:
        for mod in mods:
            findings.extend(rule.check_module(mod))
        findings.extend(rule.check_project(mods, ctx))
    kept = []
    for f in findings:
        mod = by_rel.get(f.path)
        if f.rule != ENGINE_RULE and mod is not None \
                and mod.suppressed(f.line, f.rule):
            continue
        kept.append(f)
    return sorted(set(kept), key=Finding.sort_key)


def analyze_source(text: str, rel: str = "snippet.py",
                   rules: Iterable[str] | None = None) -> list[Finding]:
    """Run module-level rules over an in-memory snippet (the fixture-pair
    test helper).  Project rules need a real tree — use a tmp root."""
    registry = all_rules()
    selected = (list(registry.values()) if rules is None
                else [registry[r] for r in rules])
    mod = ModuleInfo(None, rel, text)
    findings = [
        Finding(rel, lineno, ENGINE_RULE, "error",
                f"suppression for {ids} is missing its required reason "
                "(write '# lint: allow RAnnn -- <why this is intended>')")
        for lineno, ids in mod.malformed_suppressions]
    for rule in selected:
        findings.extend(rule.check_module(mod))
    return sorted(
        (f for f in findings
         if f.rule == ENGINE_RULE or not mod.suppressed(f.line, f.rule)),
        key=Finding.sort_key)
