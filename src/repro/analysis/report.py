"""Reporters: human-readable text and machine-readable JSON.

The JSON shape is the CI artifact contract (uploaded next to the bench
rows): top-level run metadata plus one object per finding with
``path``/``line``/``rule``/``severity``/``message``.  The text reporter is
one grep-able line per finding plus a summary tail.
"""
from __future__ import annotations

import json

from repro.analysis.findings import Finding


def render_json(findings: list[Finding], *, root: str, files: int,
                rules: list[str], suppressible: bool = True) -> str:
    counts: dict[str, int] = {}
    for f in findings:
        counts[f.rule] = counts.get(f.rule, 0) + 1
    doc = {
        "tool": "repro.analysis",
        "root": root,
        "files": files,
        "rules": rules,
        "clean": not findings,
        "counts": counts,
        "findings": [f.to_json() for f in findings],
    }
    return json.dumps(doc, indent=1, sort_keys=False)


def render_text(findings: list[Finding], *, root: str, files: int,
                rules: list[str]) -> str:
    lines = [f.render() for f in findings]
    if findings:
        by_rule: dict[str, int] = {}
        for f in findings:
            by_rule[f.rule] = by_rule.get(f.rule, 0) + 1
        tally = ", ".join(f"{r}: {n}" for r, n in sorted(by_rule.items()))
        lines.append(f"{len(findings)} finding"
                     f"{'' if len(findings) == 1 else 's'} ({tally}) "
                     f"in {files} files under {root}")
    else:
        lines.append(f"clean: 0 findings in {files} files under {root} "
                     f"(rules {', '.join(rules)})")
    return "\n".join(lines)


def load_baseline(text: str) -> set[tuple]:
    """Parse a baseline document (the JSON reporter's output, or a bare
    findings list) into the set of accepted finding keys."""
    doc = json.loads(text)
    items = doc["findings"] if isinstance(doc, dict) else doc
    return {(f["path"], f["rule"], f["message"]) for f in items}


def apply_baseline(findings: list[Finding], accepted: set[tuple]) -> list[Finding]:
    """Drop findings whose (path, rule, message) identity is baselined."""
    return [f for f in findings if f.baseline_key() not in accepted]
