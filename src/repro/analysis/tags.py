"""RA005 — container-tag drift: wire constants live in ONE registry.

Magic bytes and format version numbers are wire contracts shared by four
parsers (``sz/tiled.py``, ``sz/szjax.py``, ``sz/artifact.py``,
``exec/writer.py``) plus the GWDS envelope in ``api.py`` and the entropy
blob header.  GWTC went v1→v3 and GWDS v1→v2; each bump had to touch every
copy of the literal, and a missed copy is exactly the drift that parses
yesterday's containers with today's constants.  The shared registry
(:data:`repro.sz.artifact.CONTAINER_TAGS`) is now the single source of
truth; this rule flags, everywhere outside that registry module:

* a ``bytes`` literal equal to any registered magic or sentinel
  (``b"GWTC"``, ``b"SZJX"``, ``b"GWDS"``, ``b"GWDX"``, ``b"GWJL"``,
  ``b"RPRE"``) — import the named constant instead;
* an assignment of an integer literal to a ``*VERSION``-named constant —
  alias the registry value (``_VERSION = A.GWTC_VERSION``) so a format
  bump is one edit.
"""
from __future__ import annotations

import ast
import re

from repro.analysis.engine import ModuleInfo, Rule

#: The registry module: the one place literal tag values are allowed.
REGISTRY_MODULE = "sz/artifact.py"

_VERSION_NAME = re.compile(r"^_?[A-Z0-9_]*VERSION$")


def _registry_values() -> dict[bytes, str]:
    """magic/sentinel bytes -> the registry constant naming them."""
    from repro.sz.artifact import CONTAINER_TAGS

    out: dict[bytes, str] = {}
    for tag in CONTAINER_TAGS.values():
        out.setdefault(tag.magic, f"{tag.name} magic")
        if tag.sentinel is not None:
            out.setdefault(tag.sentinel, f"{tag.name} sentinel")
    return out


class ContainerTagDrift(Rule):
    id = "RA005"
    name = "container-tag-drift"
    severity = "error"

    def __init__(self):
        self._values = _registry_values()

    def check_module(self, mod: ModuleInfo):
        if mod.rel == REGISTRY_MODULE:
            return
        for const in mod.bytes_consts:
            label = self._values.get(const.value)
            if label is not None:
                yield self.finding(
                    mod, const.lineno,
                    f"container tag literal {const.value!r} ({label}) "
                    "duplicated outside the shared registry — import it "
                    "from repro.sz.artifact so a format bump is one edit")
        for node in mod.assigns:
            targets = (node.targets if isinstance(node, ast.Assign)
                       else [node.target])
            if not isinstance(node.value, ast.Constant) \
                    or not isinstance(node.value.value, int) \
                    or isinstance(node.value.value, bool):
                continue
            for t in targets:
                if isinstance(t, ast.Name) and _VERSION_NAME.match(t.id):
                    yield self.finding(
                        mod, node.lineno,
                        f"format version constant {t.id} = "
                        f"{node.value.value} defined outside the shared "
                        "registry — alias repro.sz.artifact's version "
                        "instead (container versions must not fork)")
