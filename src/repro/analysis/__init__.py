"""Project-specific static analysis (docs/ANALYSIS.md).

An AST-based checker suite wired in as a tier-1 CI gate: one shared parse
+ walk per file (``engine``), five rules encoding the invariants the rest
of the stack only enforces by convention —

* **RA001 lock-discipline** (``locks``): attributes registered as
  lock-guarded are only mutated under ``with self.<lock>:``.
* **RA002 tracer-safety** (``tracer``): no host numpy / prints / Python
  data-dependent branching inside jit/vmap/pallas-traced functions.
* **RA003 kernel-triple-parity** (``parity``): every Pallas kernel has a
  ``ref.py`` oracle, a ``use_pallas=None`` dispatch in ``ops.py``, and a
  kernel-vs-ref test.
* **RA004 exception-hygiene** (``hygiene``): no swallowed broad excepts;
  integrity paths raise the ``repro.errors`` hierarchy.
* **RA005 container-tag-drift** (``tags``): container magic/version
  constants resolve to the one shared registry in ``sz/artifact.py``.

Shell surface: ``python -m repro.cli lint [--json] [--rule RAnnn ...]
[--baseline PATH] [--write-baseline]`` — exit 0 clean, 1 findings, 2
usage, matching the CLI-wide exit-code contract.
"""
from repro.analysis.engine import (
    ModuleInfo,
    ProjectContext,
    Rule,
    all_rules,
    analyze_source,
    run_analysis,
)
from repro.analysis.findings import Finding

__all__ = [
    "Finding",
    "ModuleInfo",
    "ProjectContext",
    "Rule",
    "all_rules",
    "analyze_source",
    "run_analysis",
]
