"""RA004 — exception hygiene: no silent broad catches, structured
integrity raises.

Two failure patterns this rule exists to keep out of the tree:

* **Swallowed broad excepts.**  ``except Exception`` (or a bare
  ``except:``) hides real defects — a typo inside the handler scope turns
  into "the sharding constraint silently didn't apply".  Flagged
  everywhere in ``src/repro`` except (a) a ``BaseException`` handler that
  visibly RE-RAISES (the cleanup-and-reraise idiom used by the mmap open
  path and the streaming executor is correct: cleanup must run for
  KeyboardInterrupt too), and (b) modules on the explicit
  :data:`ALLOWLIST` — reporting harnesses whose contract is to convert any
  per-cell failure into an error row.  Anything else needs a
  ``# lint: allow RA004 -- <reason>`` annotation.

* **Unstructured integrity raises.**  Inside the container modules
  (:data:`INTEGRITY_MODULES`), parse/verify functions must raise from the
  ``repro.errors`` hierarchy — ``CorruptContainerError`` /
  ``CorruptLaneError`` carry offsets and expectations callers dispatch on
  (docs/ROBUSTNESS.md); a raw ``ValueError("bad magic")`` or an ``assert``
  erases that structure and breaks the CLI's exit-code contract.
"""
from __future__ import annotations

import ast

from repro.analysis.engine import ModuleInfo, Rule

#: Modules where broad excepts are accepted by design: launch-time report
#: harnesses that must record any cell failure as data and keep sweeping.
ALLOWLIST = frozenset({"launch/dryrun.py", "launch/roofline.py"})

#: Modules whose parse/verify paths participate in the structured
#: integrity contract (docs/ROBUSTNESS.md).
INTEGRITY_MODULES = frozenset({
    "api.py", "exec/writer.py", "sz/artifact.py", "sz/entropy.py",
    "sz/szjax.py", "sz/tiled.py",
})

#: Exception names allowed from integrity paths: the repro.errors
#: hierarchy (plus bare re-raise, handled structurally).
INTEGRITY_RAISES = frozenset({
    "IntegrityError", "CorruptContainerError", "CorruptLaneError",
})

_BROAD = ("Exception", "BaseException")
_BUILTIN_BROAD = frozenset({
    "AssertionError", "Exception", "RuntimeError", "ValueError",
})


def _exc_names(node: ast.AST | None) -> list[str]:
    if node is None:
        return []
    if isinstance(node, ast.Tuple):
        return [n for e in node.elts for n in _exc_names(e)]
    if isinstance(node, ast.Name):
        return [node.id]
    if isinstance(node, ast.Attribute):
        return [node.attr]
    return []


def _integrity_fn(name: str) -> bool:
    return (name == "from_bytes" or name.startswith(("parse_", "_parse"))
            or name.startswith(("verify", "_verify"))
            or name.startswith(("check_", "_check")))


class ExceptionHygiene(Rule):
    id = "RA004"
    name = "exception-hygiene"
    severity = "error"

    def check_module(self, mod: ModuleInfo):
        if mod.rel not in ALLOWLIST:
            yield from self._broad_excepts(mod)
        if mod.rel in INTEGRITY_MODULES:
            yield from self._integrity_raises(mod)

    def _broad_excepts(self, mod: ModuleInfo):
        for handler in mod.excepts:
            names = _exc_names(handler.type)
            bare = handler.type is None
            if not bare and not any(n in _BROAD for n in names):
                continue
            if not bare and "Exception" not in names \
                    and self._reraises(handler):
                continue  # `except BaseException: <cleanup>; raise` idiom
            what = "bare except:" if bare else \
                f"except {' / '.join(n for n in names if n in _BROAD)}"
            yield self.finding(
                mod, handler.lineno,
                f"broad '{what}' swallows unrelated failures — catch "
                "concrete exception types, or catch BaseException and "
                "re-raise after cleanup")

    @staticmethod
    def _reraises(handler: ast.ExceptHandler) -> bool:
        return any(isinstance(n, ast.Raise) and n.exc is None
                   for n in ast.walk(handler))

    def _integrity_raises(self, mod: ModuleInfo):
        for raise_ in mod.raises:
            fn = mod.enclosing_function(raise_)
            if fn is None or not _integrity_fn(fn.name):
                continue
            exc = raise_.exc
            if exc is None:
                continue  # bare re-raise
            name = None
            if isinstance(exc, ast.Call):
                names = _exc_names(exc.func)
                name = names[0] if names else None
            elif isinstance(exc, (ast.Name, ast.Attribute)):
                names = _exc_names(exc)
                name = names[0] if names else None
            if name in _BUILTIN_BROAD and name not in INTEGRITY_RAISES:
                yield self.finding(
                    mod, raise_.lineno,
                    f"integrity path {fn.name}() raises bare {name} — raise "
                    "from the repro.errors hierarchy (CorruptContainerError/"
                    "CorruptLaneError carry offset + expectation)")
        for assert_ in mod.asserts:
            fn = mod.enclosing_function(assert_)
            if fn is not None and _integrity_fn(fn.name):
                yield self.finding(
                    mod, assert_.lineno,
                    f"integrity path {fn.name}() validates with assert — "
                    "asserts vanish under -O and raise unstructured "
                    "AssertionError; raise a repro.errors type instead")
