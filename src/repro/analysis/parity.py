"""RA003 — kernel-triple parity: every Pallas kernel ships with its oracle.

The accelerator layer is built as triples (docs/ARCHITECTURE.md): a Pallas
kernel module under ``kernels/``, a pure-jnp oracle in ``kernels/ref.py``
that defines the kernel's semantics, and a dispatch wrapper in
``kernels/ops.py`` that picks between them with the stack's
``use_pallas=None`` auto-detect rule.  A kernel whose oracle or dispatch is
missing can drift silently — its device bytes stop being checkable against
anything.  This project rule asserts, across files:

* every kernel module (a ``kernels/*.py`` that calls ``pallas_call``,
  other than ``ops``/``ref``) has at least one public function imported by
  ``kernels/ops.py``;
* every ``*_op`` dispatch in ``ops.py`` that reaches a kernel function
  also calls a ``ref.*`` oracle that actually exists in ``ref.py``, and
  exposes a ``use_pallas`` keyword defaulting to ``None`` (the auto-detect
  contract);
* every such dispatch name appears somewhere in ``tests/*.py`` — each op
  must be exercised by a kernel-vs-ref test.

Pure-jnp ops (no Pallas branch, e.g. ``lorenzo_decode_tiles_op``) are
exempt from the oracle/auto-detect checks: there is no kernel to compare.
"""
from __future__ import annotations

import ast

from repro.analysis.engine import ModuleInfo, ProjectContext, Rule

_EXCLUDED = ("kernels/__init__.py", "kernels/ops.py", "kernels/ref.py")


def _calls_pallas(mod: ModuleInfo) -> bool:
    for call in mod.calls:
        f = call.func
        if isinstance(f, ast.Attribute) and f.attr == "pallas_call":
            return True
        if isinstance(f, ast.Name) and f.id == "pallas_call":
            return True
    return False


def _top_level_defs(mod: ModuleInfo) -> list:
    return [s for s in mod.tree.body
            if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef))]


class KernelParity(Rule):
    id = "RA003"
    name = "kernel-triple-parity"
    severity = "error"

    def check_project(self, mods: list[ModuleInfo], ctx: ProjectContext):
        by_rel = {m.rel: m for m in mods}
        kernel_mods = [m for m in mods
                       if m.rel.startswith("kernels/") and m.rel not in _EXCLUDED
                       and _calls_pallas(m)]
        if not kernel_mods:
            return
        ops = by_rel.get("kernels/ops.py")
        ref = by_rel.get("kernels/ref.py")
        if ops is None:
            for m in kernel_mods:
                yield self.finding(
                    m, 1, f"Pallas kernel module {m.rel} has no kernels/ops.py "
                          "dispatch layer (kernel/ref/op triple is incomplete)")
            return
        ref_defs = {fn.name for fn in _top_level_defs(ref)} if ref else set()
        kernel_imports = self._kernel_imports(ops)

        # 1) every kernel module is reachable through the dispatch layer
        imported = set(kernel_imports)
        for m in kernel_mods:
            base = m.rel.rsplit("/", 1)[-1][:-3]
            public = {fn.name for fn in _top_level_defs(m)
                      if not fn.name.startswith("_")}
            if not public & imported:
                yield self.finding(
                    m, 1, f"no public function of kernel module {m.rel} is "
                          "imported by kernels/ops.py — the kernel is not "
                          f"dispatchable (exports: {sorted(public) or base})")

        # 2) every dispatch that reaches a kernel also reaches its oracle,
        #    honors use_pallas=None, and is covered by a test
        tests_text = ctx.tests_text()
        for fn in _top_level_defs(ops):
            if not fn.name.endswith("_op"):
                continue
            used = {n for n in self._names_used(fn)}
            kernel_used = used & imported
            if not kernel_used:
                continue  # pure-jnp op: no kernel branch to check
            ref_used = self._ref_attrs(fn)
            if not ref_used:
                yield self.finding(
                    ops, fn.lineno,
                    f"{fn.name} dispatches kernel(s) {sorted(kernel_used)} "
                    "but never calls a ref.* oracle — device output is "
                    "uncheckable against a reference")
            missing = sorted(ref_used - ref_defs)
            if missing:
                yield self.finding(
                    ops, fn.lineno,
                    f"{fn.name} calls ref.{missing[0]} but kernels/ref.py "
                    f"does not define it (missing oracles: {missing})")
            if not self._use_pallas_defaults_none(fn):
                yield self.finding(
                    ops, fn.lineno,
                    f"{fn.name} must take use_pallas: bool | None = None "
                    "(the auto-detect dispatch contract)")
            if tests_text and fn.name not in tests_text:
                yield self.finding(
                    ops, fn.lineno,
                    f"{fn.name} appears in no test under {ctx.tests_dir} — "
                    "every dispatch op needs a kernel-vs-ref parity test")
            elif not tests_text:
                yield self.finding(
                    ops, fn.lineno,
                    f"no tests directory found to cover {fn.name} "
                    "(kernel-vs-ref parity tests are required)")

    @staticmethod
    def _kernel_imports(ops: ModuleInfo) -> dict[str, str]:
        """name -> source module for ``from repro.kernels.X import a, b``."""
        out: dict[str, str] = {}
        for node in ast.walk(ops.tree):
            if isinstance(node, ast.ImportFrom) and node.module \
                    and ".kernels." in f".{node.module}." \
                    and not node.module.endswith((".ref", ".ops")):
                for alias in node.names:
                    out[alias.asname or alias.name] = node.module
        return out

    @staticmethod
    def _names_used(fn) -> set[str]:
        return {n.id for n in ast.walk(fn) if isinstance(n, ast.Name)}

    @staticmethod
    def _ref_attrs(fn) -> set[str]:
        return {n.attr for n in ast.walk(fn)
                if isinstance(n, ast.Attribute)
                and isinstance(n.value, ast.Name) and n.value.id == "ref"}

    @staticmethod
    def _use_pallas_defaults_none(fn) -> bool:
        a = fn.args
        pairs = list(zip(a.args[len(a.args) - len(a.defaults):], a.defaults)) \
            + [(p, d) for p, d in zip(a.kwonlyargs, a.kw_defaults) if d is not None]
        for param, default in pairs:
            if param.arg == "use_pallas":
                return isinstance(default, ast.Constant) and default.value is None
        return False
