"""RA002 — tracer safety inside jit/vmap/lax.map/shard_map/pallas functions.

A function handed to the JAX tracer runs ONCE at trace time with abstract
values; host-side work inside it either crashes (`TracerBoolConversionError`
on a Python branch over a traced value), silently constant-folds (a host
``np.*`` call on a tracer), or fires at trace time instead of run time
(``print``, mutation of enclosing state).  This rule finds functions that
enter the tracer —

* defs decorated ``@jax.jit`` / ``@partial(jax.jit, ...)``,
* functions (named or lambda) passed to ``jax.jit``, ``jax.vmap``,
  ``jax.lax.map``, ``shard_map``, or ``pl.pallas_call``

— and inside them flags:

* ``print(...)`` / ``breakpoint()`` calls (trace-time side effects);
* ``global`` / ``nonlocal`` declarations (mutation of enclosing state from
  inside a traced function);
* host ``np.*`` / ``numpy.*`` calls taking a traced parameter directly
  (``jnp`` is the traced-world spelling);
* ``if`` / ``while`` tests using a traced parameter as a bare name —
  Python-level data-dependent control flow.  Attribute reads like
  ``x.ndim``/``x.shape`` are static and stay allowed, and parameters named
  in the jit's ``static_argnames``/``static_argnums`` are excluded, so
  config-style branching on static arguments does not fire.
"""
from __future__ import annotations

import ast

from repro.analysis.engine import ModuleInfo, Rule

# call targets (dotted-name suffixes) whose first function argument is traced
_WRAPPERS_ARG0 = ("jax.jit", "jax.vmap", "jax.lax.map", "lax.map",
                  "shard_map", "pallas_call", "pl.pallas_call")
_HOST_MODULES = ("np", "numpy")
_SIDE_EFFECT_CALLS = ("print", "breakpoint")


def _dotted(node: ast.AST) -> str | None:
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _const_str_items(node: ast.AST | None) -> list:
    if isinstance(node, ast.Constant):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        return [e.value for e in node.elts if isinstance(e, ast.Constant)]
    return []


def _param_names(fn) -> list[str]:
    a = fn.args
    names = [p.arg for p in (a.posonlyargs + a.args + a.kwonlyargs)]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return names


class TracerSafety(Rule):
    id = "RA002"
    name = "tracer-safety"
    severity = "error"

    def check_module(self, mod: ModuleInfo):
        seen: set[ast.AST] = set()
        for fn, static in self._traced_functions(mod):
            if fn in seen:
                continue
            seen.add(fn)
            traced = set(_param_names(fn)) - static
            yield from self._check_traced(fn, traced, mod)

    # -- which functions enter the tracer ------------------------------------

    def _traced_functions(self, mod: ModuleInfo):
        by_name = {}
        for fn in mod.functions:
            by_name.setdefault(fn.name, fn)
        # decorated defs
        for fn in mod.functions:
            for dec in fn.decorator_list:
                static = self._jit_static(dec, fn)
                if static is not None:
                    yield fn, static
        # functions passed by value to tracing wrappers
        for call in mod.calls:
            name = _dotted(call.func)
            if name is None or not name.endswith(_WRAPPERS_ARG0):
                continue
            if not call.args:
                continue
            target = call.args[0]
            if isinstance(target, ast.Lambda):
                yield target, set()
            elif isinstance(target, ast.Name) and target.id in by_name:
                yield by_name[target.id], set()

    def _jit_static(self, dec: ast.AST, fn) -> set[str] | None:
        """Static parameter names when ``dec`` marks ``fn`` as jitted,
        else None (not a jit decorator)."""
        name = _dotted(dec)
        if name in ("jit", "jax.jit"):
            return set()
        if not isinstance(dec, ast.Call):
            return None
        cname = _dotted(dec.func)
        inner = None
        if cname in ("jit", "jax.jit"):
            inner = dec
        elif cname in ("partial", "functools.partial") and dec.args \
                and _dotted(dec.args[0]) in ("jit", "jax.jit"):
            inner = dec
        if inner is None:
            return None
        static: set[str] = set()
        params = _param_names(fn)
        for kw in inner.keywords:
            if kw.arg == "static_argnames":
                static.update(s for s in _const_str_items(kw.value)
                              if isinstance(s, str))
            elif kw.arg == "static_argnums":
                for i in _const_str_items(kw.value):
                    if isinstance(i, int) and 0 <= i < len(params):
                        static.add(params[i])
        return static

    # -- what must not happen inside one -------------------------------------

    def _check_traced(self, fn, traced: set[str], mod: ModuleInfo):
        where = getattr(fn, "name", "<lambda>")
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                cname = _dotted(node.func)
                if cname in _SIDE_EFFECT_CALLS:
                    yield self.finding(
                        mod, node.lineno,
                        f"host side effect {cname}() inside traced function "
                        f"{where} (runs at trace time, not per step)")
                elif cname is not None and "." in cname \
                        and cname.split(".", 1)[0] in _HOST_MODULES:
                    hit = self._traced_arg(node, traced)
                    if hit is not None:
                        yield self.finding(
                            mod, node.lineno,
                            f"host numpy call {cname}() on traced value "
                            f"'{hit}' inside {where} (use jnp, or hoist to "
                            "the host stage)")
            elif isinstance(node, (ast.Global, ast.Nonlocal)):
                kind = "global" if isinstance(node, ast.Global) else "nonlocal"
                yield self.finding(
                    mod, node.lineno,
                    f"{kind} mutation inside traced function {where} "
                    "(side effects fire at trace time)")
            elif isinstance(node, (ast.If, ast.While)):
                hit = self._traced_name_in_test(node.test, traced)
                if hit is not None:
                    yield self.finding(
                        mod, node.lineno,
                        f"Python-level branch on traced value '{hit}' inside "
                        f"{where} (data-dependent control flow needs "
                        "lax.cond/lax.select, or mark the argument static)")

    @staticmethod
    def _traced_arg(call: ast.Call, traced: set[str]) -> str | None:
        for arg in list(call.args) + [kw.value for kw in call.keywords]:
            if isinstance(arg, ast.Starred):
                arg = arg.value
            if isinstance(arg, ast.Name) and arg.id in traced:
                return arg.id
        return None

    def _traced_name_in_test(self, test: ast.AST, traced: set[str]) -> str | None:
        """A traced parameter used as a BARE name in a branch test.

        Names under an Attribute (``x.ndim``) or a call result are skipped —
        shape/dtype/ndim reads are static facts about a tracer.  Identity
        tests against ``None`` (``if rng is None:``) are also skipped: a
        tracer is never ``None``, the comparison is a static Python fact
        and no boolean conversion of the tracer happens."""
        parents: dict[ast.AST, ast.AST] = {}
        stack = [test]
        while stack:
            node = stack.pop()
            for child in ast.iter_child_nodes(node):
                parents[child] = node
                stack.append(child)
            if isinstance(node, ast.Name) and node.id in traced:
                p = parents.get(node)
                if isinstance(p, ast.Attribute) and p.value is node:
                    continue
                if isinstance(p, ast.Call):
                    continue  # f(x) in a test: the call decides staticness
                if isinstance(p, ast.Compare) and self._is_none_identity(p):
                    continue
                return node.id
        return None

    @staticmethod
    def _is_none_identity(cmp: ast.Compare) -> bool:
        """True for ``x is None`` / ``x is not None`` shaped comparisons."""
        if not all(isinstance(op, (ast.Is, ast.IsNot)) for op in cmp.ops):
            return False
        operands = [cmp.left] + list(cmp.comparators)
        return any(isinstance(o, ast.Constant) and o.value is None
                   for o in operands)
