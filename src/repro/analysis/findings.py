"""Finding type + inline suppression / annotation comment parsing.

A :class:`Finding` is one rule violation pinned to a file and line; the
engine sorts, deduplicates, suppresses, and reports them
(docs/ANALYSIS.md).  Two comment micro-syntaxes live here because every
rule and the engine share them:

* ``# lint: allow RA004 -- <reason>`` suppresses the named rule(s) on its
  line (or, as a standalone comment, on the line below).  The reason is
  REQUIRED: a reasonless suppression is itself reported (rule ``RA000``),
  so an annotation always records *why* the violation is intended.
* ``# guarded-by: _lock`` registers the attribute assigned on that line as
  lock-guarded shared state for the RA001 lock-discipline rule.
"""
from __future__ import annotations

import re
from dataclasses import dataclass

SEVERITIES = ("error", "warning")

# the engine's own rule id: malformed suppressions, unreadable/unparseable
# files — meta-findings about the analysis input itself
ENGINE_RULE = "RA000"

_SUPPRESS_RE = re.compile(
    r"#\s*lint:\s*allow\s+(?P<rules>RA\d{3}(?:\s*,\s*RA\d{3})*)"
    r"(?:\s*--\s*(?P<reason>\S.*))?")
_GUARD_RE = re.compile(r"#\s*guarded-by:\s*(?P<lock>\w+)")


@dataclass(frozen=True)
class Finding:
    """One rule violation: where, which rule, how bad, and what."""

    path: str  # root-relative posix path
    line: int
    rule: str  # "RA001".."RA005" (or RA000 for engine meta-findings)
    severity: str  # "error" | "warning"
    message: str

    def sort_key(self) -> tuple:
        return (self.path, self.line, self.rule, self.message)

    def baseline_key(self) -> tuple:
        """Identity used by ``--baseline`` matching: line numbers drift as
        files are edited, so a baselined finding is keyed on content."""
        return (self.path, self.rule, self.message)

    def to_json(self) -> dict:
        return {"path": self.path, "line": self.line, "rule": self.rule,
                "severity": self.severity, "message": self.message}

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.severity}: {self.message}"


def parse_suppressions(lines: list[str]) -> tuple[dict[int, set[str]], list[tuple[int, str]]]:
    """Scan source lines for ``# lint: allow`` comments.

    Returns ``(allow, malformed)``: ``allow`` maps 1-based line numbers to
    the rule ids suppressed there; ``malformed`` lists ``(line, rules)``
    pairs whose annotation is missing the required ``-- reason`` string.
    """
    allow: dict[int, set[str]] = {}
    malformed: list[tuple[int, str]] = []
    for i, text in enumerate(lines, start=1):
        m = _SUPPRESS_RE.search(text)
        if m is None:
            continue
        rules = {r.strip() for r in m.group("rules").split(",")}
        if not m.group("reason"):
            malformed.append((i, ", ".join(sorted(rules))))
            continue
        allow.setdefault(i, set()).update(rules)
    return allow, malformed


def guard_annotation(line_text: str) -> str | None:
    """The lock name a ``# guarded-by: <name>`` comment declares, or None."""
    m = _GUARD_RE.search(line_text)
    return m.group("lock") if m else None
