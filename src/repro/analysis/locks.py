"""RA001 — lock discipline on registered shared state.

The serving/caching layer holds shared mutable state behind per-instance
locks (``TileCache``, ``VolumePool``, ``AdmissionController``, ``_Metrics``,
``DecodeStats``).  PR 7 shipped two real races here — unsynchronized
``DecodeStats`` counters and lock-free ``TileCache`` reads — exactly the
class of bug load tests stop catching once requests shard across hosts.
This rule makes the contract checkable:

* an attribute is REGISTERED as guarded either by a ``# guarded-by: <lock>``
  comment on the line that initializes it (``self.x = 0  # guarded-by:
  _lock``) or through a class-level ``GUARDED = {"attr": "_lock"}`` dict;
* every mutation of a registered attribute (assignment, augmented
  assignment, ``del``, item store, or a mutating method call such as
  ``.append``/``.pop``/``.update``) must be lexically inside a
  ``with self.<lock>:`` block naming the registered lock;
* ``__init__`` is exempt — the object is not shared while it is being
  constructed.

``Condition`` objects count as locks (``with self._cv:`` guards the state
the condition protects).  Reads are deliberately out of scope: immutable
and monotone reads are common and fine; it is lost *updates* that corrupt
the metrics and cache accounting.
"""
from __future__ import annotations

import ast

from repro.analysis.engine import ModuleInfo, Rule
from repro.analysis.findings import guard_annotation

# Mutating container/deque/dict/set methods: calling one of these on a
# guarded attribute mutates it just as surely as assignment does.
MUTATING_METHODS = frozenset({
    "add", "append", "appendleft", "clear", "discard", "extend", "insert",
    "move_to_end", "pop", "popitem", "popleft", "remove", "setdefault",
    "update",
})


def _self_attr(node: ast.AST) -> str | None:
    """``self.<attr>`` -> attr name (drilling through subscripts, so
    ``self._d[k]`` and ``self._d[k][j]`` both resolve to ``_d``)."""
    while isinstance(node, ast.Subscript):
        node = node.value
    if isinstance(node, ast.Attribute) and \
            isinstance(node.value, ast.Name) and node.value.id == "self":
        return node.attr
    return None


def _flatten_targets(target: ast.AST):
    if isinstance(target, (ast.Tuple, ast.List)):
        for elt in target.elts:
            yield from _flatten_targets(elt)
    else:
        yield target


class LockDiscipline(Rule):
    id = "RA001"
    name = "lock-discipline"
    severity = "error"

    def check_module(self, mod: ModuleInfo):
        for cls in mod.classes:
            guarded = self._guarded_attrs(cls, mod)
            if guarded:
                yield from self._check_class(cls, guarded, mod)

    # -- registration --------------------------------------------------------

    def _guarded_attrs(self, cls: ast.ClassDef, mod: ModuleInfo) -> dict[str, str]:
        """attr -> lock name, from guard comments + the GUARDED registry."""
        guarded: dict[str, str] = {}
        for node in ast.walk(cls):
            if isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
                if mod.enclosing_class(node) is not cls:
                    continue  # a nested class's annotations are its own
                lock = guard_annotation(mod.line(node.lineno))
                if lock is None:
                    continue
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                for t in targets:
                    for leaf in _flatten_targets(t):
                        attr = _self_attr(leaf)
                        if attr is not None:
                            guarded[attr] = lock
        # class-level registry: GUARDED = {"attr": "_lock", ...}
        for stmt in cls.body:
            if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                    and isinstance(stmt.targets[0], ast.Name) \
                    and stmt.targets[0].id == "GUARDED" \
                    and isinstance(stmt.value, ast.Dict):
                for k, v in zip(stmt.value.keys, stmt.value.values):
                    if isinstance(k, ast.Constant) and isinstance(k.value, str) \
                            and isinstance(v, ast.Constant) \
                            and isinstance(v.value, str):
                        guarded[k.value] = v.value
        return guarded

    # -- enforcement ---------------------------------------------------------

    def _check_class(self, cls: ast.ClassDef, guarded: dict[str, str],
                     mod: ModuleInfo):
        for node in ast.walk(cls):
            if mod.enclosing_class(node) is not cls:
                continue
            fn = mod.enclosing_function(node)
            if fn is None or fn.name == "__init__":
                continue  # class body / construction: not shared yet
            for attr, where in self._mutations(node):
                lock = guarded.get(attr)
                if lock is None:
                    continue
                if not self._lock_held(where, lock, mod):
                    yield self.finding(
                        mod, where.lineno,
                        f"{cls.name}.{attr} is registered as guarded by "
                        f"self.{lock} but is mutated in {fn.name}() outside "
                        f"a 'with self.{lock}:' block")

    def _mutations(self, node: ast.AST):
        """(attr, node) pairs for every self-attribute mutation in node."""
        if isinstance(node, (ast.Assign, ast.AugAssign)):
            targets = (node.targets if isinstance(node, ast.Assign)
                       else [node.target])
            for t in targets:
                for leaf in _flatten_targets(t):
                    attr = _self_attr(leaf)
                    if attr is not None:
                        yield attr, node
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            attr = _self_attr(node.target)
            if attr is not None:
                yield attr, node
        elif isinstance(node, ast.Delete):
            for t in node.targets:
                attr = _self_attr(t)
                if attr is not None:
                    yield attr, node
        elif isinstance(node, ast.Call) \
                and isinstance(node.func, ast.Attribute) \
                and node.func.attr in MUTATING_METHODS:
            attr = _self_attr(node.func.value)
            if attr is not None:
                yield attr, node

    def _lock_held(self, node: ast.AST, lock: str, mod: ModuleInfo) -> bool:
        for anc in mod.ancestors(node):
            if isinstance(anc, (ast.With, ast.AsyncWith)):
                for item in anc.items:
                    e = item.context_expr
                    if isinstance(e, ast.Attribute) \
                            and isinstance(e.value, ast.Name) \
                            and e.value.id == "self" and e.attr == lock:
                        return True
        return False
