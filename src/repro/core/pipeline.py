"""GWLZ end-to-end pipeline (paper Figs. 1-2): compression module +
reconstruction module, with the trained enhancer weights attached to the
compressed stream (fp32, as in §4.1)."""
from __future__ import annotations

import struct
from dataclasses import dataclass, replace
from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metrics
from repro.core.trainer import (
    GWLZModel,
    GWLZTrainConfig,
    enhance,
    enhance_tiles,
    train_enhancers,
    train_enhancers_tiled,
)
from repro.sz.szjax import SZCompressed, SZCompressor

_GW_MAGIC = b"GWLZ"


# ---------------------------------------------------------------------------
# model (de)serialization — becomes extras["gwlz"] in the SZ artifact
# ---------------------------------------------------------------------------


def serialize_model(model: GWLZModel) -> bytes:
    cfg = model.cfg
    head = _GW_MAGIC + struct.pack(
        "<IIIB3x",
        cfg.n_groups,
        cfg.channels,
        {"quantile": 0, "range": 1, "log": 2}[cfg.strategy],
        1 if cfg.residual_learning else 0,
    )
    blobs = []
    leaves, _ = jax.tree_util.tree_flatten(model.params)
    leaves += jax.tree_util.tree_flatten(model.bn_state)[0]
    leaves += [model.edges, model.rscale]
    for leaf in leaves:
        arr = np.asarray(leaf, np.float32)
        blobs.append(struct.pack("<I", arr.size) + arr.tobytes())
    return head + b"".join(blobs)


def deserialize_model(blob: bytes) -> GWLZModel:
    assert blob[:4] == _GW_MAGIC, "bad GWLZ model blob"
    n_groups, channels, strat, resid = struct.unpack_from("<IIIB", blob, 4)
    cfg = GWLZTrainConfig(
        n_groups=n_groups,
        channels=channels,
        strategy={0: "quantile", 1: "range", 2: "log"}[strat],
        residual_learning=bool(resid),
    )
    off = 4 + struct.calcsize("<IIIB3x")

    def read(shape):
        nonlocal off
        (n,) = struct.unpack_from("<I", blob, off)
        off += 4
        arr = np.frombuffer(blob, np.float32, n, offset=off).copy().reshape(shape)
        off += 4 * n
        return jnp.asarray(arr)

    G, C = n_groups, channels
    params = {
        "b1": read((G, C)),
        "b2": read((G, 1)),
        "beta": read((G, C)),
        "gamma": read((G, C)),
        "w1": read((G, 3, 3, 1, C)),
        "w2": read((G, 3, 3, C, 1)),
    }
    bn_state = {"mean": read((G, C)), "var": read((G, C))}
    edges = read((G + 1,))
    rscale = read((G,))
    return GWLZModel(params=params, bn_state=bn_state, edges=edges, rscale=rscale, cfg=cfg)


# Decode-side cache: random-access consumers (api.CompressedVolume slicing,
# the CLI region path) decode many small ROIs from one artifact, and the
# attached model blob is identical every time — parse it once, not per slice.
# Keyed on the blob bytes (hashable); models are treated as immutable.
_deserialize_model_cached = lru_cache(maxsize=8)(deserialize_model)


# ---------------------------------------------------------------------------
# pipeline
# ---------------------------------------------------------------------------


@dataclass
class GWLZStats:
    psnr_sz: float
    psnr_gwlz: float
    cr_sz: float
    cr_gwlz: float
    overhead: float  # extra bytes / sz bytes (paper Table 2 col 5)
    max_err_sz: float
    max_err_gwlz: float
    eb_abs: float
    n_model_params: int
    loss_history: np.ndarray | None = None


class GWLZ:
    """compress(): SZ3-class compression + group-wise enhancer training.
    decompress(): SZ decode + group-wise enhancement (Figs. 1-2).

    The canonical entry points are container-agnostic: :meth:`compress_volume`
    returns a lazy :class:`repro.api.CompressedVolume` handle and
    :meth:`decode` accepts either artifact (monolithic ``SZJX`` or tiled
    ``GWTC``) plus an optional ROI.  The historical per-container methods
    (``compress``/``compress_tiled``/``decompress``/``decompress_tiled``/
    ``decompress_region``) survive as thin shims over those two."""

    def __init__(
        self,
        sz: SZCompressor | None = None,
        train_cfg: GWLZTrainConfig = GWLZTrainConfig(),
        clamp_to_bound: bool = False,
    ):
        self.sz = sz or SZCompressor()
        self.train_cfg = train_cfg
        self.clamp_to_bound = clamp_to_bound

    # -- shared orchestration core (monolithic and tiled paths) ----------------

    def _clamp(self, artifact) -> float | None:
        return artifact.eb_abs if self.clamp_to_bound else None

    def _finish_compress(
        self, x, artifact, recon, *, train_fn, enhance_fn, callback
    ) -> tuple["object", GWLZStats]:
        """The single train+attach+enhance+stats sequence both compression
        front ends share: fit enhancers on (recon, residual), attach the
        serialized model to the artifact's extras, enhance the training
        volume, and report the paper's metrics."""
        sz_bytes = artifact.nbytes
        model, history = train_fn(recon, x - recon, callback)
        artifact.extras["gwlz"] = serialize_model(model)
        enhanced = enhance_fn(recon, model)
        total_bytes = artifact.nbytes
        stats = GWLZStats(
            psnr_sz=float(metrics.psnr(x, recon)),
            psnr_gwlz=float(metrics.psnr(x, enhanced)),
            cr_sz=float(x.nbytes / sz_bytes),
            cr_gwlz=float(x.nbytes / total_bytes),
            overhead=float((total_bytes - sz_bytes) / sz_bytes),
            max_err_sz=float(metrics.max_abs_err(x, recon)),
            max_err_gwlz=float(metrics.max_abs_err(x, enhanced)),
            eb_abs=artifact.eb_abs,
            n_model_params=model.n_params,
            loss_history=history["loss"],
        )
        return artifact, stats

    def _compress_mono(self, x, *, rel_eb, abs_eb, callback):
        x = jnp.asarray(x, jnp.float32)
        artifact, recon = self.sz.compress(x, rel_eb=rel_eb, abs_eb=abs_eb)
        return self._finish_compress(
            x, artifact, recon,
            train_fn=lambda r, res, cb: train_enhancers(r, res, self.train_cfg, callback=cb),
            enhance_fn=lambda r, m: enhance(r, m, clamp_eb=self._clamp(artifact)),
            callback=callback,
        )

    # -- canonical container-agnostic entry points -----------------------------

    def compress_volume(
        self, x: jax.Array, *, tiled: bool = False, tile=(64, 64, 64),
        rel_eb: float | None = None, abs_eb: float | None = None,
        predictor: str | None = None, callback=None,
    ):
        """Compress + train + attach, returning a lazy
        :class:`repro.api.CompressedVolume` handle (``vol.stats`` carries the
        paper metrics; decode/slicing routes back through this pipeline so
        the attached enhancer is always applied)."""
        from repro.api import CompressedVolume

        if tiled:
            artifact, stats = self._compress_tiled(
                x, tile, rel_eb=rel_eb, abs_eb=abs_eb, predictor=predictor,
                callback=callback)
        else:
            if predictor is not None and predictor != self.sz.predictor:
                raise ValueError(
                    "monolithic predictor is fixed by the SZCompressor; "
                    f"construct GWLZ(sz=SZCompressor(predictor={predictor!r}))")
            artifact, stats = self._compress_mono(
                x, rel_eb=rel_eb, abs_eb=abs_eb, callback=callback)
        return CompressedVolume(artifact, stats=stats, pipeline=self)

    def decode(self, artifact, roi=None, *, workers: int | None = None) -> jax.Array:
        """Container-agnostic decode: full volume, or just ``roi``.

        Tiled artifacts route an ROI to the random-access region path
        (entropy-decoding only intersecting lanes, enhancer applied per
        tile); monolithic artifacts decode once and crop after enhancement —
        either way the ROI result is bit-identical to the full decode's
        crop."""
        from repro.sz import tiled
        from repro.sz.tiled import TiledCompressed

        if isinstance(artifact, TiledCompressed):
            transform = self._tile_enhancer(artifact)
            if roi is None:
                return tiled.decompress_tiled(
                    artifact, workers=workers, tile_transform=transform)
            return tiled.decompress_region(
                artifact, roi, workers=workers, tile_transform=transform)
        recon = self.sz.decompress(artifact)
        blob = artifact.extras.get("gwlz")
        if blob is not None:
            recon = enhance(recon, _deserialize_model_cached(blob),
                            clamp_eb=self._clamp(artifact))
        if roi is None:
            return recon
        from repro.sz.tiled import normalize_roi

        bounds = normalize_roi(roi, tuple(artifact.shape))
        return recon[tuple(slice(lo, hi) for lo, hi in bounds)]

    def decode_tiles(self, artifact, lane_ids, *, workers: int | None = None,
                     bucket_cap: int | None = None) -> jax.Array:
        """Decode the named lanes of a tiled artifact to FINAL per-tile
        values (enhancer applied when attached): ``[len(ids), *tile]``.

        This is the unit the façade's concurrent tile cache stores — the
        per-tile programs are fixed-shape, so any subset reconstructs the
        exact bits the full decode would, and cached tiles can be stitched
        with freshly decoded ones.  Batches dispatch bucket-padded
        (``tiled.dispatch_bucketed``) so arbitrary lane counts reuse a
        bounded set of compiled programs; ``bucket_cap=0`` disables."""
        from repro.sz import tiled

        recon, _, bad = tiled.decode_lanes(artifact, lane_ids, workers=workers,
                                           with_mask=True,
                                           bucket_cap=bucket_cap)
        transform = self._tile_enhancer(artifact)
        if transform is not None:
            recon = tiled.apply_tile_transform(transform, recon,
                                               bucket_cap=bucket_cap)
            # quarantined tiles must stay at the fill value — the enhancer
            # must not fabricate data for a lane that failed its checksum
            recon = tiled._refill_quarantined(recon, bad, artifact.fill_value)
        return recon

    # -- per-container shims ---------------------------------------------------

    def compress(
        self, x: jax.Array, *, rel_eb: float | None = None, abs_eb: float | None = None,
        callback=None,
    ) -> tuple[SZCompressed, GWLZStats]:
        return self._compress_mono(x, rel_eb=rel_eb, abs_eb=abs_eb, callback=callback)

    def decompress(self, artifact: SZCompressed) -> jax.Array:
        return self.decode(artifact)

    # -- tiled path (GWTC container, random-access decode) --------------------

    def _tile_enhancer(self, artifact):
        """Per-tile enhancement transform for decoded tile batches, or None.

        One ``lax.map``-batched call (``trainer.enhance_tiles``) that
        compiles a single fixed-tile-shape per-tile program: the per-tile
        program does not depend on how many tiles are batched, so region
        decode and full decode enhance every tile bit-identically — the
        contract ``repro.sz.tiled`` requires of any ``tile_transform`` —
        while the decode hot path pays one dispatch instead of ~n_tiles."""
        blob = artifact.extras.get("gwlz")
        if blob is None:
            return None
        model = _deserialize_model_cached(blob)
        clamp = self._clamp(artifact)

        def transform(tiles: jax.Array) -> jax.Array:
            return enhance_tiles(tiles, model, clamp_eb=clamp)

        # compiled-program identity for the bucketed dispatcher
        # (tiled.apply_tile_transform): every static knob that changes the
        # traced enhancer program, never the batch size
        transform.program_key = (
            "gwlz-enhance", int(model.cfg.n_groups), int(model.cfg.channels),
            bool(model.cfg.residual_learning), tuple(artifact.tile),
            clamp is not None)
        return transform

    def _compress_tiled(
        self, x: jax.Array, tile=(64, 64, 64), *,
        rel_eb: float | None = None, abs_eb: float | None = None,
        predictor: str | None = None, callback=None,
    ) -> tuple["object", GWLZStats]:
        """Tile-grid GWLZ: tiled SZ compress (any registered predictor), then
        ONE batched enhancer training pass over the per-tile slice stack; the
        model rides in the GWTC container's extras.  Returns (TiledCompressed,
        stats)."""
        from repro.sz import tiled

        x = jnp.asarray(x, jnp.float32)
        if x.ndim != 3:
            raise ValueError("tiled GWLZ needs a 3D volume (enhancers are 2D CNNs)")
        artifact, recon = self.sz.compress_tiled(
            x, tile, rel_eb=rel_eb, abs_eb=abs_eb, predictor=predictor)

        # Train on the DECODER'S OWN tiles — the exact arrays decompression
        # will feed the enhancer.  Re-padding the cropped recon would differ
        # in the pad region for interp (its decode of the padded input is not
        # edge replication of the crop), skewing training and stats away
        # from what gw.decompress_tiled(artifact) actually produces.
        recon_tiles, _ = tiled.decode_lanes(artifact, range(artifact.n_tiles))
        resid_tiles = tiled.split_tiles(
            tiled.pad_to_tiles(x, artifact.tile), artifact.tile) - recon_tiles

        def train_fn(_recon, _residual, cb):
            return train_enhancers_tiled(
                recon_tiles, resid_tiles, self.train_cfg, callback=cb)

        def enhance_fn(_recon, model):
            enhanced_tiles = self._tile_enhancer(artifact)(recon_tiles)
            return tiled.stitch_tiles(enhanced_tiles, artifact.grid)[
                tuple(slice(0, d) for d in x.shape)]

        return self._finish_compress(
            x, artifact, recon, train_fn=train_fn, enhance_fn=enhance_fn,
            callback=callback)

    def compress_tiled(
        self, x: jax.Array, tile=(64, 64, 64), *,
        rel_eb: float | None = None, abs_eb: float | None = None,
        predictor: str | None = None, callback=None,
    ) -> tuple["object", GWLZStats]:
        return self._compress_tiled(
            x, tile, rel_eb=rel_eb, abs_eb=abs_eb, predictor=predictor,
            callback=callback)

    def decompress_tiled(self, artifact, *, workers: int | None = None) -> jax.Array:
        return self.decode(artifact, workers=workers)

    def decompress_region(self, artifact, roi, *, workers: int | None = None) -> jax.Array:
        """ROI decode touching only intersecting tiles; enhancement (when a
        model is attached) runs on exactly those tiles."""
        return self.decode(artifact, roi, workers=workers)


def quick_compress(x, rel_eb=1e-3, n_groups=20, epochs=60, **kw):
    """Convenience entry point used by examples/tests (reduced epochs)."""
    cfg = GWLZTrainConfig(n_groups=n_groups, epochs=epochs, **kw)
    return GWLZ(train_cfg=cfg).compress(x, rel_eb=rel_eb)
