"""Value-based group partitioning (paper §3.3).

The paper partitions the decompressed volume into ``n`` groups "according to
value ranges" so each group has a narrow min-max span and near-Gaussian
distribution.  Three strategies:

* ``"quantile"`` (default) — equal-mass bins; balances sample counts, which is
  what makes the per-group distributions Gaussian-like in Fig. 7.
* ``"range"``  — equal-width bins over [min, max] (the literal reading).
* ``"log"``    — log-spaced bins; natural for the log-skewed Nyx fields.

Grouping is computed on the *decompressed* data so the reconstruction side
can reproduce it bit-exactly without access to the original.
"""
from __future__ import annotations

from functools import partial

import jax
import numpy as np
import jax.numpy as jnp

STRATEGIES = ("quantile", "range", "log")


def compute_edges(x: jax.Array, n_groups: int, strategy: str = "quantile") -> jax.Array:
    """Monotone bin edges, shape [n_groups + 1]; edges[0]=-inf, edges[-1]=+inf
    semantics are applied by :func:`assign_groups` (values clamp into end bins)."""
    x = jnp.asarray(x)
    flat = x.ravel()
    if strategy == "quantile":
        qs = jnp.linspace(0.0, 1.0, n_groups + 1)
        edges = jnp.quantile(flat, qs)
        # Coarsely quantized data produces *duplicate* quantiles (mass ties at
        # grid values), which would become degenerate near-empty bins that
        # can't train an enhancer.  Merge duplicates: each surviving bin keeps
        # real mass; the removed bins are re-padded past the max (empty, and
        # therefore inactive via min_group_pixels).
        e = np.asarray(edges, np.float64)
        rng_ = max(e[-1] - e[0], 1e-30)
        keep = [e[0]]
        for v in e[1:]:
            if v - keep[-1] > rng_ * 1e-6:
                keep.append(v)
        pad = rng_ * 1e-3
        while len(keep) < n_groups + 1:
            keep.append(keep[-1] + pad)
        return jnp.asarray(np.asarray(keep), x.dtype)
    lo = jnp.min(flat)
    hi = jnp.max(flat)
    if strategy == "range":
        return jnp.linspace(lo, hi, n_groups + 1).astype(x.dtype)
    if strategy == "log":
        shift = jnp.where(lo <= 0, -lo + 1e-6 * (hi - lo) + 1e-30, 0.0)
        le = jnp.linspace(jnp.log(lo + shift), jnp.log(hi + shift), n_groups + 1)
        return (jnp.exp(le) - shift).astype(x.dtype)
    raise ValueError(f"unknown grouping strategy {strategy!r}")


def assign_groups(x: jax.Array, edges: jax.Array) -> jax.Array:
    """int32 group id per element, in [0, n_groups)."""
    n_groups = edges.shape[0] - 1
    ids = jnp.searchsorted(edges, x.ravel(), side="right") - 1
    return jnp.clip(ids, 0, n_groups - 1).reshape(x.shape).astype(jnp.int32)


@partial(jax.jit, static_argnames=("n_groups",))
def group_masks(ids: jax.Array, n_groups: int) -> jax.Array:
    """bool [n_groups, *ids.shape] one-hot masks."""
    return jax.nn.one_hot(ids, n_groups, axis=0, dtype=jnp.bool_)


def group_normalizers(edges: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(lo[g], scale[g]) for min-max normalization of group inputs.

    End bins use the edge values; widths are guarded against zero.
    """
    lo = edges[:-1]
    hi = edges[1:]
    scale = jnp.maximum(hi - lo, 1e-12)
    return lo, scale


def group_stats(x: jax.Array, ids: jax.Array, n_groups: int) -> dict:
    """Per-group count/mean/min/max — used by benchmarks to reproduce Fig. 7."""
    flat = x.ravel()
    gid = ids.ravel()
    counts = jnp.zeros(n_groups).at[gid].add(1.0)
    sums = jnp.zeros(n_groups).at[gid].add(flat)
    mins = jnp.full(n_groups, jnp.inf).at[gid].min(flat)
    maxs = jnp.full(n_groups, -jnp.inf).at[gid].max(flat)
    return {
        "count": counts,
        "mean": sums / jnp.maximum(counts, 1.0),
        "min": mins,
        "max": maxs,
    }
