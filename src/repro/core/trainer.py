"""Group-wise residual training (paper §3.2-3.3).

All G enhancers are trained *simultaneously* as one SPMD program: the group
axis is a leading batch axis of the parameter pytree (``vmap`` over models).
On a production mesh the group axis maps to ``model`` and the slice batch to
``data`` (see repro.launch.gwlz_dist); on one host it is a plain vmap.

Faithful knobs (paper §4.1): C=9 channels / 2 convs (~200 params per model),
batch of 10 slices, 300 epochs, Adam lr 1e-3 with a step decay every 30
epochs.  ``residual_learning=False`` reproduces the "Regular" baseline of
Fig. 5 (predict the original data directly instead of the residual).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import enhancer, grouping
from repro.optim import AdamWConfig
from repro.optim import adamw
from repro.optim.schedule import step_decay


@dataclass(frozen=True)
class GWLZTrainConfig:
    n_groups: int = 20
    strategy: str = "quantile"
    channels: int = 9
    epochs: int = 300
    batch_size: int = 10
    lr: float = 1e-3
    lr_decay_every_epochs: int = 30
    lr_decay_factor: float = 0.5
    seed: int = 0
    slice_axis: int = 0
    residual_learning: bool = True  # False -> Fig. 5 "Regular" baseline
    # Robustness beyond the paper (DESIGN.md §8): tiny groups can't train a
    # CNN (masked-BN variance degenerates), and a group whose enhancement
    # hurts on the training volume should be disabled — both get identity
    # enhancement via rscale=0.  Costs nothing in the stream.
    min_group_pixels: int = 1024
    gate_groups: bool = True


@dataclass
class GWLZModel:
    """Everything the reconstruction side needs (serialized into the stream)."""

    params: dict  # leaves have leading [G] axis
    bn_state: dict  # leading [G]
    edges: jax.Array  # [G+1]
    rscale: jax.Array  # [G] residual normalization scale
    cfg: GWLZTrainConfig = field(default_factory=GWLZTrainConfig)

    @property
    def n_params(self) -> int:
        return sum(int(p.size) for p in jax.tree_util.tree_leaves(self.params))


def _as_slices(x: jax.Array, axis: int) -> jax.Array:
    return jnp.moveaxis(x, axis, 0)


def _per_group_scale(r: jax.Array, ids: jax.Array, n_groups: int) -> jax.Array:
    """max |R| within each group (normalizes the learning target)."""
    absr = jnp.abs(r).ravel()
    s = jnp.zeros(n_groups).at[ids.ravel()].max(absr)
    return jnp.maximum(s, 1e-12)


def _group_inputs(xb, idsb, edges, n_groups):
    """Normalized, masked inputs for every group: [G, B, H, W] (+ masks)."""
    lo, scale = grouping.group_normalizers(edges)
    masks = jax.nn.one_hot(idsb, n_groups, axis=0, dtype=xb.dtype)  # [G,B,H,W]
    xn = (xb[None] - lo[:, None, None, None]) / scale[:, None, None, None]
    return xn * masks, masks


def _loss_one_group(params, state, xg, maskg, target):
    pred, new_state = enhancer.apply(params, state, xg, train=True, mask=maskg)
    se = (pred - target) ** 2 * maskg
    loss = se.sum() / jnp.maximum(maskg.sum(), 1.0)
    return loss, new_state


@partial(jax.jit, static_argnames=("n_groups", "residual_learning", "adam_cfg"))
def train_step(
    params,
    bn_state,
    opt_state,
    xb,
    rb,
    idsb,
    edges,
    rscale,
    lr,
    *,
    n_groups: int,
    residual_learning: bool,
    adam_cfg: AdamWConfig,
):
    """One Adam step for all G models at once.  Returns per-group losses."""
    xn, masks = _group_inputs(xb, idsb, edges, n_groups)
    if residual_learning:
        safe = jnp.where(rscale > 0, rscale, 1.0)
        target = rb[None] / safe[:, None, None, None] * masks
    else:
        # Regular baseline: predict the normalized original directly.
        lo, scale = grouping.group_normalizers(edges)
        orig = xb[None] + rb[None]  # X = X' + R
        target = (orig - lo[:, None, None, None]) / scale[:, None, None, None] * masks

    active = (rscale > 0.0).astype(jnp.float32)

    def lossfn(p):
        losses, new_states = jax.vmap(_loss_one_group)(p, bn_state, xn, masks, target)
        return (losses * active).sum(), (losses * active, new_states)

    grads, (losses, new_bn) = jax.grad(lossfn, has_aux=True)(params)
    new_params, new_opt = adamw.update(params, opt_state, grads, lr, adam_cfg)
    return new_params, new_bn, new_opt, losses


def train_enhancers(
    xprime: jax.Array,
    residual: jax.Array,
    cfg: GWLZTrainConfig = GWLZTrainConfig(),
    *,
    callback=None,
) -> tuple[GWLZModel, dict]:
    """Fit G enhancers to map decompressed slices -> residual slices.

    Returns (model, history) where history["loss"][epoch, group] traces the
    per-group training loss (Fig. 5 reproduction).
    """
    G = cfg.n_groups
    xs = _as_slices(jnp.asarray(xprime, jnp.float32), cfg.slice_axis)
    rs = _as_slices(jnp.asarray(residual, jnp.float32), cfg.slice_axis)
    n_slices = xs.shape[0]

    edges = grouping.compute_edges(xs, G, cfg.strategy)
    ids = grouping.assign_groups(xs, edges)
    rscale = _per_group_scale(rs, ids, G)
    counts = jnp.zeros(G).at[ids.ravel()].add(1.0)
    rscale = jnp.where(counts >= cfg.min_group_pixels, rscale, 0.0)

    key = jax.random.PRNGKey(cfg.seed)
    pkeys = jax.random.split(key, G)
    params = jax.vmap(lambda k: enhancer.init_params(k, cfg.channels))(pkeys)
    bn_state = jax.vmap(lambda _: enhancer.init_state(cfg.channels))(jnp.arange(G))
    adam_cfg = AdamWConfig()
    opt_state = adamw.init(params, adam_cfg)

    bs = min(cfg.batch_size, n_slices)
    steps_per_epoch = max(n_slices // bs, 1)
    sched = step_decay(cfg.lr, cfg.lr_decay_factor, cfg.lr_decay_every_epochs * steps_per_epoch)

    rng = np.random.default_rng(cfg.seed)
    history = {"loss": np.zeros((cfg.epochs, G), np.float64), "lr": np.zeros(cfg.epochs)}
    gstep = 0
    for epoch in range(cfg.epochs):
        order = rng.permutation(n_slices)
        ep_loss = np.zeros(G, np.float64)
        for s in range(steps_per_epoch):
            idx = order[s * bs : (s + 1) * bs]
            xb, rb, idsb = xs[idx], rs[idx], ids[idx]
            lr = sched(gstep)
            params, bn_state, opt_state, losses = train_step(
                params, bn_state, opt_state, xb, rb, idsb, edges, rscale, lr,
                n_groups=G, residual_learning=cfg.residual_learning, adam_cfg=adam_cfg,
            )
            ep_loss += np.asarray(losses, np.float64)
            gstep += 1
        history["loss"][epoch] = ep_loss / steps_per_epoch
        history["lr"][epoch] = float(sched(gstep - 1))
        if callback is not None:
            callback(epoch, history["loss"][epoch])
    # Replace running BN stats with exact full-volume statistics (the data we
    # will enhance is exactly the data we trained on — see _bn_calibrate).
    bn_state = _bn_calibrate(params, xs, ids, edges, n_groups=G)
    if cfg.gate_groups and cfg.residual_learning:
        gate = _gate_groups(params, bn_state, xs, rs, ids, edges, rscale, n_groups=G)
        rscale = rscale * gate
        history["gate"] = np.asarray(gate)
    model = GWLZModel(params=params, bn_state=bn_state, edges=edges, rscale=rscale, cfg=cfg)
    return model, history


def tiles_as_slices(tiles: jax.Array) -> jax.Array:
    """[Nt, T0, ...] tile batch -> one slice stack along every tile's axis 0.

    Folds the tile-batch axis into the slice axis, so a whole tile grid
    trains as a single slice batch."""
    return tiles.reshape((-1,) + tuple(tiles.shape[2:]))


def train_enhancers_tiled(
    recon_tiles: jax.Array,
    residual_tiles: jax.Array,
    cfg: GWLZTrainConfig = GWLZTrainConfig(),
    *,
    callback=None,
) -> tuple[GWLZModel, dict]:
    """Group-wise training routed through the tile grid.

    Every tile contributes its axis-0 slices to ONE batched
    :func:`train_enhancers` call — per-tile group masks are computed inside
    the shared step over the stacked slices, so the tile grid trains exactly
    like a (taller) volume.  Requires 3D tiles ([Nt, T0, T1, T2]); the
    enhancers are 2D CNNs over each tile's (T1, T2) slices."""
    if recon_tiles.ndim != 4 or residual_tiles.shape != recon_tiles.shape:
        raise ValueError(f"expected matching [Nt, T, T, T] tile stacks, got "
                         f"{recon_tiles.shape} / {residual_tiles.shape}")
    cfg = replace(cfg, slice_axis=0)  # tile slices are already stacked on axis 0
    return train_enhancers(
        tiles_as_slices(recon_tiles), tiles_as_slices(residual_tiles), cfg,
        callback=callback)


class TileReservoir:
    """Bounded uniform sample of (recon, residual) tile pairs from a stream.

    Algorithm R over the tile stream: the streaming compressor
    (repro.exec.executor) cannot hold every tile's reconstruction for
    enhancer training the way the eager path does, so it offers each
    batch's tiles here and trains on the reservoir — an unbiased sample of
    the volume whatever its size, in ``capacity * tile_bytes * 2`` memory.
    """

    def __init__(self, capacity: int, seed: int = 0):
        if capacity < 1:
            raise ValueError("reservoir capacity must be >= 1")
        self.capacity = int(capacity)
        self.n_seen = 0
        self._rng = np.random.default_rng(seed)
        self._recon: list[np.ndarray] = []
        self._resid: list[np.ndarray] = []

    def __len__(self) -> int:
        return len(self._recon)

    @property
    def nbytes(self) -> int:
        return sum(a.nbytes for a in self._recon) + sum(a.nbytes for a in self._resid)

    def offer(self, recon_tiles: np.ndarray, resid_tiles: np.ndarray) -> int:
        """Offer one tile batch ([B, *tile] pairs); returns bytes GROWN (for
        the executor's memory accounting — replacements are size-neutral)."""
        if recon_tiles.shape != resid_tiles.shape:
            raise ValueError(
                f"recon/residual shape mismatch: {recon_tiles.shape} vs "
                f"{resid_tiles.shape}")
        grown = 0
        for rec, res in zip(recon_tiles, resid_tiles):
            self.n_seen += 1
            if len(self._recon) < self.capacity:
                self._recon.append(np.array(rec, np.float32))
                self._resid.append(np.array(res, np.float32))
                grown += self._recon[-1].nbytes + self._resid[-1].nbytes
            else:
                j = int(self._rng.integers(0, self.n_seen))
                if j < self.capacity:
                    self._recon[j] = np.array(rec, np.float32)
                    self._resid[j] = np.array(res, np.float32)
        return grown

    def stacks(self) -> tuple[np.ndarray, np.ndarray]:
        if not self._recon:
            raise ValueError("empty reservoir: offer at least one tile batch")
        return np.stack(self._recon), np.stack(self._resid)


def train_enhancers_streamed(
    reservoir: TileReservoir,
    cfg: GWLZTrainConfig = GWLZTrainConfig(),
    *,
    callback=None,
) -> tuple[GWLZModel, dict]:
    """Group-wise training for the streaming path: fit on the reservoir's
    sampled tile pairs exactly like :func:`train_enhancers_tiled` fits on
    the full grid.  The model is volume-agnostic (it maps decoded values to
    residuals through the group edges), so a uniform sample trains the same
    estimator the full stack would — just with sampling noise bounded by
    the reservoir size."""
    recon, resid = reservoir.stacks()
    return train_enhancers_tiled(jnp.asarray(recon), jnp.asarray(resid), cfg,
                                 callback=callback)


@partial(jax.jit, static_argnames=("n_groups",))
def _gate_groups(params, bn_state, xs, rs, ids, edges, rscale, *, n_groups):
    """Per-group acceptance test on the training volume: keep a group's
    enhancer only if it reduces that group's residual MSE."""
    xn, masks = _group_inputs(xs, ids, edges, n_groups)

    def one(p, st, xg):
        pred, _ = enhancer.apply(p, st, xg, train=False)
        return pred

    preds = jax.vmap(one)(params, bn_state, xn) * rscale[:, None, None, None]
    err_with = (((rs[None] - preds) * masks) ** 2).sum(axis=(1, 2, 3))
    err_without = ((rs[None] * masks) ** 2).sum(axis=(1, 2, 3))
    return (err_with < err_without).astype(jnp.float32)


@partial(jax.jit, static_argnames=("n_groups",))
def _bn_calibrate(params, xs, ids, edges, *, n_groups):
    """Exact masked BN statistics of the *final* model over the full volume.

    Per-batch BN statistics drift from the running average enough to cost
    ~1 dB at inference; since compression trains on exactly the data it will
    enhance, we can use the exact statistics (one extra forward pass)."""
    xn, masks = _group_inputs(xs, ids, edges, n_groups)

    def stats_one(p, xg, maskg):
        h = enhancer._conv(xg[..., None], p["w1"], p["b1"])
        m = maskg[..., None]
        cnt = jnp.maximum(m.sum(axis=(0, 1, 2)), 1.0)
        mean = (h * m).sum(axis=(0, 1, 2)) / cnt
        var = ((h - mean) ** 2 * m).sum(axis=(0, 1, 2)) / cnt
        return {"mean": mean, "var": var}

    return jax.vmap(stats_one)(params, xn, masks)


@partial(jax.jit, static_argnames=("n_groups", "residual_learning"))
def _enhance_slices(params, bn_state, xs, edges, rscale, *, n_groups, residual_learning=True):
    ids = grouping.assign_groups(xs, edges)
    xn, masks = _group_inputs(xs, ids, edges, n_groups)

    def one(p, st, xg):
        pred, _ = enhancer.apply(p, st, xg, train=False)
        return pred

    preds = jax.vmap(one)(params, bn_state, xn)  # [G,B,H,W]
    if residual_learning:
        rhat = (preds * rscale[:, None, None, None] * masks).sum(axis=0)
        return xs + rhat
    lo, scale = grouping.group_normalizers(edges)
    xhat = (preds * scale[:, None, None, None] + lo[:, None, None, None]) * masks
    return xhat.sum(axis=0)


def _enhance_one_tile(params, bn_state, t, edges, rscale, clamp_eb, *,
                      n_groups, residual_learning, slice_axis, batch, use_clamp):
    """One tile's enhancement as a pure traced program — the same op sequence
    :func:`enhance` runs (moveaxis, slice-batched ``_enhance_slices``,
    optional clamp, concat, moveaxis back), so the two paths agree bit-for-
    bit on every backend."""
    xs = jnp.moveaxis(t, slice_axis, 0)
    outs = []
    for i in range(0, xs.shape[0], batch):
        xb = xs[i : i + batch]
        out = _enhance_slices(params, bn_state, xb, edges, rscale,
                              n_groups=n_groups, residual_learning=residual_learning)
        if use_clamp:
            out = jnp.clip(out, xb - clamp_eb, xb + clamp_eb)
        outs.append(out)
    return jnp.moveaxis(jnp.concatenate(outs, axis=0), 0, slice_axis)


@partial(jax.jit, static_argnames=("n_groups", "residual_learning", "slice_axis",
                                   "batch", "use_clamp"))
def _enhance_tiles_mapped(params, bn_state, tiles, edges, rscale, clamp_eb, *,
                          n_groups, residual_learning, slice_axis, batch, use_clamp):
    return jax.lax.map(
        lambda t: _enhance_one_tile(
            params, bn_state, t, edges, rscale, clamp_eb, n_groups=n_groups,
            residual_learning=residual_learning, slice_axis=slice_axis,
            batch=batch, use_clamp=use_clamp),
        tiles)


def enhance_tiles(
    tiles: jax.Array,
    model: GWLZModel,
    *,
    clamp_eb: float | None = None,
    batch: int = 64,
) -> jax.Array:
    """Batched per-tile enhancement: ``[K, *tile] -> [K, *tile]``.

    One ``lax.map`` over the tile batch compiles a single fixed-tile-shape
    per-tile program and runs it K times inside one dispatch — the per-tile
    program is independent of K, so region decode (small K) and full decode
    (K = n_tiles) enhance every tile bit-identically, which is the contract
    ``repro.sz.tiled`` requires of any ``tile_transform``.  Replaces the
    per-tile Python loop (~n_tiles jit dispatches on the decode hot path;
    speedup measured by ``throughput/tiled/enhance_batched``)."""
    cfg = model.cfg
    clamp = jnp.float32(0.0 if clamp_eb is None else clamp_eb)
    return _enhance_tiles_mapped(
        model.params, model.bn_state, tiles, model.edges, model.rscale, clamp,
        n_groups=cfg.n_groups, residual_learning=cfg.residual_learning,
        slice_axis=cfg.slice_axis, batch=batch, use_clamp=clamp_eb is not None)


def enhance_tiles_looped(
    tiles: jax.Array,
    model: GWLZModel,
    *,
    clamp_eb: float | None = None,
) -> jax.Array:
    """Per-tile Python-loop reference (the pre-batching hot path), kept as
    the parity baseline for tests and the enhancer-speedup benchmark."""
    return jnp.stack([enhance(t, model, clamp_eb=clamp_eb) for t in tiles])


def enhance(
    xprime: jax.Array,
    model: GWLZModel,
    *,
    clamp_eb: float | None = None,
    batch: int = 64,
) -> jax.Array:
    """Reconstruction module: X_hat = X' + R_hat, merged across groups.

    ``clamp_eb``: beyond-paper bounded-enhancement mode (DESIGN.md §8.1) —
    clips the enhanced value into [X'-e, X'+e].  Since the true value also
    lies in that interval, the worst-case error vs the original is 2e
    (the unclamped paper-faithful mode has no worst-case bound at all).
    """
    cfg = model.cfg
    xs = _as_slices(jnp.asarray(xprime, jnp.float32), cfg.slice_axis)
    outs = []
    for i in range(0, xs.shape[0], batch):
        xb = xs[i : i + batch]
        out = _enhance_slices(
            model.params, model.bn_state, xb, model.edges, model.rscale,
            n_groups=cfg.n_groups, residual_learning=cfg.residual_learning,
        )
        if clamp_eb is not None:
            out = jnp.clip(out, xb - clamp_eb, xb + clamp_eb)
        outs.append(out)
    enhanced = jnp.concatenate(outs, axis=0)
    return jnp.moveaxis(enhanced, 0, cfg.slice_axis)
