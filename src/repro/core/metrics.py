"""Compression-quality metrics (paper §2.1)."""
from __future__ import annotations

import jax.numpy as jnp


def mse(x, y):
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    return jnp.mean((x - y) ** 2)


def vrange(x):
    x = jnp.asarray(x)
    return jnp.max(x) - jnp.min(x)


def psnr(x, y):
    """PSNR per Eq. (1): 20 log10 vrange(x) - 10 log10 mse(x, y)."""
    return 20.0 * jnp.log10(vrange(x)) - 10.0 * jnp.log10(jnp.maximum(mse(x, y), 1e-30))


def nrmse(x, y):
    return jnp.sqrt(mse(x, y)) / vrange(x)


def max_abs_err(x, y):
    return jnp.max(jnp.abs(jnp.asarray(x) - jnp.asarray(y)))
