"""The GWLZ learnable enhancer (paper Fig. 3).

Encoder-decoder CNN: Conv3x3(1->C) -> BatchNorm -> ReLU -> Conv3x3(C->1),
C = 9 channels, ~190 trainable parameters + 2*C running BN stats.  Slices of
the volume are treated as single-channel images; the model predicts the
*normalized residual map* (DnCNN-style residual learning, §3.2).

Parameters are a flat dict pytree so a batch of G enhancers is just the same
pytree with a leading G axis (vmap over models — DESIGN.md §3.3).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

DEFAULT_CHANNELS = 9
_BN_EPS = 1e-5
_BN_MOMENTUM = 0.1


def init_params(key: jax.Array, channels: int = DEFAULT_CHANNELS, ksize: int = 3) -> dict:
    k1, k2 = jax.random.split(key)
    fan1 = ksize * ksize * 1
    fan2 = ksize * ksize * channels
    return {
        "w1": jax.random.normal(k1, (ksize, ksize, 1, channels)) * (2.0 / fan1) ** 0.5,
        "b1": jnp.zeros((channels,)),
        "gamma": jnp.ones((channels,)),
        "beta": jnp.zeros((channels,)),
        "w2": jax.random.normal(k2, (ksize, ksize, channels, 1)) * (2.0 / fan2) ** 0.5,
        "b2": jnp.zeros((1,)),
    }


def init_state(channels: int = DEFAULT_CHANNELS) -> dict:
    """Non-trainable BN running statistics (stored in the artifact)."""
    return {"mean": jnp.zeros((channels,)), "var": jnp.ones((channels,))}


def param_count(params: dict) -> int:
    return sum(int(p.size) for p in jax.tree_util.tree_leaves(params))


def _shifts3x3(x: jax.Array) -> jax.Array:
    """[..., H, W, C] -> [..., H, W, 9, C]: the 3x3 neighborhood per pixel
    (zero-padded borders, identical to SAME conv)."""
    H, W = x.shape[-3], x.shape[-2]
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 3) + [(1, 1), (1, 1), (0, 0)])
    taps = [
        jax.lax.slice_in_dim(jax.lax.slice_in_dim(xp, dy, dy + H, axis=x.ndim - 3), dx, dx + W, axis=x.ndim - 2)
        for dy in range(3)
        for dx in range(3)
    ]
    return jnp.stack(taps, axis=-2)


def _conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """3x3 SAME conv expressed as shift+matmul.

    XLA CPU's conv *transpose* (the backward pass) is ~12x slower than the
    equivalent dot at these tiny channel counts, so the matmul form makes
    group-wise training tractable on the host; on TPU the fused Pallas kernel
    (repro.kernels.enhancer_fused) replaces the inference path anyway.
    x: [B, H, W, Cin]; w: [3, 3, Cin, Cout].
    """
    p = _shifts3x3(x)  # [B,H,W,9,Cin]
    kh, kw, cin, cout = w.shape
    y = jnp.einsum("bhwkc,kco->bhwo", p, w.reshape(9, cin, cout))
    return y + b


def apply(
    params: dict,
    state: dict,
    x: jax.Array,
    *,
    train: bool,
    mask: jax.Array | None = None,
) -> tuple[jax.Array, dict]:
    """Forward pass.

    ``x``: [B, H, W] normalized single-channel slices (placeholder zeros
    outside the group).  Returns ([B, H, W] predicted normalized residual,
    new BN state).  In train mode BN uses batch statistics over in-group
    pixels only (placeholders would otherwise poison the statistics).
    """
    h = _conv(x[..., None], params["w1"], params["b1"])
    if train:
        if mask is not None:
            m = mask[..., None].astype(h.dtype)
            cnt = jnp.maximum(m.sum(axis=(0, 1, 2)), 1.0)
            mean = (h * m).sum(axis=(0, 1, 2)) / cnt
            var = ((h - mean) ** 2 * m).sum(axis=(0, 1, 2)) / cnt
        else:
            mean = h.mean(axis=(0, 1, 2))
            var = h.var(axis=(0, 1, 2))
        new_state = {
            "mean": (1 - _BN_MOMENTUM) * state["mean"] + _BN_MOMENTUM * mean,
            "var": (1 - _BN_MOMENTUM) * state["var"] + _BN_MOMENTUM * var,
        }
    else:
        mean, var = state["mean"], state["var"]
        new_state = state
    h = (h - mean) * lax.rsqrt(var + _BN_EPS) * params["gamma"] + params["beta"]
    h = jax.nn.relu(h)
    out = _conv(h, params["w2"], params["b2"])
    return out[..., 0], new_state


# Fused Pallas forward (inference hot path) is selected via use_pallas=True in
# the pipeline; see repro.kernels.enhancer_fused / repro.kernels.ops.
apply_inference = partial(apply, train=False)
