"""GWLZ core: the paper's contribution as a composable JAX module."""
from repro.core import enhancer, grouping, metrics
from repro.core.pipeline import GWLZ, GWLZStats, quick_compress, serialize_model, deserialize_model
from repro.core.trainer import GWLZModel, GWLZTrainConfig, enhance, train_enhancers

__all__ = [
    "enhancer",
    "grouping",
    "metrics",
    "GWLZ",
    "GWLZStats",
    "quick_compress",
    "serialize_model",
    "deserialize_model",
    "GWLZModel",
    "GWLZTrainConfig",
    "enhance",
    "train_enhancers",
]
