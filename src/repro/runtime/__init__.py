from repro.runtime.elastic import plan_remesh, reshard_restore
from repro.runtime.fault import (
    FailureInjector,
    HeartbeatMonitor,
    ResilientLoop,
    RetryPolicy,
)

__all__ = [
    "plan_remesh",
    "reshard_restore",
    "FailureInjector",
    "HeartbeatMonitor",
    "ResilientLoop",
    "RetryPolicy",
]
