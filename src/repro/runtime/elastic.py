"""Elastic scaling: re-shard a checkpoint onto a different mesh.

Checkpoints store full logical arrays (mesh-agnostic), so scaling from N to M
pods is: build the new mesh, recompute PartitionSpecs against it (the
divisibility-aware rules drop axes that no longer fit), and device_put each
leaf with its new NamedSharding.  The same path serves shrink (node loss) and
grow (capacity arrival).
"""
from __future__ import annotations

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.launch import sharding as SH


def reshard_restore(
    manager: CheckpointManager,
    target_tree,
    new_mesh,
    *,
    step: int | None = None,
    opts: SH.ShardingOptions | None = None,
):
    """Restore ``target_tree``-shaped state onto ``new_mesh``."""
    opts = opts or SH.ShardingOptions()
    pspecs = SH.param_pspecs(target_tree, opts, new_mesh)
    shardings = SH.named(new_mesh, pspecs)
    return manager.restore(target_tree, step, shardings=shardings)


def plan_remesh(old_mesh_shape: tuple, n_devices: int) -> tuple:
    """Pick the closest (data, model) factorization for the surviving devices,
    preserving the model-parallel degree when possible (weights keep their
    layout; only DP shrinks)."""
    model = old_mesh_shape[-1]
    while n_devices % model != 0 and model > 1:
        model //= 2
    return (n_devices // model, model)
