"""Fault tolerance: retry policies, heartbeats, stragglers, restart.

On a real cluster the HeartbeatMonitor feeds the pod manager; here the same
interface is exercised by tests with injected delays/failures.  The
ResilientLoop is the production training driver's core: deterministic step
boundaries, periodic async checkpoints, automatic restore-and-replay after a
failure, straggler-triggered rebalancing hooks.  :class:`RetryPolicy` is the
shared transient-failure contract: the streaming compression executor runs
its device and host stages under one (docs/ROBUSTNESS.md), and
:class:`FailureInjector` drives deterministic fault schedules through the
same code paths in tests.
"""
from __future__ import annotations

import random
import time
from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from repro.checkpoint.manager import CheckpointManager


@dataclass(frozen=True)
class RetryPolicy:
    """How many times to retry a transient failure, and how long to wait.

    ``run(fn)`` calls ``fn`` up to ``max_attempts`` times, sleeping
    ``backoff * 2**attempt`` seconds between attempts (exponential, plus a
    uniform ``jitter`` fraction so colliding workers decorrelate).  Only
    exceptions in ``retry_on`` are retried — anything else (a programming
    error, a corrupt-input ValueError) propagates on the first attempt.
    The caller observes every retry through ``on_retry(exc, attempt)``;
    ``sleep`` is injectable so tests run without wall-clock delays."""

    max_attempts: int = 3
    backoff: float = 0.05
    jitter: float = 0.0
    retry_on: tuple[type[BaseException], ...] = (RuntimeError, OSError)

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError(f"max_attempts must be >= 1, got {self.max_attempts}")

    def delay(self, attempt: int) -> float:
        """Seconds to wait after failed attempt ``attempt`` (0-based)."""
        d = self.backoff * (2.0 ** attempt)
        if self.jitter:
            d *= 1.0 + random.uniform(0.0, self.jitter)
        return d

    def run(self, fn: Callable, *, on_retry: Callable | None = None,
            sleep: Callable[[float], None] = time.sleep):
        """``fn()`` with retries; returns its result or raises the last error."""
        for attempt in range(self.max_attempts):
            try:
                return fn()
            except self.retry_on as e:
                if attempt + 1 >= self.max_attempts:
                    raise
                if on_retry is not None:
                    on_retry(e, attempt)
                sleep(self.delay(attempt))
        raise AssertionError("unreachable")  # pragma: no cover


@dataclass
class HeartbeatMonitor:
    """Per-worker step heartbeats with MAD-based straggler detection."""

    n_workers: int
    straggler_factor: float = 3.0
    window: int = 16
    _times: dict[int, list[float]] = field(default_factory=dict)

    def beat(self, worker: int, step_duration: float) -> None:
        self._times.setdefault(worker, []).append(step_duration)
        if len(self._times[worker]) > self.window:
            self._times[worker] = self._times[worker][-self.window:]

    def stragglers(self) -> list[int]:
        if len(self._times) < self.n_workers:
            return []  # missing heartbeats handled by dead()
        med = np.median([np.median(v) for v in self._times.values()])
        bad = []
        for w, v in self._times.items():
            if np.median(v[-4:]) > self.straggler_factor * max(med, 1e-9):
                bad.append(w)
        return bad

    def dead(self, last_beat: dict[int, float], now: float, timeout: float) -> list[int]:
        return [w for w in range(self.n_workers) if now - last_beat.get(w, 0.0) > timeout]


class FailureInjector:
    """Deterministic fault injection for tests: raise at given steps.

    ``fail_at`` names the steps (batch ids, lane ids, loop steps — whatever
    the instrumented code passes) that fail; each fires ``attempts`` times
    before succeeding, so a schedule can model a transient blip
    (``attempts=1``, survived by one retry) or a hard fault
    (``attempts >= RetryPolicy.max_attempts``, exhausting the policy).
    ``exc`` picks the raised type — e.g. ``OSError`` for an append-path
    fault — either an exception class or a ``step -> exception`` factory."""

    def __init__(self, fail_at: set[int], *, exc=RuntimeError, attempts: int = 1):
        self.fail_at = set(fail_at)
        self.exc = exc
        self.attempts = int(attempts)
        self.failed: dict[int, int] = {}  # step -> times fired

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at and self.failed.get(step, 0) < self.attempts:
            self.failed[step] = self.failed.get(step, 0) + 1
            if isinstance(self.exc, type) and issubclass(self.exc, BaseException):
                raise self.exc(f"injected failure at step {step} "
                               f"(attempt {self.failed[step]})")
            raise self.exc(step)


@dataclass
class ResilientLoop:
    """Checkpoint/restart training loop.

    ``step_fn(state, batch) -> (state, metrics)`` must be deterministic given
    (state, batch); batches are addressed by step index so replay after
    restore is exact.
    """

    step_fn: Callable
    batch_fn: Callable  # step -> batch
    manager: CheckpointManager
    ckpt_every: int = 50
    max_restarts: int = 8

    def run(self, state, n_steps: int, *, injector: FailureInjector | None = None,
            monitor: HeartbeatMonitor | None = None):
        metrics_log = []
        restarts = 0
        step = 0
        # resume if checkpoints exist
        latest = self.manager.latest_step()
        if latest is not None:
            state = self.manager.restore(state, latest)
            step = latest
        while step < n_steps:
            try:
                t0 = time.time()
                if injector is not None:
                    injector.maybe_fail(step)
                batch = self.batch_fn(step)
                state, m = self.step_fn(state, batch)
                if monitor is not None:
                    monitor.beat(0, time.time() - t0)
                metrics_log.append(m)
                step += 1
                if step % self.ckpt_every == 0:
                    self.manager.save(step, state)
            except RuntimeError as e:
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                latest = self.manager.latest_step()
                if latest is None:
                    step = 0  # cold restart
                    continue
                self.manager.wait()
                state = self.manager.restore(state, latest)
                step = latest
        self.manager.wait()
        return state, metrics_log, restarts
