"""Fault tolerance: heartbeats, straggler detection, checkpointed restart.

On a real cluster the HeartbeatMonitor feeds the pod manager; here the same
interface is exercised by tests with injected delays/failures.  The
ResilientLoop is the production training driver's core: deterministic step
boundaries, periodic async checkpoints, automatic restore-and-replay after a
failure, straggler-triggered rebalancing hooks.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from repro.checkpoint.manager import CheckpointManager


@dataclass
class HeartbeatMonitor:
    """Per-worker step heartbeats with MAD-based straggler detection."""

    n_workers: int
    straggler_factor: float = 3.0
    window: int = 16
    _times: dict[int, list[float]] = field(default_factory=dict)

    def beat(self, worker: int, step_duration: float) -> None:
        self._times.setdefault(worker, []).append(step_duration)
        if len(self._times[worker]) > self.window:
            self._times[worker] = self._times[worker][-self.window:]

    def stragglers(self) -> list[int]:
        if len(self._times) < self.n_workers:
            return []  # missing heartbeats handled by dead()
        med = np.median([np.median(v) for v in self._times.values()])
        bad = []
        for w, v in self._times.items():
            if np.median(v[-4:]) > self.straggler_factor * max(med, 1e-9):
                bad.append(w)
        return bad

    def dead(self, last_beat: dict[int, float], now: float, timeout: float) -> list[int]:
        return [w for w in range(self.n_workers) if now - last_beat.get(w, 0.0) > timeout]


class FailureInjector:
    """Deterministic fault injection for tests: raise at given steps."""

    def __init__(self, fail_at: set[int]):
        self.fail_at = set(fail_at)
        self.failed: set[int] = set()

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at and step not in self.failed:
            self.failed.add(step)
            raise RuntimeError(f"injected failure at step {step}")


@dataclass
class ResilientLoop:
    """Checkpoint/restart training loop.

    ``step_fn(state, batch) -> (state, metrics)`` must be deterministic given
    (state, batch); batches are addressed by step index so replay after
    restore is exact.
    """

    step_fn: Callable
    batch_fn: Callable  # step -> batch
    manager: CheckpointManager
    ckpt_every: int = 50
    max_restarts: int = 8

    def run(self, state, n_steps: int, *, injector: FailureInjector | None = None,
            monitor: HeartbeatMonitor | None = None):
        metrics_log = []
        restarts = 0
        step = 0
        # resume if checkpoints exist
        latest = self.manager.latest_step()
        if latest is not None:
            state = self.manager.restore(state, latest)
            step = latest
        while step < n_steps:
            try:
                t0 = time.time()
                if injector is not None:
                    injector.maybe_fail(step)
                batch = self.batch_fn(step)
                state, m = self.step_fn(state, batch)
                if monitor is not None:
                    monitor.beat(0, time.time() - t0)
                metrics_log.append(m)
                step += 1
                if step % self.ckpt_every == 0:
                    self.manager.save(step, state)
            except RuntimeError as e:
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                latest = self.manager.latest_step()
                if latest is None:
                    step = 0  # cold restart
                    continue
                self.manager.wait()
                state = self.manager.restore(state, latest)
                step = latest
        self.manager.wait()
        return state, metrics_log, restarts
