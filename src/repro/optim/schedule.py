"""Learning-rate schedules."""
from __future__ import annotations

import jax.numpy as jnp


def step_decay(init_lr: float, factor: float, every_steps: int):
    """Paper §4.1: lr starts at 1e-3 with a decay every 30 epochs.  The decay
    magnitude is ambiguous in the paper ("a decay of 0.005 every 30 epochs");
    we default to factor=0.5 and expose the knob (EXPERIMENTS.md §Repro-notes)."""

    def fn(step):
        return init_lr * factor ** (jnp.asarray(step) // every_steps)

    return fn


def warmup_cosine(init_lr: float, warmup_steps: int, total_steps: int, min_ratio: float = 0.1):
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = (step + 1.0) / jnp.maximum(warmup_steps, 1)  # lr > 0 from step 0
        prog = jnp.clip((step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1), 0, 1)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return init_lr * jnp.where(step < warmup_steps, warm, cos)

    return fn


def constant(lr: float):
    return lambda step: jnp.full((), lr)
