from repro.optim.adamw import AdamWConfig, init, update
from repro.optim.schedule import constant, step_decay, warmup_cosine

__all__ = ["AdamWConfig", "init", "update", "constant", "step_decay", "warmup_cosine"]
