"""Error-bounded gradient compression with error feedback (DESIGN.md §8.3).

The paper's core primitive — error-bounded uniform quantization — applied to
distributed-training gradients: before the cross-pod reduction each shard
quantizes its gradient onto a 2*eb grid (eb relative to the gradient's RMS),
accumulates the quantization error locally (error feedback, so the bias does
not compound), and reduces int8/int16 codes instead of fp32 — a 2-4x cut of
the DP-reduction wire bytes, targeted at the "pod" axis where links are
slowest.

``compressed_psum`` is the shard_map building block; ``EFState``/``apply``
wrap a whole gradient pytree for the GWLZ distributed trainer and the LM
drivers.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class GradCompressConfig:
    rel_eb: float = 1e-2        # eb = rel_eb * rms(grad)
    code_dtype: str = "int8"    # int8 | int16
    enabled: bool = True


def _code_bound(dtype: str) -> int:
    return 127 if dtype == "int8" else 32767


def quantize_leaf(g: jax.Array, err: jax.Array, cfg: GradCompressConfig):
    """Returns (codes, scale, new_err). |g_hat - (g + err)| <= eb pointwise
    unless clipped at the code bound (clipped mass flows into new_err)."""
    g_fb = g + err
    rms = jnp.sqrt(jnp.mean(g_fb.astype(jnp.float32) ** 2)) + 1e-20
    eb = cfg.rel_eb * rms
    scale = 2.0 * eb
    bound = _code_bound(cfg.code_dtype)
    codes = jnp.clip(jnp.rint(g_fb / scale), -bound, bound)
    g_hat = codes * scale
    new_err = (g_fb - g_hat).astype(err.dtype)
    dt = jnp.int8 if cfg.code_dtype == "int8" else jnp.int16
    return codes.astype(dt), scale, new_err


def compressed_psum(g: jax.Array, err: jax.Array, axis_name, cfg: GradCompressConfig):
    """shard_map building block: quantize -> int psum -> dequantize/average.

    Codes are summed in int32 (no overflow below ~2^15 shards at int16).
    The scale is averaged across shards (RMS varies slightly per shard)."""
    codes, scale, new_err = quantize_leaf(g, err, cfg)
    summed = jax.lax.psum(codes.astype(jnp.int32), axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    mean_scale = jax.lax.psum(scale, axis_name) / n
    return (summed.astype(jnp.float32) * mean_scale / n).astype(g.dtype), new_err


def init_ef(params) -> dict:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def apply(grads, ef_state, cfg: GradCompressConfig, axis_name=None):
    """Quantize a whole gradient pytree (with error feedback).  When
    ``axis_name`` is given (inside shard_map) the reduction itself runs on
    int codes; otherwise this quantizes in place (single-shard semantics,
    used by tests and the serial trainer)."""
    if not cfg.enabled:
        return grads, ef_state

    if axis_name is None:
        def one(g, e):
            codes, scale, ne = quantize_leaf(g, e, cfg)
            return (codes.astype(jnp.float32) * scale).astype(g.dtype), ne
    else:
        def one(g, e):
            return compressed_psum(g, e, axis_name, cfg)

    out = jax.tree.map(one, grads, ef_state)
    new_g = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_e = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_g, new_e
