"""AdamW with optional compressed optimizer state (bf16 / int8 moments).

Moment compression is one of the framework's distributed-memory tricks
(DESIGN.md §8.5): int8 moments use per-tensor absmax scaling with stochastic
rounding on the first moment, cutting optimizer HBM by 4x — this is what lets
the 671B training cells fit a single v5e pod (EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    moment_dtype: str = "fp32"  # fp32 | bf16 | int8


def _store(x: jax.Array, dtype: str, key: jax.Array | None = None):
    if dtype == "fp32":
        return x, None
    if dtype == "bf16":
        return x.astype(jnp.bfloat16), None
    if dtype == "int8":
        scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-30) / 127.0
        y = x / scale
        if key is not None:  # stochastic rounding (first moment)
            y = jnp.floor(y + jax.random.uniform(key, y.shape, y.dtype))
        else:
            y = jnp.rint(y)
        return jnp.clip(y, -127, 127).astype(jnp.int8), scale
    raise ValueError(dtype)


def _load(x: jax.Array, scale, dtype: str) -> jax.Array:
    if dtype == "fp32":
        return x
    if dtype == "bf16":
        return x.astype(jnp.float32)
    return x.astype(jnp.float32) * scale


def init(params: Any, cfg: AdamWConfig = AdamWConfig()) -> dict:
    def zeros_like_stored(p):
        if cfg.moment_dtype == "int8":
            return {"q": jnp.zeros(p.shape, jnp.int8), "s": jnp.zeros(())}
        dt = jnp.bfloat16 if cfg.moment_dtype == "bf16" else jnp.float32
        return jnp.zeros(p.shape, dt)

    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros_like_stored, params),
        "v": jax.tree.map(zeros_like_stored, params),
    }


def _unpack(x, dtype):
    if dtype == "int8":
        return _load(x["q"], x["s"], dtype)
    return _load(x, None, dtype)


def _pack(x, dtype, key=None):
    stored, scale = _store(x, dtype, key)
    if dtype == "int8":
        return {"q": stored, "s": scale}
    return stored


@partial(jax.jit, static_argnames=("cfg",), donate_argnums=(0, 1))
def update(
    params: Any,
    state: dict,
    grads: Any,
    lr: jax.Array | float,
    cfg: AdamWConfig = AdamWConfig(),
    rng: jax.Array | None = None,
) -> tuple[Any, dict]:
    step = state["step"] + 1
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    leaves, treedef = jax.tree_util.tree_flatten(params)
    gleaves = treedef.flatten_up_to(grads)
    mleaves = treedef.flatten_up_to(state["m"])
    vleaves = treedef.flatten_up_to(state["v"])
    if rng is None:
        rng = jax.random.PRNGKey(0)
    keys = jax.random.split(rng, len(leaves))

    new_p, new_m, new_v = [], [], []
    for p, g, m_st, v_st, k in zip(leaves, gleaves, mleaves, vleaves, keys):
        g = g.astype(jnp.float32)
        m = b1 * _unpack(m_st, cfg.moment_dtype) + (1 - b1) * g
        v = b2 * _unpack(v_st, cfg.moment_dtype) + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        upd = mh / (jnp.sqrt(vh) + cfg.eps)
        if cfg.weight_decay:
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        new_p.append((p.astype(jnp.float32) - lr * upd).astype(p.dtype))
        new_m.append(_pack(m, cfg.moment_dtype, k if cfg.moment_dtype == "int8" else None))
        new_v.append(_pack(v, cfg.moment_dtype))

    return (
        jax.tree_util.tree_unflatten(treedef, new_p),
        {
            "step": step,
            "m": jax.tree_util.tree_unflatten(treedef, new_m),
            "v": jax.tree_util.tree_unflatten(treedef, new_v),
        },
    )
