"""Synthetic LM token pipeline: deterministic, seeded, host-prefetched.

Batches are addressed by step index (``batch_at``) so the ResilientLoop can
replay exactly after a restart — the property the fault-tolerance tests
assert.  A background prefetch thread keeps the host ahead of the device.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class TokenPipelineConfig:
    vocab: int
    batch: int
    seq: int
    seed: int = 0
    zipf_a: float = 1.2  # natural-language-ish marginal distribution


class TokenPipeline:
    def __init__(self, cfg: TokenPipelineConfig):
        self.cfg = cfg
        # Zipfian unigram table (clipped to vocab)
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_a)
        self._p = p / p.sum()

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        """Deterministic batch for a global step (replayable)."""
        rng = np.random.default_rng((self.cfg.seed << 20) ^ step)
        toks = rng.choice(self.cfg.vocab, size=(self.cfg.batch, self.cfg.seq + 1), p=self._p)
        toks = toks.astype(np.int32)
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}

    def prefetch(self, start_step: int, depth: int = 2):
        """Generator with a background thread filling a bounded queue."""
        q: queue.Queue = queue.Queue(maxsize=depth)
        stop = threading.Event()

        def worker():
            s = start_step
            while not stop.is_set():
                q.put((s, self.batch_at(s)))
                s += 1

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()


class NyxBlockPipeline:
    """Sharded loader for science volumes: yields (block_coords, block) tiles
    of a large field so multi-host GWLZ jobs stream the volume data-parallel."""

    def __init__(self, volume: np.ndarray, block: tuple[int, int, int]):
        self.volume = volume
        self.block = block
        Z, Y, X = volume.shape
        bz, by, bx = block
        assert Z % bz == 0 and Y % by == 0 and X % bx == 0
        self.grid = (Z // bz, Y // by, X // bx)

    def __iter__(self):
        bz, by, bx = self.block
        for iz in range(self.grid[0]):
            for iy in range(self.grid[1]):
                for ix in range(self.grid[2]):
                    yield (iz, iy, ix), self.volume[
                        iz * bz : (iz + 1) * bz,
                        iy * by : (iy + 1) * by,
                        ix * bx : (ix + 1) * bx,
                    ]

    def shard(self, host_id: int, n_hosts: int):
        """Round-robin block assignment per host (data-parallel compression)."""
        for i, (coords, blk) in enumerate(self):
            if i % n_hosts == host_id:
                yield coords, blk
