"""Synthetic scientific fields with Nyx-like statistics.

The offline container has no SDRBench download, so we synthesize fields that
match the *published statistics* of the Nyx sample (Table 1 of the paper:
Temperature min 2281 / avg 8453 / max 4.78e6; Dark Matter Density min 0 /
avg 1 / max 13779) and its qualitative structure: spatially correlated,
log-skewed, spiky.  Benchmarks validate GWLZ *trends* on these fields;
absolute PSNRs will differ from the paper's (EXPERIMENTS.md §Reproduction).
"""
from __future__ import annotations

import numpy as np

NYX_FIELDS = ("temperature", "dark_matter_density", "baryon_density", "velocity_x")


def gaussian_random_field(shape, power: float = -3.0, seed: int = 0) -> np.ndarray:
    """Isotropic GRF with power-law spectrum k**power (unit variance)."""
    rng = np.random.default_rng(seed)
    white = rng.standard_normal(shape).astype(np.float32)
    f = np.fft.rfftn(white)
    ks = np.meshgrid(
        *[np.fft.fftfreq(n) for n in shape[:-1]],
        np.fft.rfftfreq(shape[-1]),
        indexing="ij",
    )
    k = np.sqrt(sum(x * x for x in ks))
    k[tuple(0 for _ in shape)] = 1.0
    amp = k ** (power / 2.0)
    amp[tuple(0 for _ in shape)] = 0.0
    g = np.fft.irfftn(f * amp, s=shape).astype(np.float32)
    g /= g.std() + 1e-12
    return g


def nyx_like_field(shape=(64, 64, 64), field: str = "temperature", seed: int = 0) -> np.ndarray:
    """A 3D field mimicking the named Nyx field's distribution."""
    g = gaussian_random_field(shape, power=-2.4, seed=seed)
    if field == "temperature":
        # log-normal bulk + rare hot filaments + small-scale turbulence;
        # matches Table 1: min 2281 / max ~4.8e6, mean ~8e3 (heavily skewed).
        fine = gaussian_random_field(shape, power=-1.2, seed=seed + 101)
        lnT = 0.6 * g + 0.18 * fine + 1.4 * np.clip(g - 1.1, 0, None) ** 2
        lo, hi = np.log(2281.0), np.log(4.78e6)
        lnT = lo + (lnT - lnT.min()) * (hi - lo) / (lnT.max() - lnT.min() + 1e-9)
        return np.exp(lnT).astype(np.float32)
    if field == "dark_matter_density":
        fine = gaussian_random_field(shape, power=-1.2, seed=seed + 103)
        x = np.exp(2.2 * g + 0.4 * fine)
        x = x / x.mean()  # avg 1 as in Table 1 (clumped: most mass near 0)
        return x.astype(np.float32)
    if field == "baryon_density":
        x = np.exp(1.4 * g)
        return (x / x.mean()).astype(np.float32)
    if field == "velocity_x":
        return (g * 2.3e7).astype(np.float32)
    raise ValueError(f"unknown field {field!r}")


def field_stats(x: np.ndarray) -> dict:
    return {
        "min": float(x.min()),
        "avg": float(x.mean()),
        "max": float(x.max()),
        "range": float(x.max() - x.min()),
    }
