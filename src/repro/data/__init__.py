from repro.data.synthetic import (
    NYX_FIELDS,
    field_stats,
    gaussian_random_field,
    nyx_like_field,
)

__all__ = ["NYX_FIELDS", "field_stats", "gaussian_random_field", "nyx_like_field"]
