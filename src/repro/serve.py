"""Multi-tenant region-decode daemon over the streaming read path.

Holds many open :class:`repro.api.CompressedVolume` handles behind ONE
shared, budgeted :class:`repro.exec.cache.TileCache` and serves decoded
regions to concurrent readers (docs/SERVING.md):

    GET /v/<name>/region?roi=8:40,:,16:32   -> .npy bytes of full[roi]
    GET /v/<name>/info                      -> volume metadata JSON
    GET /healthz                            -> liveness
    GET /metrics                            -> latency / cache / admission JSON

Three properties make this safe at "hundreds of concurrent readers":

* **shared cache, namespaced keys** — every handle is opened with
  ``api.open(path, tile_cache=pool.cache, cache_ns=name)``, so all
  volumes compete for one byte budget and a hot volume can use all of it;
* **single-flight decode** — overlapping ROIs claim tiles through
  ``TileCache.claim``; concurrent requests needing the same lane agree on
  one decoder and everyone else waits for the hand-off, so each lane
  entropy-decodes once no matter how many clients ask for it;
* **admission control** — request working sets (intersecting lanes ×
  :func:`repro.exec.plan.tile_working_bytes`) are admitted against the
  same byte budget the streaming executor plans with; excess requests
  queue (bounded, then 503) instead of overcommitting memory.

The pure-logic layer (:class:`VolumePool`) is importable without HTTP;
:class:`RegionServer` wraps it in a stdlib ``ThreadingHTTPServer``.  Shell
entry: ``python -m repro.cli serve``.  Load harness with asserted p99 /
hit-rate: ``benchmarks/serve_load.py``.
"""
from __future__ import annotations

import hashlib
import io
import json
import threading
import time
from collections import deque
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from urllib.parse import parse_qs, urlparse

import numpy as np

from repro import api
from repro.errors import IntegrityError
from repro.exec.cache import DecodeBatcher, TileCache
from repro.exec.plan import bucketed_batch_tiles, tile_working_bytes
from repro.sz import tiled as _tiled
from repro.sz.tiled import TiledCompressed, region_tiles

__all__ = [
    "AdmissionController",
    "RegionServer",
    "RequestRejected",
    "VolumePool",
]

DEFAULT_MEM_BUDGET = 256 << 20
# bounded latency history: enough for stable p99 at load-test scale without
# unbounded growth on a long-lived daemon
_LATENCY_WINDOW = 10_000


class RequestRejected(RuntimeError):
    """Admission control refused the request (queue full / admit timeout) —
    the HTTP layer maps this to 503 Service Unavailable."""


class AdmissionController:
    """Byte-budgeted admission for concurrent decodes.

    Each request declares the working-set bytes its decode may allocate
    (missing lanes × per-tile working estimate); ``admit`` blocks until the
    in-flight total fits the budget.  A request larger than the whole
    budget is admitted ALONE (when nothing else is in flight) — matching
    :func:`repro.exec.plan.max_inflight_tiles`'s always-admit-one rule, so
    oversized ROIs serialize instead of deadlocking.  ``max_queue`` bounds
    how many requests may wait; beyond it (or past ``timeout`` seconds)
    admission raises :class:`RequestRejected`."""

    def __init__(self, budget_bytes: int, *, max_queue: int = 1024,
                 timeout: float = 60.0):
        self.budget = int(budget_bytes)
        self.max_queue = int(max_queue)
        self.timeout = float(timeout)
        self._cv = threading.Condition()
        self.inflight_bytes = 0  # guarded-by: _cv
        self.queue_depth = 0  # guarded-by: _cv
        self.peak_queue_depth = 0  # guarded-by: _cv
        self.rejected = 0  # guarded-by: _cv

    def admit(self, cost: int) -> None:
        cost = max(0, int(cost))
        deadline = time.monotonic() + self.timeout
        with self._cv:
            if self.queue_depth >= self.max_queue:
                self.rejected += 1
                raise RequestRejected(
                    f"admission queue full ({self.max_queue} waiting)")
            self.queue_depth += 1
            self.peak_queue_depth = max(self.peak_queue_depth, self.queue_depth)
            try:
                while self.inflight_bytes and \
                        self.inflight_bytes + cost > self.budget:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0 or not self._cv.wait(remaining):
                        self.rejected += 1
                        raise RequestRejected(
                            f"admission timed out after {self.timeout:.0f}s "
                            f"({self.inflight_bytes} bytes in flight)")
                self.inflight_bytes += cost
            finally:
                self.queue_depth -= 1

    def release(self, cost: int) -> None:
        with self._cv:
            self.inflight_bytes -= max(0, int(cost))
            self._cv.notify_all()

    def info(self) -> dict:
        with self._cv:
            return {"budget_bytes": self.budget,
                    "inflight_bytes": self.inflight_bytes,
                    "queue_depth": self.queue_depth,
                    "peak_queue_depth": self.peak_queue_depth,
                    "rejected": self.rejected}


class _Metrics:
    """Lock-guarded request aggregates behind ``/metrics``."""

    def __init__(self):
        self._lock = threading.Lock()
        self.started = time.monotonic()
        self.requests = 0  # guarded-by: _lock
        self.errors = 0  # guarded-by: _lock
        self.not_modified = 0  # guarded-by: _lock
        self.lanes_served = 0  # guarded-by: _lock
        self.per_volume: dict[str, int] = {}  # guarded-by: _lock
        self._latency_ms: deque[float] = deque(maxlen=_LATENCY_WINDOW)  # guarded-by: _lock

    def record(self, name: str, latency_ms: float, lanes: int) -> None:
        with self._lock:
            self.requests += 1
            self.lanes_served += lanes
            self.per_volume[name] = self.per_volume.get(name, 0) + 1
            self._latency_ms.append(latency_ms)

    def record_not_modified(self, name: str) -> None:
        """An ETag revalidation hit: the request was answered 304 with no
        decode and no latency sample (nothing ran)."""
        with self._lock:
            self.requests += 1
            self.not_modified += 1
            self.per_volume[name] = self.per_volume.get(name, 0) + 1

    def record_error(self) -> None:
        with self._lock:
            self.errors += 1

    def snapshot(self) -> dict:
        with self._lock:
            lat = np.asarray(self._latency_ms, np.float64)
            out = {"uptime_s": time.monotonic() - self.started,
                   "requests": self.requests, "errors": self.errors,
                   "not_modified": self.not_modified,
                   "lanes_served": self.lanes_served,
                   "per_volume_requests": dict(self.per_volume)}
        if lat.size:
            p50, p90, p99 = np.percentile(lat, [50, 90, 99])
            out["latency_ms"] = {
                "count": int(lat.size), "mean": float(lat.mean()),
                "p50": float(p50), "p90": float(p90), "p99": float(p99),
                "max": float(lat.max())}
        else:
            out["latency_ms"] = {"count": 0}
        return out


class VolumePool:
    """The daemon's pure-logic core: named volumes over one shared cache.

    HTTP-free, so tests and the load benchmark can drive it in process.
    Volumes given as paths are opened with the pool's shared cache and
    closed by :meth:`close`; pre-opened handles are registered as-is (open
    them with ``tile_cache=pool.cache`` to share the budget)."""

    def __init__(self, volumes=None, *, cache_bytes: int | None = None,
                 mem_budget: int = DEFAULT_MEM_BUDGET, max_queue: int = 1024,
                 admit_timeout: float = 60.0, verify: str = "lazy",
                 on_corrupt: str = "raise", fill_value: float = 0.0,
                 batch_wait_ms: float | None = 2.0,
                 batch_max_tiles: int = 256):
        self.cache = TileCache(
            api.DEFAULT_TILE_CACHE_BYTES if cache_bytes is None else cache_bytes)
        self.admission = AdmissionController(
            mem_budget, max_queue=max_queue, timeout=admit_timeout)
        self.metrics = _Metrics()
        # cross-request decode micro-batcher (exec/cache.py): concurrent
        # requests to one volume coalesce their claimed-lane decodes into one
        # bucketed device dispatch per round; batch_wait_ms=None disables
        self.batcher = None if batch_wait_ms is None else DecodeBatcher(
            max_wait_ms=batch_wait_ms, max_batch_tiles=batch_max_tiles)
        self._open_kw = dict(verify=verify, on_corrupt=on_corrupt,
                             fill_value=fill_value,
                             decode_batcher=self.batcher)
        self._volumes: dict[str, api.CompressedVolume] = {}  # guarded-by: _lock
        self._owned: set[str] = set()  # guarded-by: _lock
        self._etag_seeds: dict[str, str] = {}  # guarded-by: _lock
        self._lock = threading.Lock()
        for name, spec in dict(volumes or {}).items():
            self.add_volume(name, spec)

    def add_volume(self, name: str, spec) -> api.CompressedVolume:
        """Register ``spec`` (a path, or an open handle) under ``name``."""
        if isinstance(spec, api.CompressedVolume):
            vol, owned = spec, False
            if vol.decode_batcher is None:
                vol.decode_batcher = self.batcher
        else:
            obj = api.open(spec, tile_cache=self.cache, cache_ns=name,
                           **self._open_kw)
            if isinstance(obj, api.Dataset):
                obj.close()
                raise ValueError(
                    f"{spec!r} is a GWDS dataset; register each field as its "
                    "own volume (open the field and pass the handle)")
            vol, owned = obj, True
        with self._lock:
            if name in self._volumes:
                if owned:
                    vol.close()
                raise ValueError(f"volume {name!r} already registered")
            self._volumes[name] = vol
            if owned:
                self._owned.add(name)
        return vol

    @property
    def names(self) -> tuple[str, ...]:
        with self._lock:
            return tuple(self._volumes)

    def volume(self, name: str) -> api.CompressedVolume:
        with self._lock:
            try:
                return self._volumes[name]
            except KeyError:
                raise KeyError(f"no volume {name!r} "
                               f"(serving: {sorted(self._volumes)})") from None

    def _request_cost(self, vol: api.CompressedVolume, n_lanes: int) -> int:
        """Working-set bytes a region decode may allocate, priced with the
        same per-tile estimate the streaming planner uses.  Lane counts are
        rounded up to their bucketed dispatch width (exec/plan.py): the
        padded rows occupy device working set exactly like real ones, so
        admission must charge for them."""
        art = vol.artifact
        if isinstance(art, TiledCompressed):
            per = tile_working_bytes(art.tile, art.predictor, art.levels)
            return bucketed_batch_tiles(n_lanes) * per
        return 3 * int(np.prod(art.shape)) * 4  # monolithic: full decode

    def _etag_seed(self, name: str, vol: api.CompressedVolume) -> str:
        """Per-volume ETag seed: container identity (shape, byte size, eb,
        codec settings, and the footer lane CRCs when present — those pin the
        actual lane bytes).  Computed once per registered volume."""
        with self._lock:
            cached = self._etag_seeds.get(name)
        if cached is not None:
            return cached
        art = vol.artifact
        h = hashlib.sha1()
        h.update(repr((name, tuple(vol.shape), int(vol.nbytes),
                       float(vol.eb_abs))).encode())
        if isinstance(art, TiledCompressed):
            h.update(repr((art.predictor, art.backend, art.order,
                           art.levels, tuple(art.tile))).encode())
            if art.lane_crcs is not None:
                h.update(np.asarray(art.lane_crcs, np.uint32).tobytes())
        seed = h.hexdigest()
        with self._lock:
            self._etag_seeds[name] = seed
        return seed

    def region_etag(self, name: str, roi) -> tuple[str, tuple]:
        """Strong ETag for ``GET /v/<name>/region``: hash of the volume's
        container identity, the *canonical* ROI (so ``"0:8"`` and ``":8"``
        revalidate each other), and the entropy codec path.  Returns
        ``(etag, parsed_roi)``; raises like :meth:`region` on bad input."""
        from repro.sz.entropy import _accel_default
        from repro.sz.tiled import normalize_roi

        vol = self.volume(name)
        if isinstance(roi, str):
            from repro.cli import parse_roi

            roi = parse_roi(roi)
        canon = normalize_roi(roi, tuple(vol.shape))
        codec_path = "pallas" if _accel_default() else "host"
        digest = hashlib.sha1(
            f"{self._etag_seed(name, vol)}|{canon}|{codec_path}".encode()
        ).hexdigest()
        return f'"{digest[:32]}"', roi

    def region(self, name: str, roi) -> tuple[np.ndarray, dict]:
        """Decode ``vol[roi]`` under admission control.

        ``roi`` is a roi-spec string (``"8:40,:,16:32"``) or a tuple of
        ints/slices.  Returns ``(block, meta)`` where ``meta`` carries the
        per-request metrics (latency_ms, lanes touched / total, shape).
        Raises ``KeyError`` (unknown volume), ``IndexError``/``ValueError``
        (bad ROI), :class:`RequestRejected` (admission), and
        :class:`~repro.errors.IntegrityError` (corrupt lane under the
        pool's ``on_corrupt="raise"`` policy)."""
        vol = self.volume(name)
        if isinstance(roi, str):
            from repro.cli import parse_roi

            roi = parse_roi(roi)
        lanes, total = api.region_lane_count(vol, roi)
        cost = self._request_cost(vol, lanes)
        self.admission.admit(cost)
        t0 = time.perf_counter()
        try:
            block = vol[roi]
        finally:
            self.admission.release(cost)
        latency_ms = (time.perf_counter() - t0) * 1e3
        self.metrics.record(name, latency_ms, lanes)
        meta = {"volume": name, "shape": list(block.shape),
                "dtype": str(block.dtype), "lanes": lanes,
                "lanes_total": total, "latency_ms": latency_ms,
                "cost_bytes": cost}
        return block, meta

    def info(self, name: str) -> dict:
        vol = self.volume(name)
        art = vol.artifact
        out = {"volume": name, "shape": list(vol.shape),
               "dtype": str(vol.dtype), "nbytes": vol.nbytes,
               "eb_abs": vol.eb_abs, "tiled": vol.tiled,
               "enhanced": vol.enhanced,
               "stats": {"tiles_decoded": vol.stats.tiles_decoded,
                         "tiles_total": vol.stats.tiles_total,
                         "cache_hits": vol.stats.cache_hits,
                         "quarantined": vol.stats.quarantined}}
        if vol.tiled:
            out.update(tile=list(art.tile), grid=list(art.grid),
                       n_lanes=art.n_tiles, predictor=art.predictor,
                       backend=art.backend)
        return out

    def metrics_snapshot(self) -> dict:
        out = self.metrics.snapshot()
        out["cache"] = self.cache.info()
        out["admission"] = self.admission.info()
        if self.batcher is not None:
            out["batcher"] = self.batcher.info()
        # process-wide compile/dispatch counters (sz/tiled.py): `programs` is
        # the number of distinct compiled decode executables ever dispatched —
        # flat after warmup means zero recompiles on the hot path
        decode = _tiled.dispatch_stats()
        decode["batch_hist"] = {str(k): v
                                for k, v in sorted(decode["batch_hist"].items())}
        out["decode"] = decode
        out["volumes"] = {n: self.info(n)["stats"] for n in self.names}
        return out

    def close(self) -> None:
        with self._lock:
            volumes, owned = self._volumes, self._owned
            self._volumes, self._owned = {}, set()
        for name, vol in volumes.items():
            if name in owned:
                vol.close()
        self.cache.clear()

    def __enter__(self) -> "VolumePool":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()


# ---------------------------------------------------------------------------
# HTTP layer
# ---------------------------------------------------------------------------


class _Handler(BaseHTTPRequestHandler):
    protocol_version = "HTTP/1.1"
    server_version = "repro-serve/1"

    # -- plumbing ----------------------------------------------------------

    def log_message(self, fmt, *args):  # quiet by default
        if self.server.verbose:  # pragma: no cover - debug aid
            super().log_message(fmt, *args)

    def _send(self, code: int, body: bytes, content_type: str,
              headers: dict | None = None) -> None:
        self.send_response(code)
        self.send_header("Content-Type", content_type)
        self.send_header("Content-Length", str(len(body)))
        for k, v in (headers or {}).items():
            self.send_header(k, str(v))
        self.end_headers()
        self.wfile.write(body)

    def _json(self, code: int, obj: dict, headers: dict | None = None) -> None:
        self._send(code, json.dumps(obj).encode() + b"\n",
                   "application/json", headers)

    def _error(self, code: int, message: str) -> None:
        self.server.pool.metrics.record_error()
        self._json(code, {"error": message})

    # -- routes ------------------------------------------------------------

    def do_GET(self):  # noqa: N802 - BaseHTTPRequestHandler contract
        pool: VolumePool = self.server.pool
        url = urlparse(self.path)
        parts = [p for p in url.path.split("/") if p]
        try:
            if parts == ["healthz"]:
                return self._json(200, {"status": "ok",
                                        "volumes": sorted(pool.names)})
            if parts == ["metrics"]:
                return self._json(200, pool.metrics_snapshot())
            if len(parts) == 3 and parts[0] == "v":
                _, name, verb = parts
                if verb == "info":
                    return self._json(200, pool.info(name))
                if verb == "region":
                    return self._region(pool, name, url.query)
            return self._error(404, f"no route {url.path!r} (routes: "
                                    "/healthz /metrics /v/<name>/info "
                                    "/v/<name>/region?roi=...)")
        except KeyError as e:
            return self._error(404, str(e))
        except RequestRejected as e:
            return self._error(503, str(e))
        except IntegrityError as e:
            return self._error(500, f"integrity failure: {e}")
        except (IndexError, ValueError) as e:
            return self._error(400, str(e))
        except (BrokenPipeError, ConnectionResetError):  # pragma: no cover
            pass  # client went away mid-response

    def _region(self, pool: VolumePool, name: str, query: str) -> None:
        q = parse_qs(query)
        roi = q.get("roi", [None])[0]
        if roi is None:
            return self._error(400, "region requires ?roi=, e.g. "
                                    "roi=8:40,:,16:32")
        # ETag revalidation runs BEFORE admission/decode: a repeated ROI
        # costs one hash, not a region decode
        etag, parsed = pool.region_etag(name, roi)
        inm = self.headers.get("If-None-Match")
        if inm is not None and (inm.strip() == "*" or
                                etag in (v.strip() for v in inm.split(","))):
            pool.metrics.record_not_modified(name)
            return self._send(304, b"", "application/x-npy",
                              headers={"ETag": etag})
        block, meta = pool.region(name, parsed)
        buf = io.BytesIO()
        np.save(buf, np.ascontiguousarray(block))
        self._send(200, buf.getvalue(), "application/x-npy",
                   headers={"X-Repro-Meta": json.dumps(meta), "ETag": etag})


class _ThreadingServer(ThreadingHTTPServer):
    daemon_threads = True
    # hundreds of concurrent readers open sockets faster than handler
    # threads spawn; the default backlog of 5 refuses connections under
    # exactly the load the daemon exists to absorb
    request_queue_size = 512


class RegionServer:
    """The daemon: a :class:`VolumePool` behind a ``ThreadingHTTPServer``.

    ``port=0`` binds an ephemeral port (read it back from ``.address``
    after :meth:`start`).  ``start()`` serves on a daemon thread —
    tests and the load benchmark run the server in process; the CLI's
    ``serve`` command calls :meth:`serve_forever` in the foreground."""

    def __init__(self, volumes=None, *, host: str = "127.0.0.1", port: int = 0,
                 verbose: bool = False, **pool_kw):
        self.pool = volumes if isinstance(volumes, VolumePool) \
            else VolumePool(volumes, **pool_kw)
        self._http = _ThreadingServer((host, port), _Handler)
        self._http.pool = self.pool
        self._http.verbose = verbose
        self._thread: threading.Thread | None = None

    @property
    def address(self) -> tuple[str, int]:
        return self._http.server_address[:2]

    @property
    def url(self) -> str:
        host, port = self.address
        return f"http://{host}:{port}"

    def start(self) -> "RegionServer":
        self._thread = threading.Thread(
            target=self._http.serve_forever, name="repro-serve", daemon=True)
        self._thread.start()
        return self

    def serve_forever(self) -> None:
        self._http.serve_forever()

    def stop(self) -> None:
        self._http.shutdown()
        self._http.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None
        self.pool.close()

    def __enter__(self) -> "RegionServer":
        return self.start()

    def __exit__(self, exc_type, exc, tb) -> None:
        self.stop()


def fetch_region(url: str, name: str, roi: str, timeout: float = 60.0,
                 etag: str | None = None):
    """Tiny stdlib client for tests/benchmarks: GET a region and parse the
    ``.npy`` payload.  Returns ``(array, meta_dict)`` — ``meta["etag"]``
    carries the response ETag; pass it back as ``etag=`` to revalidate,
    which returns ``(None, meta)`` on a 304.  Raises ``RuntimeError`` with
    the server's error message on other non-200s."""
    from urllib.error import HTTPError
    from urllib.request import Request, urlopen

    req = Request(f"{url}/v/{name}/region?roi={roi}")
    if etag is not None:
        req.add_header("If-None-Match", etag)
    try:
        with urlopen(req, timeout=timeout) as r:
            meta = json.loads(r.headers.get("X-Repro-Meta", "{}"))
            meta["etag"] = r.headers.get("ETag")
            arr = np.load(io.BytesIO(r.read()))
    except HTTPError as e:
        if e.code == 304:
            return None, {"etag": e.headers.get("ETag")}
        detail = e.read().decode(errors="replace").strip()
        raise RuntimeError(f"region {name!r} roi={roi!r}: "
                           f"HTTP {e.code}: {detail}") from None
    return arr, meta


def fetch_json(url: str, path: str, timeout: float = 60.0) -> dict:
    """GET a JSON endpoint (``/healthz``, ``/metrics``, ``/v/<n>/info``)."""
    from urllib.request import urlopen

    with urlopen(f"{url}{path}", timeout=timeout) as r:
        return json.loads(r.read())
