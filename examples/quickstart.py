"""Quickstart: GWLZ end-to-end on a synthetic Nyx-like field.

    PYTHONPATH=src python examples/quickstart.py

Compresses the Temperature field with SZ3-class compression at REB 5e-3,
trains 8 group-wise enhancers, attaches them to the stream, round-trips
through bytes, and reports the paper's metrics (Table 2 row analogue).
Finishes with the tiled path at both registered predictors — the same
interp-vs-lorenzo choice applies to tile-grid compression with
random-access region decode (see examples/tiled_region_decode.py).
"""
import sys

sys.path.insert(0, "src")

import jax.numpy as jnp

from repro.core import GWLZ, GWLZTrainConfig, metrics
from repro.data import nyx_like_field
from repro.sz import SZCompressor
from repro.sz.szjax import SZCompressed


def main():
    x = jnp.asarray(nyx_like_field((48, 48, 48), "temperature", seed=1))
    cfg = GWLZTrainConfig(n_groups=8, epochs=80, batch_size=10, min_group_pixels=256)
    gwlz = GWLZ(train_cfg=cfg)

    print("compressing + training enhancers ...")
    artifact, stats = gwlz.compress(x, rel_eb=5e-3)
    print(f"  PSNR  SZ3-only : {stats.psnr_sz:6.2f} dB")
    print(f"  PSNR  GWLZ     : {stats.psnr_gwlz:6.2f} dB  (+{stats.psnr_gwlz-stats.psnr_sz:.2f})")
    print(f"  CR    SZ3-only : {stats.cr_sz:8.1f}x")
    print(f"  CR    GWLZ     : {stats.cr_gwlz:8.1f}x  (overhead {stats.overhead:.4f}x)")
    print(f"  enhancer params: {stats.n_model_params} across {cfg.n_groups} groups")

    blob = artifact.to_bytes()
    print(f"stream size: {len(blob):,} bytes; decompressing from bytes ...")
    out = gwlz.decompress(SZCompressed.from_bytes(blob))
    print(f"  round-trip PSNR: {float(metrics.psnr(x, out)):6.2f} dB")
    print(f"  max |err| / eb : {float(metrics.max_abs_err(x, out)) / artifact.eb_abs:.3f}")

    print("tiled path (GWTC v2, predictor-pluggable) ...")
    for pred in ("lorenzo", "interp"):
        art, _ = SZCompressor(predictor=pred).compress_tiled(x, (16, 16, 16), rel_eb=5e-3)
        print(f"  predictor={pred:8s}: cr {x.nbytes / art.nbytes:6.1f}x "
              f"over {art.n_tiles} independently decodable tiles")


if __name__ == "__main__":
    main()
