"""Quickstart: GWLZ end-to-end through the `repro.api` front door.

    PYTHONPATH=src python examples/quickstart.py

Compresses the Temperature field with SZ3-class compression at REB 5e-3,
trains 8 group-wise enhancers (attached to the stream), persists through
``api.save``/``api.open`` (the envelope is self-sniffing), and reports the
paper's metrics (Table 2 row analogue).  Finishes with the tiled path —
the SAME handle interface, but numpy-style slicing decodes only the
entropy lanes intersecting the request (docs/API.md).
"""
import sys
import tempfile

sys.path.insert(0, "src")

import numpy as np

from repro import api
from repro.core import GWLZTrainConfig, metrics
from repro.data import nyx_like_field


def main():
    x = np.asarray(nyx_like_field((48, 48, 48), "temperature", seed=1))
    cfg = GWLZTrainConfig(n_groups=8, epochs=80, batch_size=10, min_group_pixels=256)

    print("compressing + training enhancers ...")
    vol = api.compress(x, eb=5e-3, enhance=cfg)
    stats = vol.stats
    print(f"  PSNR  SZ3-only : {stats.psnr_sz:6.2f} dB")
    print(f"  PSNR  GWLZ     : {stats.psnr_gwlz:6.2f} dB  (+{stats.psnr_gwlz-stats.psnr_sz:.2f})")
    print(f"  CR    SZ3-only : {stats.cr_sz:8.1f}x")
    print(f"  CR    GWLZ     : {stats.cr_gwlz:8.1f}x  (overhead {stats.overhead:.4f}x)")
    print(f"  enhancer params: {stats.n_model_params} across {cfg.n_groups} groups")

    with tempfile.NamedTemporaryFile(suffix=".gwlz") as f:
        written = api.save(f.name, vol)
        print(f"stream size: {written:,} bytes on disk (== vol.nbytes); reopening ...")
        out = np.asarray(api.open(f.name))  # sniffs SZJX, applies the enhancer
    print(f"  round-trip PSNR: {float(metrics.psnr(x, out)):6.2f} dB")
    print(f"  max |err| / eb : {float(metrics.max_abs_err(x, out)) / vol.eb_abs:.3f}")

    print("tiled path (GWTC, random-access slicing through the same handle) ...")
    for pred in ("lorenzo", "interp"):
        tv = api.compress(x, eb=5e-3, tiled=True, tile=(16, 16, 16), predictor=pred)
        roi = tv[0:16, 16:32, 0:16]  # decodes 1 of 27 entropy lanes
        lanes, total = api.region_lane_count(tv, (slice(0, 16), slice(16, 32), slice(0, 16)))
        print(f"  predictor={pred:8s}: cr {x.nbytes / tv.nbytes:6.1f}x; "
              f"vol[0:16,16:32,0:16] -> {roi.shape} from {lanes}/{total} lanes")


if __name__ == "__main__":
    main()
