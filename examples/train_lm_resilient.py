"""End-to-end resilient LM training with GWLZ-compressed checkpoints.

    PYTHONPATH=src python examples/train_lm_resilient.py --arch gemma3-1b

Runs the production training driver on a reduced config: deterministic token
pipeline, jitted train step, async checkpoints every 20 steps with GWLZ
error-bounded tensor compression, an injected node failure at step 30, and
automatic restore-and-replay.  (Full-size configs lower via
``python -m repro.launch.dryrun`` — this container is CPU-only.)
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.launch import train as train_driver


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--steps", type=int, default=60)
    args = ap.parse_args()
    losses = train_driver.main([
        "--arch", args.arch, "--reduced",
        "--steps", str(args.steps),
        "--batch", "4", "--seq", "32",
        "--ckpt-every", "20",
        "--ckpt-dir", "/tmp/repro_example_ckpt",
        "--gwlz-ckpt-eb", "1e-4",
        "--inject-failure-at", "30",
    ])
    assert losses[-1] < losses[0], "training should reduce loss"
    print("resilient training completed; loss improved "
          f"{losses[0]:.3f} -> {losses[-1]:.3f} despite the injected failure")


if __name__ == "__main__":
    main()
