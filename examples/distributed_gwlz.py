"""The paper's pipeline as a distributed SPMD program (host-mesh demo).

    PYTHONPATH=src python examples/distributed_gwlz.py

Groups map to the "model" axis, volume slices to "data" (DESIGN.md §5); on
this 1-device container the mesh is (1, 1) but the program is identical to
the 256-chip cell the dry-run lowers (gwlz-nyx / vol512_g32).  Demonstrates
error-bounded int8 gradient compression with error feedback on the DP axis.
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.core import grouping, metrics
from repro.data import nyx_like_field
from repro.launch.gwlz_dist import DistGWLZConfig, build_state, make_dist_train_step
from repro.launch.mesh import make_host_mesh


def main():
    mesh = make_host_mesh()
    cfg = DistGWLZConfig(n_groups=4, volume=32, batch_slices=8, grad_compress=True)
    x = jnp.asarray(nyx_like_field((32, 32, 32), "temperature", seed=1))
    vol = api.compress(x, eb=5e-3, backend="zlib")
    recon = jnp.asarray(np.asarray(vol))  # decompressor-visible reconstruction
    resid = x - recon

    edges = grouping.compute_edges(recon, cfg.n_groups, "quantile")
    ids = grouping.assign_groups(recon, edges)
    rscale = jnp.zeros(cfg.n_groups).at[ids.ravel()].max(jnp.abs(resid).ravel())

    step, state_sh, batch_sh = make_dist_train_step(cfg, mesh)
    state = build_state(cfg)
    jstep = jax.jit(step)

    rng = np.random.default_rng(0)
    for it in range(120):
        sl = rng.choice(32, size=cfg.batch_slices, replace=False)
        batch = {"x": recon[sl], "r": resid[sl], "edges": edges, "rscale": rscale}
        state, losses = jstep(state, batch)
        if it % 30 == 0:
            print(f"step {it:3d} mean group loss {float(losses.mean()):.4f}")

    # enhance with the trained groups
    from repro.core.trainer import GWLZModel, GWLZTrainConfig, enhance, _bn_calibrate

    bn = _bn_calibrate(state["params"], recon, ids, edges, n_groups=cfg.n_groups)
    model = GWLZModel(params=state["params"], bn_state=bn, edges=edges, rscale=rscale,
                      cfg=GWLZTrainConfig(n_groups=cfg.n_groups))
    enh = enhance(recon, model)
    print(f"PSNR sz={float(metrics.psnr(x, recon)):.2f} -> gwlz={float(metrics.psnr(x, enh)):.2f}"
          f" (distributed, int8-EF gradient reduction)")


if __name__ == "__main__":
    main()
