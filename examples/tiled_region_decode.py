"""Tiled compression with random-access region decode, through `repro.api`.

Compresses a Nyx-like field over a tile grid with a selectable per-tile
predictor (the tiled path dispatches any registered predictor — interp
usually compresses smooth fields tighter, lorenzo is cheaper), optionally
trains group-wise enhancers over the grid, persists via ``api.save``, then
reopens and slices the handle: ``vol[roi]`` decodes only the intersecting
entropy lanes — the partial-read path for Nyx-scale fields.  The enhancer
(when attached) is applied per decoded tile, so the slice is bit-identical
to the full decode's crop.

    PYTHONPATH=src python examples/tiled_region_decode.py --size 64 --tile 32 \
        [--predictor interp|lorenzo] [--gwlz --groups 4 --epochs 20]
"""
import argparse
import sys
import tempfile
import time

sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from repro import api
from repro.core import GWLZTrainConfig
from repro.data import NYX_FIELDS, nyx_like_field
from repro.sz import tiled


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--field", default="temperature", choices=list(NYX_FIELDS))
    ap.add_argument("--size", type=int, default=64)
    ap.add_argument("--tile", type=int, default=32)
    ap.add_argument("--reb", type=float, default=1e-3)
    ap.add_argument("--predictor", default="interp", choices=["lorenzo", "interp"],
                    help="per-tile prediction transform (predictor registry)")
    ap.add_argument("--gwlz", action="store_true", help="attach group-wise enhancers")
    ap.add_argument("--groups", type=int, default=4)
    ap.add_argument("--epochs", type=int, default=20)
    args = ap.parse_args()

    x = jnp.asarray(nyx_like_field((args.size,) * 3, args.field, seed=1))
    enhance = (GWLZTrainConfig(n_groups=args.groups, epochs=args.epochs,
                               min_group_pixels=256)
               if args.gwlz else False)

    vol = api.compress(x, eb=args.reb, tiled=True, tile=(args.tile,) * 3,
                       predictor=args.predictor, enhance=enhance)
    if vol.train_stats is not None:
        print(f"GWLZ tiled [{args.predictor}]: PSNR {vol.train_stats.psnr_sz:.2f} -> "
              f"{vol.train_stats.psnr_gwlz:.2f} dB, overhead {vol.train_stats.overhead:.4f}x")
    else:
        err = float(jnp.max(jnp.abs(jnp.asarray(np.asarray(vol)) - x)))
        print(f"SZ tiled [{args.predictor}]: max|err|={err:.4g} (eb={vol.eb_abs:.4g})")

    art = vol.artifact
    rep = vol.size_report()
    print(f"container: {vol.nbytes} bytes over {art.n_tiles} lanes "
          f"(grid {art.grid}, cr {x.nbytes / vol.nbytes:.1f}x, "
          f"index {rep['index']} B)")

    half = args.size // 2
    roi = (slice(0, half), slice(half, args.size), slice(0, half))
    with tempfile.NamedTemporaryFile(suffix=".gwtc") as f:
        api.save(f.name, vol)
        vol2 = api.open(f.name)  # self-sniffing reopen; enhancer rides along

        np.asarray(api.CompressedVolume(vol2.artifact)), vol2[roi]  # warm jit caches

        t0 = time.perf_counter()
        # fresh handle over the parsed artifact: uncached full decode, and the
        # same parse-free footing as the region timing below
        full = np.asarray(api.CompressedVolume(vol2.artifact))
        t_full = time.perf_counter() - t0

        t0 = time.perf_counter()
        region = vol2[roi]  # tiled slicing never uses the full-decode cache
        t_reg = time.perf_counter() - t0

    st = tiled.DECODE_STATS
    np.testing.assert_array_equal(region, full[roi])

    print(f"full decode:   {t_full*1e3:7.1f} ms ({st['tiles_total']} lanes)")
    print(f"region decode: {t_reg*1e3:7.1f} ms ({st['tiles_decoded']}/"
          f"{st['tiles_total']} lanes, {t_full/max(t_reg, 1e-9):.1f}x faster, "
          f"bit-identical to full[roi])")


if __name__ == "__main__":
    main()
