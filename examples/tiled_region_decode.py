"""Tiled compression with random-access region decode (GWTC container).

Compresses a Nyx-like field over a tile grid with a selectable per-tile
predictor (the tiled path dispatches any registered predictor — interp
usually compresses smooth fields tighter, lorenzo is cheaper), optionally
trains group-wise enhancers over the grid, then decodes a sub-region
touching only the intersecting entropy lanes — the partial-read path for
Nyx-scale fields.

    PYTHONPATH=src python examples/tiled_region_decode.py --size 64 --tile 32 \
        [--predictor interp|lorenzo] [--gwlz --groups 4 --epochs 20]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from repro.core import GWLZ, GWLZTrainConfig
from repro.data import NYX_FIELDS, nyx_like_field
from repro.sz import SZCompressor, tiled


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--field", default="temperature", choices=list(NYX_FIELDS))
    ap.add_argument("--size", type=int, default=64)
    ap.add_argument("--tile", type=int, default=32)
    ap.add_argument("--reb", type=float, default=1e-3)
    ap.add_argument("--predictor", default="interp", choices=["lorenzo", "interp"],
                    help="per-tile prediction transform (predictor registry)")
    ap.add_argument("--gwlz", action="store_true", help="attach group-wise enhancers")
    ap.add_argument("--groups", type=int, default=4)
    ap.add_argument("--epochs", type=int, default=20)
    args = ap.parse_args()

    x = jnp.asarray(nyx_like_field((args.size,) * 3, args.field, seed=1))
    tile = (args.tile,) * 3

    if args.gwlz:
        cfg = GWLZTrainConfig(n_groups=args.groups, epochs=args.epochs,
                              min_group_pixels=256)
        gw = GWLZ(train_cfg=cfg)
        artifact, stats = gw.compress_tiled(x, tile, rel_eb=args.reb,
                                            predictor=args.predictor)
        print(f"GWLZ tiled [{artifact.predictor}]: PSNR {stats.psnr_sz:.2f} -> "
              f"{stats.psnr_gwlz:.2f} dB, overhead {stats.overhead:.4f}x")
        decompress_full = lambda a: gw.decompress_tiled(a)
        decompress_roi = lambda a, roi: gw.decompress_region(a, roi)
    else:
        comp = SZCompressor(predictor=args.predictor)
        artifact, recon = comp.compress_tiled(x, tile, rel_eb=args.reb)
        err = float(jnp.max(jnp.abs(recon - x)))
        print(f"SZ tiled [{artifact.predictor}]: max|err|={err:.4g} "
              f"(eb={artifact.eb_abs:.4g})")
        decompress_full = comp.decompress_tiled
        decompress_roi = comp.decompress_region

    blob = artifact.to_bytes()
    rep = artifact.size_report()
    print(f"container: {len(blob)} bytes over {artifact.n_tiles} lanes "
          f"(grid {artifact.grid}, cr {x.nbytes / len(blob):.1f}x, "
          f"index {rep['index']} B)")

    art2 = tiled.TiledCompressed.from_bytes(blob)
    half = args.size // 2
    roi = (slice(0, half), slice(half, args.size), slice(0, half))
    decompress_full(art2), decompress_roi(art2, roi)  # warm the jit caches

    t0 = time.perf_counter()
    full = decompress_full(art2)
    t_full = time.perf_counter() - t0

    t0 = time.perf_counter()
    region = decompress_roi(art2, roi)
    t_reg = time.perf_counter() - t0
    st = tiled.DECODE_STATS
    np.testing.assert_array_equal(np.asarray(region), np.asarray(full)[roi])

    print(f"full decode:   {t_full*1e3:7.1f} ms ({st['tiles_total']} lanes)")
    print(f"region decode: {t_reg*1e3:7.1f} ms ({st['tiles_decoded']}/"
          f"{st['tiles_total']} lanes, {t_full/max(t_reg, 1e-9):.1f}x faster, "
          f"bit-identical to full[roi])")


if __name__ == "__main__":
    main()
