"""Full compression pipeline with group diagnostics (paper Figs. 4/7).

    PYTHONPATH=src python examples/compress_field.py --field dark_matter_density \
        --reb 1e-3 --groups 8 --out /tmp/field.gwlz [--plot-stats]
"""
import argparse
import os
import sys

sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from repro import api
from repro.core import GWLZTrainConfig, grouping
from repro.data import NYX_FIELDS, field_stats, nyx_like_field


def text_hist(vals, bins=30, width=40):
    h, edges = np.histogram(vals, bins=bins)
    top = h.max() or 1
    lines = []
    for i, c in enumerate(h):
        bar = "#" * int(width * c / top)
        lines.append(f"  {edges[i]:12.4g} | {bar}")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--field", default="temperature", choices=list(NYX_FIELDS))
    ap.add_argument("--size", type=int, default=48)
    ap.add_argument("--reb", type=float, default=1e-3)
    ap.add_argument("--groups", type=int, default=8)
    ap.add_argument("--epochs", type=int, default=80)
    ap.add_argument("--out", default="/tmp/field.gwlz")
    ap.add_argument("--plot-stats", action="store_true")
    args = ap.parse_args()

    x = jnp.asarray(nyx_like_field((args.size,) * 3, args.field, seed=1))
    print(f"field={args.field} stats={field_stats(np.asarray(x))}")

    cfg = GWLZTrainConfig(n_groups=args.groups, epochs=args.epochs, min_group_pixels=256)
    vol = api.compress(x, eb=args.reb, enhance=cfg)
    artifact, stats = vol.artifact, vol.stats
    print(f"PSNR {stats.psnr_sz:.2f} -> {stats.psnr_gwlz:.2f} dB; overhead {stats.overhead:.4f}x")

    if args.plot_stats:
        from repro.core.pipeline import deserialize_model
        from repro.sz import decompress

        model = deserialize_model(artifact.extras["gwlz"])
        recon = decompress(artifact)  # raw SZ recon (pre-enhancement)
        ids = grouping.assign_groups(recon, model.edges)
        st = grouping.group_stats(recon, ids, args.groups)
        resid = np.asarray(x - recon)
        print("\nper-group decompressed-value distributions (Fig. 7 analogue):")
        for g in range(args.groups):
            sel = np.asarray(ids) == g
            cnt = int(st["count"][g])
            if cnt == 0:
                continue
            print(f" group {g}: n={cnt} range=[{float(st['min'][g]):.4g},{float(st['max'][g]):.4g}]"
                  f" resid_rms={resid[sel].std():.4g}")
        print("\nresidual distribution (Fig. 4b analogue):")
        print(text_hist(resid.ravel()[:: max(resid.size // 20000, 1)]))

    # the façade's save writes the self-describing container verbatim, so the
    # enhancer model rides along and bytes-on-disk == vol.nbytes exactly
    written = api.save(args.out, vol)
    on_disk = os.path.getsize(args.out)
    assert written == on_disk == vol.nbytes, (written, on_disk, vol.nbytes)
    print(f"\nwrote {args.out} ({on_disk} bytes == vol.nbytes); verifying ...")
    vol2 = api.open(args.out)
    assert vol2.enhanced, "attached enhancer must survive the round trip"
    out = jnp.asarray(np.asarray(vol2))
    err = float(jnp.max(jnp.abs(out - x)))
    print(f"max|err|={err:.4g} (eb={vol2.eb_abs:.4g})")


if __name__ == "__main__":
    main()
