"""Batched serving demo: ring-buffer KV caches, greedy decode.

    PYTHONPATH=src python examples/serve_lm.py --arch gemma3-1b
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.launch import serve as serve_driver


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    args = ap.parse_args()
    serve_driver.main(["--arch", args.arch, "--reduced", "--batch", "4",
                       "--prompt-len", "8", "--gen-len", "24", "--ctx", "64"])


if __name__ == "__main__":
    main()
