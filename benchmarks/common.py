"""Shared benchmark config. REPRO_BENCH_FAST=1 shrinks everything for CI;
REPRO_BENCH_SMOKE=1 (``benchmarks/run.py --fast``) shrinks harder so the
whole harness runs in seconds as a rot check (tests/test_bench_smoke.py)."""
from __future__ import annotations

import os
import time

FAST = os.environ.get("REPRO_BENCH_FAST", "0") == "1"
SMOKE = os.environ.get("REPRO_BENCH_SMOKE", "0") == "1"

# CPU-budget settings (paper used 512^3 on 4x RTX4090; we scale down and
# validate trends — EXPERIMENTS.md §Reproduction-notes).  The single-core
# container bounds the budget: 48^3 volumes, 80 epochs, GWLZ-8 for the REB
# sweep (group count scaled to volume; the group-count sweep itself is
# table3).
if SMOKE:
    VOLUME = (16, 16, 16)
    EPOCHS = 2
    REBS = (1e-3,)
    GROUPS = (1, 2)
    FIELDS = ("temperature",)
    TABLE2_GROUPS = 2
elif FAST:
    VOLUME = (32, 32, 32)
    EPOCHS = 30
    REBS = (5e-3, 1e-3, 1e-4)
    GROUPS = (1, 4)
    FIELDS = ("temperature",)
    TABLE2_GROUPS = 4
else:
    VOLUME = (48, 48, 48)
    EPOCHS = 80
    REBS = (5e-3, 1e-3, 1e-4, 1e-5)
    GROUPS = (1, 5, 10, 20)
    FIELDS = ("temperature", "dark_matter_density")
    TABLE2_GROUPS = 8

# entropy-stage isolation benchmark volume (the acceptance target is 64^3)
ENTROPY_VOLUME = (32, 32, 32) if SMOKE else (64, 64, 64)

# tiled-engine benchmark: full size matches the ISSUE 2 acceptance setting
# (single tile of a 128^3 volume; region decode >= 4x over full decode)
TILED_VOLUME = (32, 32, 32) if SMOKE else (128, 128, 128)
TILED_TILE = (16, 16, 16) if SMOKE else (64, 64, 64)


def timed(fn, *args, repeats=3, **kw):
    fn(*args, **kw)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeats
    return out, dt * 1e6  # us


# Every emitted row, machine-readable — ``benchmarks/run.py --json`` dumps
# this so CI can upload the fast run as a workflow artifact.
ROWS: list[dict] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    ROWS.append({"name": name, "us_per_call": round(us_per_call, 1),
                 "derived": derived})
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def peak_rss_mb() -> float:
    """Process peak RSS in MiB (ru_maxrss is KB on Linux, bytes on macOS)."""
    import resource
    import sys

    ru = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    return ru / (1 << 20) if sys.platform == "darwin" else ru / 1024
