"""Paper Table 3: PSNR vs number of groups (GWLZ-1/5/10/20)."""
from __future__ import annotations

import time

import jax.numpy as jnp

from benchmarks.common import EPOCHS, GROUPS, VOLUME, emit
from repro.core import metrics
from repro.core.trainer import GWLZTrainConfig, enhance, train_enhancers
from repro.data import nyx_like_field
from repro.sz import compress


def main(reb: float = 5e-3, field: str = "temperature") -> None:
    x = jnp.asarray(nyx_like_field(VOLUME, field, seed=1))
    art, recon = compress(x, rel_eb=reb, backend="zlib")
    resid = x - recon
    psnr_sz = float(metrics.psnr(x, recon))
    emit(f"table3/{field}/sz3", 0.0, f"psnr={psnr_sz:.1f}")
    for g in GROUPS:
        cfg = GWLZTrainConfig(n_groups=g, epochs=EPOCHS, batch_size=10, min_group_pixels=256)
        t0 = time.perf_counter()
        model, hist = train_enhancers(recon, resid, cfg)
        dt = (time.perf_counter() - t0) * 1e6
        enh = enhance(recon, model)
        emit(
            f"table3/{field}/gwlz-{g}",
            dt,
            f"psnr={float(metrics.psnr(x, enh)):.1f};"
            f"active={int((model.rscale > 0).sum())}/{g}",
        )


if __name__ == "__main__":
    main()
