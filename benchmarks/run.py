"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  REPRO_BENCH_FAST=1 shrinks settings.
``--fast`` is the smoke mode (tiny volumes, 2 epochs) used by
tests/test_bench_smoke.py so benchmark scripts can't silently rot; ``--only``
restricts which modules run (all modules are still imported, so import rot is
always caught).  ``--fast`` is process-wide: it sets env vars that
benchmarks.common freezes at first import, so run it in its own process (the
CLI), not interleaved with full-size runs via main().  Roofline terms for the TPU target come from the compiled
dry-run (``python -m repro.launch.dryrun`` + ``python -m repro.launch.roofline``).
"""
from __future__ import annotations

import argparse
import os
import traceback


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true",
                    help="smoke mode: tiny settings so the full harness runs in seconds")
    ap.add_argument("--only", nargs="+", default=None, metavar="MODULE",
                    help="run only these modules (throughput, fig5_losscurves, "
                         "table3_groups, table2_psnr)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also dump every emitted row as JSON (CI uploads the "
                         "--fast run as a workflow artifact)")
    args = ap.parse_args(argv)
    if args.fast:
        os.environ["REPRO_BENCH_FAST"] = "1"
        os.environ["REPRO_BENCH_SMOKE"] = "1"

    # import after the env is set: benchmarks.common reads it at import time
    from benchmarks import fig5_losscurves, table2_psnr, table3_groups, throughput

    modules = (throughput, fig5_losscurves, table3_groups, table2_psnr)
    if args.only is not None:
        wanted = set(args.only)
        modules = tuple(m for m in modules if m.__name__.split(".")[-1] in wanted)
        missing = wanted - {m.__name__.split(".")[-1] for m in modules}
        if missing:
            ap.error(f"unknown module(s): {sorted(missing)}")

    print("name,us_per_call,derived")
    failures = 0
    for mod in modules:
        try:
            mod.main()
        except Exception as e:  # keep the harness going; failures are visible
            failures += 1
            print(f"{mod.__name__},0,ERROR:{type(e).__name__}:{e}")
            traceback.print_exc()
    if args.json:
        import json

        from benchmarks import common

        with open(args.json, "w") as f:
            json.dump({"fast": args.fast, "failures": failures,
                       "rows": common.ROWS}, f, indent=1)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
