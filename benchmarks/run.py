"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  REPRO_BENCH_FAST=1 shrinks settings.
Roofline terms for the TPU target come from the compiled dry-run
(``python -m repro.launch.dryrun`` + ``python -m repro.launch.roofline``).
"""
from __future__ import annotations

import traceback


def main() -> None:
    from benchmarks import fig5_losscurves, table2_psnr, table3_groups, throughput

    print("name,us_per_call,derived")
    for mod in (throughput, fig5_losscurves, table3_groups, table2_psnr):
        try:
            mod.main()
        except Exception as e:  # keep the harness going; failures are visible
            print(f"{mod.__name__},0,ERROR:{type(e).__name__}:{e}")
            traceback.print_exc()


if __name__ == "__main__":
    main()
