"""Paper Fig. 5: loss curves — sole-group regular vs sole-group residual vs
group-wise residual."""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import FAST, VOLUME, emit
from repro.core.trainer import GWLZTrainConfig, train_enhancers
from repro.data import nyx_like_field
from repro.sz import compress


def main(reb: float = 5e-3) -> None:
    x = jnp.asarray(nyx_like_field(VOLUME, "temperature", seed=1))
    art, recon = compress(x, rel_eb=reb, backend="zlib")
    resid = x - recon
    epochs = 20 if FAST else 60
    variants = {
        "sole-regular": GWLZTrainConfig(n_groups=1, epochs=epochs, residual_learning=False,
                                        gate_groups=False),
        "sole-residual": GWLZTrainConfig(n_groups=1, epochs=epochs, gate_groups=False),
        "groupwise-residual": GWLZTrainConfig(n_groups=4, epochs=epochs, gate_groups=False,
                                              min_group_pixels=256),
    }
    from repro.core import metrics
    from repro.core.trainer import enhance

    curves = {}
    psnrs = {}
    for name, cfg in variants.items():
        t0 = time.perf_counter()
        model, hist = train_enhancers(recon, resid, cfg)
        dt = (time.perf_counter() - t0) * 1e6
        active = np.asarray(model.rscale) > 0
        loss = hist["loss"][:, active].mean(axis=1) if active.any() else hist["loss"].mean(axis=1)
        curves[name] = loss
        psnrs[name] = float(metrics.psnr(x, enhance(recon, model)))
        pts = ";".join(f"{v:.4f}" for v in loss[:: max(epochs // 10, 1)])
        emit(f"fig5/{name}", dt, f"final={loss[-1]:.4f};psnr={psnrs[name]:.2f};curve={pts}")
    # the paper's ordering, compared in the denormalized volume domain
    order_ok = psnrs["groupwise-residual"] >= psnrs["sole-residual"] - 0.3 >= psnrs["sole-regular"] - 0.6
    emit("fig5/ordering", 0.0,
         f"groupwise>=sole_residual>=sole_regular={bool(order_ok)};psnrs={psnrs}")


if __name__ == "__main__":
    main()
