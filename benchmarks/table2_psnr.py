"""Paper Table 2: PSNR (SZ3 vs GWLZ-n) + file-size overhead across REBs."""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import EPOCHS, FIELDS, REBS, TABLE2_GROUPS, VOLUME, emit
from repro.core import GWLZ, GWLZTrainConfig
from repro.data import nyx_like_field


def main(n_groups: int | None = None) -> None:
    n_groups = TABLE2_GROUPS if n_groups is None else n_groups
    for field in FIELDS:
        x = jnp.asarray(nyx_like_field(VOLUME, field, seed=1))
        for reb in REBS:
            cfg = GWLZTrainConfig(n_groups=n_groups, epochs=EPOCHS, batch_size=10,
                                  min_group_pixels=256)
            import time

            t0 = time.perf_counter()
            art, st = GWLZ(train_cfg=cfg).compress(x, rel_eb=reb)
            dt = (time.perf_counter() - t0) * 1e6
            emit(
                f"table2/{field}/reb{reb:g}",
                dt,
                f"psnr_sz={st.psnr_sz:.1f};psnr_gwlz={st.psnr_gwlz:.1f};"
                f"improve%={100*(st.psnr_gwlz-st.psnr_sz)/st.psnr_sz:.1f};"
                f"overhead={st.overhead:.4f};cr_sz={st.cr_sz:.1f}",
            )


if __name__ == "__main__":
    main()
