"""Serving-daemon load test: hundreds of concurrent readers, one shared
budgeted tile cache — thresholds ASSERTED, not just printed.

    PYTHONPATH=src:. python -m benchmarks.serve_load [--fast] [--json PATH]

Drives the real HTTP daemon (``repro.serve.RegionServer`` on an ephemeral
port) with ``--readers`` concurrent client threads issuing overlapping
ROI requests drawn from a shared pool against one volume, then asserts the
three properties the tentpole promises (docs/SERVING.md):

* **correctness** — every served region is byte-compared against
  ``full[roi]`` from an independent eager decode; one mismatch fails,
* **cache sharing** — the aggregate hit rate over the shared cache must
  clear ``--min-hit-rate`` (overlapping ROIs + single-flight mean each
  lane entropy-decodes roughly once no matter how many clients want it),
* **latency** — p99 region latency (client-observed, queueing included)
  must stay under ``--p99-ms``.

Emits ``serve_load/...`` rows in the harness CSV schema and, with
``--json``, a machine-readable report CI uploads next to the throughput
artifact.  ``--fast`` shrinks the volume, not the concurrency: the
100-reader floor is the acceptance criterion and always holds.
"""
from __future__ import annotations

import argparse
import json
import sys
import threading
import time

import numpy as np


def build_report(args) -> dict:
    from repro import api
    from repro.data import nyx_like_field
    from repro.serve import RegionServer, fetch_json, fetch_region

    from benchmarks.common import emit

    side, tile = args.side, args.tile
    x = np.asarray(nyx_like_field((side,) * 3, "temperature", seed=11),
                   np.float32)
    vol = api.compress(x, abs_eb=float(np.ptp(x)) * 1e-3, tiled=True,
                       tile=(tile,) * 3, predictor="lorenzo")
    full = np.asarray(api.CompressedVolume(vol.artifact))  # independent decode

    # the served handle shares the daemon pool's budgeted cache
    server = RegionServer(cache_bytes=args.cache_bytes,
                          mem_budget=args.mem_budget)
    shared = api.CompressedVolume(vol.artifact, tile_cache=server.pool.cache,
                                  cache_ns="nyx")
    server.pool.add_volume("nyx", shared)

    # shared ROI pool: overlapping windows so readers contend for the same
    # lanes — the regime the single-flight + shared-cache design targets
    rng = np.random.default_rng(7)
    rois = []
    for _ in range(args.roi_pool):
        lo = rng.integers(0, max(1, side - tile), 3)
        hi = [int(min(side, a + rng.integers(tile // 2, 2 * tile)))
              for a in lo]
        rois.append(",".join(f"{int(a)}:{b}" for a, b in zip(lo, hi)))

    latencies: list[float] = []
    mismatches: list[str] = []
    failures: list[str] = []
    lock = threading.Lock()
    gate = threading.Barrier(args.readers + 1)

    def reader(seed: int) -> None:
        r = np.random.default_rng(seed)
        picks = [rois[int(i)] for i in r.integers(0, len(rois),
                                                  args.requests_per_reader)]
        gate.wait()
        for roi in picks:
            t0 = time.perf_counter()
            try:
                arr, _meta = fetch_region(server.url, "nyx", roi,
                                          timeout=args.p99_ms / 250)
            except Exception as e:  # noqa: BLE001 - reported, asserted below
                with lock:
                    failures.append(f"{roi}: {e}")
                continue
            ms = (time.perf_counter() - t0) * 1e3
            sl = tuple(slice(*map(int, t.split(":"))) for t in roi.split(","))
            ok = np.array_equal(arr, full[sl])
            with lock:
                latencies.append(ms)
                if not ok:
                    mismatches.append(roi)

    threads = [threading.Thread(target=reader, args=(1000 + s,), daemon=True)
               for s in range(args.readers)]
    with server:
        for t in threads:
            t.start()
        gate.wait()
        t0 = time.perf_counter()
        for t in threads:
            t.join()
        wall_s = time.perf_counter() - t0
        metrics = fetch_json(server.url, "/metrics")

    lat = np.asarray(latencies, np.float64)
    total = args.readers * args.requests_per_reader
    p50, p90, p99 = (np.percentile(lat, [50, 90, 99]) if lat.size
                     else (float("nan"),) * 3)
    cache = metrics["cache"]
    report = {
        "readers": args.readers,
        "requests": total,
        "completed": int(lat.size),
        "failures": failures[:10],
        "mismatches": mismatches[:10],
        "wall_s": wall_s,
        "rps": lat.size / wall_s if wall_s else 0.0,
        "latency_ms": {"p50": float(p50), "p90": float(p90), "p99": float(p99),
                       "mean": float(lat.mean()) if lat.size else float("nan")},
        "cache": cache,
        "admission": metrics["admission"],
        "volume": {"side": side, "tile": tile,
                   "n_lanes": vol.stats.tiles_total},
        "thresholds": {"p99_ms": args.p99_ms,
                       "min_hit_rate": args.min_hit_rate},
    }

    emit("serve_load/region_p99", p99 * 1e3,
         f"p99_ms={p99:.1f} over {lat.size} requests from {args.readers} readers")
    emit("serve_load/region_p50", p50 * 1e3, f"p50_ms={p50:.1f}")
    emit("serve_load/hit_rate", 0.0,
         f"hit_rate={cache['hit_rate']:.3f} hits={cache['hits']} "
         f"misses={cache['misses']} coalesced={cache['coalesced']}")
    emit("serve_load/throughput", 0.0, f"rps={report['rps']:.1f} "
         f"peak_queue={metrics['admission']['peak_queue_depth']}")

    # -- asserted acceptance thresholds ------------------------------------
    errors = []
    if failures:
        errors.append(f"{len(failures)} requests failed (first: {failures[0]})")
    if mismatches:
        errors.append(f"{len(mismatches)} regions != full[roi] "
                      f"(first: {mismatches[0]})")
    if lat.size < total:
        errors.append(f"only {lat.size}/{total} requests completed")
    if not (p99 < args.p99_ms):
        errors.append(f"p99 {p99:.1f} ms exceeds the {args.p99_ms:.0f} ms bound")
    if not (cache["hit_rate"] > args.min_hit_rate):
        errors.append(f"hit rate {cache['hit_rate']:.3f} below "
                      f"{args.min_hit_rate} — the shared cache is not sharing")
    report["passed"] = not errors
    report["errors"] = errors
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true",
                    help="CI smoke: smaller volume, same 100-reader floor")
    ap.add_argument("--readers", type=int, default=None,
                    help="concurrent client threads (default 200, fast 100; "
                         "the acceptance floor is 100)")
    ap.add_argument("--requests-per-reader", type=int, default=None)
    ap.add_argument("--roi-pool", type=int, default=32,
                    help="distinct (overlapping) ROIs shared by all readers")
    ap.add_argument("--side", type=int, default=None, help="volume side")
    ap.add_argument("--tile", type=int, default=None, help="tile side")
    ap.add_argument("--cache-bytes", type=int, default=64 << 20)
    ap.add_argument("--mem-budget", type=int, default=64 << 20)
    ap.add_argument("--p99-ms", type=float, default=None,
                    help="asserted p99 latency bound (default 5000 ms; "
                         "client-observed, queueing included)")
    ap.add_argument("--min-hit-rate", type=float, default=0.5,
                    help="asserted shared-cache hit-rate floor")
    ap.add_argument("--json", default=None, metavar="PATH")
    args = ap.parse_args(argv)
    if args.readers is None:
        args.readers = 100 if args.fast else 200
    if args.readers < 100:
        ap.error("the acceptance criterion needs >= 100 concurrent readers")
    if args.requests_per_reader is None:
        args.requests_per_reader = 3 if args.fast else 5
    if args.side is None:
        args.side = 24 if args.fast else 48
    if args.tile is None:
        args.tile = 8 if args.fast else 16
    if args.p99_ms is None:
        # single-core CI shares one GIL between 100 readers and the decode
        # pool; the bound is about catching collapse (serialized decodes,
        # admission deadlock), not micro-latency
        args.p99_ms = 5000.0 if args.fast else 10000.0

    report = build_report(args)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2)
        print(f"wrote {args.json}")
    for e in report["errors"]:
        print(f"FAIL: {e}", file=sys.stderr)
    if report["passed"]:
        print(f"serve_load ok: {report['completed']} requests, "
              f"p99 {report['latency_ms']['p99']:.1f} ms, "
              f"hit_rate {report['cache']['hit_rate']:.3f}, "
              f"{report['rps']:.1f} req/s")
    return 0 if report["passed"] else 1


if __name__ == "__main__":
    sys.exit(main())
