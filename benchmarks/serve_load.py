"""Serving-daemon load test: hundreds of concurrent readers, one shared
budgeted tile cache — thresholds ASSERTED, not just printed.

    PYTHONPATH=src:. python -m benchmarks.serve_load [--fast] [--json PATH]

Drives the real HTTP daemon (``repro.serve.RegionServer`` on an ephemeral
port) with ``--readers`` concurrent client threads issuing overlapping
ROI requests drawn from a shared pool against one volume, then asserts the
three properties the tentpole promises (docs/SERVING.md):

* **correctness** — every served region is byte-compared against
  ``full[roi]`` from an independent eager decode; one mismatch fails,
* **cache sharing** — the aggregate hit rate over the shared cache must
  clear ``--min-hit-rate`` (overlapping ROIs + single-flight mean each
  lane entropy-decodes roughly once no matter how many clients want it),
* **latency** — p99 region latency (client-observed, queueing included)
  must stay under ``--p99-ms``,
* **compile stability** — after a warmup pass that touches every decode
  bucket, the storm must trigger **zero** new decode programs
  (``recompiles_after_warmup == 0``); bucketed padding bounds the set of
  compiled executables, and this assertion is what keeps it bounded,
* **dispatch reduction** — a serialized in-process phase hammers one
  volume with ``--readers`` concurrent single-lane region reads, batcher
  off then on, and asserts the cross-request micro-batcher cuts device
  dispatches by at least 2x.

``--batcher off`` disables the pool's cross-request decode batcher (CI
runs both modes and uploads both reports); ``--max-wait-ms`` sets the
batcher's coalescing window.

Emits ``serve_load/...`` rows in the harness CSV schema and, with
``--json``, a machine-readable report CI uploads next to the throughput
artifact.  ``--fast`` shrinks the volume, not the concurrency: the
100-reader floor is the acceptance criterion and always holds.
"""
from __future__ import annotations

import argparse
import json
import sys
import threading
import time

import numpy as np


def _warm_decode_buckets(handle) -> None:
    """Compile every decode program the storm can reach: one decode per
    power-of-two bucket width up to the cap (27 lanes under the cap also
    touches the cap-width bucket via padding).  Goes through the pipeline
    directly so the tile cache stays cold for the hit-rate assertion."""
    from repro.sz import tiled

    n_lanes = handle.artifact.n_tiles
    b = 1
    while b <= tiled.DEFAULT_BUCKET_CAP:
        handle.pipeline.decode_tiles(handle.artifact,
                                     list(range(min(b, n_lanes))))
        if b >= n_lanes:
            break
        b *= 2


def _dispatch_compare(args, artifact, full) -> dict:
    """Serialized in-process phase: ``--readers`` threads each decode one
    tile-aligned lane through a fresh shared-cache handle, batcher off then
    on; the device-dispatch delta (process-global ``tiled`` counters) must
    drop by >= 2x with the batcher coalescing cross-request work."""
    import itertools

    from repro import api
    from repro.exec.cache import DecodeBatcher, TileCache
    from repro.sz import tiled

    t, shp = artifact.tile, artifact.shape
    rois = [tuple(slice(a, min(a + t[d], shp[d])) for d, a in enumerate(pos))
            for pos in itertools.product(
                *[range(0, shp[d], t[d]) for d in range(len(shp))])]

    out = {}
    for mode in ("off", "on"):
        batcher = None if mode == "off" else DecodeBatcher(
            max_wait_ms=max(args.max_wait_ms, 20.0), max_batch_tiles=4096)
        handle = api.CompressedVolume(
            artifact, tile_cache=TileCache(args.cache_bytes),
            cache_ns="cmp", decode_batcher=batcher)
        bad: list[int] = []
        lock = threading.Lock()
        gate = threading.Barrier(args.readers)

        def worker(i: int) -> None:
            roi = rois[i % len(rois)]
            gate.wait()
            arr = handle[roi]
            if not np.array_equal(arr, full[roi]):
                with lock:
                    bad.append(i)

        before = tiled.dispatch_stats()["dispatches"]
        threads = [threading.Thread(target=worker, args=(i,), daemon=True)
                   for i in range(args.readers)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        out[mode] = {
            "dispatches": tiled.dispatch_stats()["dispatches"] - before,
            "mismatches": len(bad),
        }
        if batcher is not None:
            out[mode]["batcher"] = batcher.info()
    off, on = out["off"]["dispatches"], out["on"]["dispatches"]
    out["reduction"] = off / on if on else float("inf")
    return out


def build_report(args) -> dict:
    from repro import api
    from repro.data import nyx_like_field
    from repro.serve import RegionServer, fetch_json, fetch_region
    from repro.sz import tiled

    from benchmarks.common import emit

    side, tile = args.side, args.tile
    x = np.asarray(nyx_like_field((side,) * 3, "temperature", seed=11),
                   np.float32)
    vol = api.compress(x, abs_eb=float(np.ptp(x)) * 1e-3, tiled=True,
                       tile=(tile,) * 3, predictor="lorenzo")
    full = np.asarray(api.CompressedVolume(vol.artifact))  # independent decode

    # the served handle shares the daemon pool's budgeted cache
    server = RegionServer(cache_bytes=args.cache_bytes,
                          mem_budget=args.mem_budget,
                          batch_wait_ms=(None if args.batcher == "off"
                                         else args.max_wait_ms))
    shared = api.CompressedVolume(vol.artifact, tile_cache=server.pool.cache,
                                  cache_ns="nyx")
    server.pool.add_volume("nyx", shared)

    # compile every reachable bucket program, then snapshot: the storm must
    # not mint a single new one (zero warm-path recompiles, asserted below)
    _warm_decode_buckets(shared)
    warm_programs = tiled.dispatch_stats()["programs"]

    # shared ROI pool: overlapping windows so readers contend for the same
    # lanes — the regime the single-flight + shared-cache design targets
    rng = np.random.default_rng(7)
    rois = []
    for _ in range(args.roi_pool):
        lo = rng.integers(0, max(1, side - tile), 3)
        hi = [int(min(side, a + rng.integers(tile // 2, 2 * tile)))
              for a in lo]
        rois.append(",".join(f"{int(a)}:{b}" for a, b in zip(lo, hi)))

    latencies: list[float] = []
    mismatches: list[str] = []
    failures: list[str] = []
    lock = threading.Lock()
    gate = threading.Barrier(args.readers + 1)

    def reader(seed: int) -> None:
        r = np.random.default_rng(seed)
        picks = [rois[int(i)] for i in r.integers(0, len(rois),
                                                  args.requests_per_reader)]
        gate.wait()
        for roi in picks:
            t0 = time.perf_counter()
            try:
                arr, _meta = fetch_region(server.url, "nyx", roi,
                                          timeout=args.p99_ms / 250)
            except Exception as e:  # noqa: BLE001 - reported, asserted below
                with lock:
                    failures.append(f"{roi}: {e}")
                continue
            ms = (time.perf_counter() - t0) * 1e3
            sl = tuple(slice(*map(int, t.split(":"))) for t in roi.split(","))
            ok = np.array_equal(arr, full[sl])
            with lock:
                latencies.append(ms)
                if not ok:
                    mismatches.append(roi)

    threads = [threading.Thread(target=reader, args=(1000 + s,), daemon=True)
               for s in range(args.readers)]
    with server:
        for t in threads:
            t.start()
        gate.wait()
        t0 = time.perf_counter()
        for t in threads:
            t.join()
        wall_s = time.perf_counter() - t0
        metrics = fetch_json(server.url, "/metrics")

    recompiles = tiled.dispatch_stats()["programs"] - warm_programs
    compare = _dispatch_compare(args, vol.artifact, full)

    lat = np.asarray(latencies, np.float64)
    total = args.readers * args.requests_per_reader
    p50, p90, p99 = (np.percentile(lat, [50, 90, 99]) if lat.size
                     else (float("nan"),) * 3)
    cache = metrics["cache"]
    report = {
        "readers": args.readers,
        "requests": total,
        "completed": int(lat.size),
        "failures": failures[:10],
        "mismatches": mismatches[:10],
        "wall_s": wall_s,
        "rps": lat.size / wall_s if wall_s else 0.0,
        "latency_ms": {"p50": float(p50), "p90": float(p90), "p99": float(p99),
                       "mean": float(lat.mean()) if lat.size else float("nan")},
        "cache": cache,
        "admission": metrics["admission"],
        "batcher_mode": args.batcher,
        "batcher": metrics.get("batcher"),
        "decode_programs": tiled.dispatch_stats(),
        "recompiles_after_warmup": int(recompiles),
        "dispatch_compare": compare,
        "volume": {"side": side, "tile": tile,
                   "n_lanes": vol.stats.tiles_total},
        "thresholds": {"p99_ms": args.p99_ms,
                       "min_hit_rate": args.min_hit_rate},
    }
    report["decode_programs"]["batch_hist"] = {
        str(k): v for k, v in report["decode_programs"]["batch_hist"].items()}

    emit("serve_load/region_p99", p99 * 1e3,
         f"p99_ms={p99:.1f} over {lat.size} requests from {args.readers} readers")
    emit("serve_load/region_p50", p50 * 1e3, f"p50_ms={p50:.1f}")
    emit("serve_load/hit_rate", 0.0,
         f"hit_rate={cache['hit_rate']:.3f} hits={cache['hits']} "
         f"misses={cache['misses']} coalesced={cache['coalesced']}")
    emit("serve_load/throughput", 0.0, f"rps={report['rps']:.1f} "
         f"peak_queue={metrics['admission']['peak_queue_depth']}")
    emit("serve_load/recompiles", 0.0,
         f"recompiles_after_warmup={recompiles} "
         f"programs={report['decode_programs']['programs']} "
         f"batcher={args.batcher}")
    emit("serve_load/dispatch_reduction", 0.0,
         f"off={compare['off']['dispatches']} on={compare['on']['dispatches']} "
         f"reduction={compare['reduction']:.1f}x readers={args.readers}")

    # -- asserted acceptance thresholds ------------------------------------
    errors = []
    if failures:
        errors.append(f"{len(failures)} requests failed (first: {failures[0]})")
    if mismatches:
        errors.append(f"{len(mismatches)} regions != full[roi] "
                      f"(first: {mismatches[0]})")
    if lat.size < total:
        errors.append(f"only {lat.size}/{total} requests completed")
    if not (p99 < args.p99_ms):
        errors.append(f"p99 {p99:.1f} ms exceeds the {args.p99_ms:.0f} ms bound")
    if not (cache["hit_rate"] > args.min_hit_rate):
        errors.append(f"hit rate {cache['hit_rate']:.3f} below "
                      f"{args.min_hit_rate} — the shared cache is not sharing")
    if recompiles != 0:
        errors.append(f"{recompiles} decode programs compiled AFTER warmup — "
                      f"the bucket set is not bounding compilation")
    if compare["off"]["mismatches"] or compare["on"]["mismatches"]:
        errors.append("dispatch-compare phase served bytes != full[roi]")
    if compare["on"]["dispatches"] * 2 > compare["off"]["dispatches"]:
        errors.append(
            f"batcher cut dispatches only {compare['reduction']:.2f}x "
            f"({compare['off']['dispatches']} -> "
            f"{compare['on']['dispatches']}); need >= 2x")
    report["passed"] = not errors
    report["errors"] = errors
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true",
                    help="CI smoke: smaller volume, same 100-reader floor")
    ap.add_argument("--readers", type=int, default=None,
                    help="concurrent client threads (default 200, fast 100; "
                         "the acceptance floor is 100)")
    ap.add_argument("--requests-per-reader", type=int, default=None)
    ap.add_argument("--roi-pool", type=int, default=32,
                    help="distinct (overlapping) ROIs shared by all readers")
    ap.add_argument("--side", type=int, default=None, help="volume side")
    ap.add_argument("--tile", type=int, default=None, help="tile side")
    ap.add_argument("--cache-bytes", type=int, default=64 << 20)
    ap.add_argument("--mem-budget", type=int, default=64 << 20)
    ap.add_argument("--p99-ms", type=float, default=None,
                    help="asserted p99 latency bound (default 5000 ms; "
                         "client-observed, queueing included)")
    ap.add_argument("--min-hit-rate", type=float, default=0.5,
                    help="asserted shared-cache hit-rate floor")
    ap.add_argument("--batcher", choices=("on", "off"), default="on",
                    help="cross-request decode micro-batcher in the served "
                         "pool (CI runs both and uploads both reports)")
    ap.add_argument("--max-wait-ms", type=float, default=2.0,
                    help="batcher coalescing window (pool batch_wait_ms)")
    ap.add_argument("--json", default=None, metavar="PATH")
    args = ap.parse_args(argv)
    if args.readers is None:
        args.readers = 100 if args.fast else 200
    if args.readers < 100:
        ap.error("the acceptance criterion needs >= 100 concurrent readers")
    if args.requests_per_reader is None:
        args.requests_per_reader = 3 if args.fast else 5
    if args.side is None:
        args.side = 24 if args.fast else 48
    if args.tile is None:
        args.tile = 8 if args.fast else 16
    if args.p99_ms is None:
        # single-core CI shares one GIL between 100 readers and the decode
        # pool; the bound is about catching collapse (serialized decodes,
        # admission deadlock), not micro-latency
        args.p99_ms = 5000.0 if args.fast else 10000.0

    report = build_report(args)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2)
        print(f"wrote {args.json}")
    for e in report["errors"]:
        print(f"FAIL: {e}", file=sys.stderr)
    if report["passed"]:
        print(f"serve_load ok: {report['completed']} requests, "
              f"p99 {report['latency_ms']['p99']:.1f} ms, "
              f"hit_rate {report['cache']['hit_rate']:.3f}, "
              f"{report['rps']:.1f} req/s, "
              f"recompiles {report['recompiles_after_warmup']}, "
              f"dispatch x{report['dispatch_compare']['reduction']:.1f}")
    return 0 if report["passed"] else 1


if __name__ == "__main__":
    sys.exit(main())
