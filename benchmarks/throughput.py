"""Compression/decompression + kernel throughput (host CPU; the TPU path is
characterized by the dry-run roofline, EXPERIMENTS.md §Roofline).

Times every entropy backend (zlib / huffman / huffman+zlib) end-to-end and
per-stage, plus the entropy-stage isolation benchmark: chunked vectorized
Huffman decode vs the seed per-symbol walk on a 64^3 code tensor (the
acceptance target for the chunked codec is >= 20x)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import (
    ENTROPY_VOLUME,
    TILED_TILE,
    TILED_VOLUME,
    VOLUME,
    emit,
    peak_rss_mb,
    timed,
)
from repro import api
from repro.core import enhancer as E
from repro.data import nyx_like_field
from repro.kernels import ops
from repro.sz.entropy import decode_codes, encode_codes, encode_codes_legacy

BACKENDS = ("zlib", "huffman", "huffman+zlib")


def _entropy_stage_bench() -> None:
    """Isolated entropy-stage decode: new chunked format vs seed format."""
    x = jnp.asarray(nyx_like_field(ENTROPY_VOLUME, "temperature", seed=3))
    eb = 1e-3 * float(jnp.max(x) - jnp.min(x))
    codes = np.asarray(ops.lorenzo_quant_op(x, eb, use_pallas=False))
    raw_mb = codes.size * 4

    blob_new = encode_codes(codes, "huffman+zlib")
    blob_old = encode_codes_legacy(codes, "huffman+zlib")
    out_new, us_new = timed(lambda: decode_codes(blob_new, codes.shape), repeats=3)
    out_old, us_old = timed(lambda: decode_codes(blob_old, codes.shape), repeats=1)
    assert np.array_equal(out_new, codes), "chunked decode must be byte-identical"
    assert np.array_equal(out_old, codes), "legacy decode must be byte-identical"
    side = ENTROPY_VOLUME[0]
    emit(f"throughput/entropy/hcz_decode_{side}c", us_new, f"MBps={raw_mb/us_new:.1f}")
    emit(f"throughput/entropy/hz_seed_decode_{side}c", us_old, f"MBps={raw_mb/us_old:.1f}")
    emit(f"throughput/entropy/decode_speedup_{side}c", us_new,
         f"speedup_vs_seed={us_old/us_new:.1f}x;overhead={(len(blob_new)/len(blob_old)-1)*100:.2f}%")


def _entropy_device_bench() -> None:
    """Device (Pallas) Huffman encode/decode vs the host codec on the same
    code tensor, byte-identity asserted (the ISSUE 8 acceptance rows).

    Off-TPU the kernels run in interpret mode, so the speedup column
    characterizes the dispatch path, not silicon; on TPU the same rows
    report the compiled device throughput.  The stream rows compare the
    executor's per-batch host-stage time with lane packing on the device
    stage vs on the host stage."""
    import os
    import tempfile

    from repro.exec import stream_compress

    x = jnp.asarray(nyx_like_field(ENTROPY_VOLUME, "temperature", seed=3))
    eb = 1e-3 * float(jnp.max(x) - jnp.min(x))
    codes = np.asarray(ops.lorenzo_quant_op(x, eb, use_pallas=False))
    raw_mb = codes.size * 4

    blob_host, us_he = timed(
        lambda: encode_codes(codes, "huffman", use_pallas=False), repeats=3)
    blob_dev, us_de = timed(
        lambda: encode_codes(codes, "huffman", use_pallas=True), repeats=3)
    assert blob_dev == blob_host, "device blob must be bit-identical to host"
    emit("throughput/entropy/device/encode", us_de,
         f"MBps={raw_mb/us_de:.1f};host_MBps={raw_mb/us_he:.1f};"
         f"speedup_vs_host={us_he/us_de:.2f}x")

    out_host, us_hd = timed(
        lambda: decode_codes(blob_host, codes.shape, use_pallas=False), repeats=3)
    out_dev, us_dd = timed(
        lambda: decode_codes(blob_host, codes.shape, use_pallas=True), repeats=3)
    assert np.array_equal(out_dev, codes) and np.array_equal(out_host, codes)
    emit("throughput/entropy/device/decode", us_dd,
         f"MBps={raw_mb/us_dd:.1f};host_MBps={raw_mb/us_hd:.1f};"
         f"speedup_vs_host={us_hd/us_dd:.2f}x")

    # streaming executor: host-stage time with device vs host lane packing
    xs = np.asarray(nyx_like_field(TILED_VOLUME, "temperature", seed=11),
                    np.float32)
    src = tempfile.mktemp(suffix=".npy")
    np.save(src, xs)
    try:
        outs = {}
        for label, dev in (("host", False), ("device", True)):
            out = tempfile.mktemp(suffix=".gwtc")
            rep, us = timed(lambda: stream_compress(
                src, out, tile=TILED_TILE, rel_eb=1e-3, predictor="lorenzo",
                mem_budget=max(xs.nbytes // 4, 1 << 20), use_pallas=dev),
                repeats=1)
            outs[label] = (out, rep, us)
        (out_h, rep_h, us_h), (out_d, rep_d, us_d) = outs["host"], outs["device"]
        assert rep_d.entropy_device and not rep_h.entropy_device
        assert open(out_h, "rb").read() == open(out_d, "rb").read(), \
            "device-packed container must be bit-identical to the host one"
        emit("throughput/entropy/device/stream_host_stage",
             rep_d.host_stage_s * 1e6,
             f"host_path_stage_s={rep_h.host_stage_s:.4f};"
             f"device_path_stage_s={rep_d.host_stage_s:.4f};"
             f"stage_reduction={rep_h.host_stage_s/max(rep_d.host_stage_s, 1e-9):.1f}x;"
             f"batches={rep_d.n_batches}")
        os.unlink(out_h)
        os.unlink(out_d)
    finally:
        os.unlink(src)


def _tiled_bench() -> None:
    """Tiled engine THROUGH THE FAÇADE (`api.compress` + handle slicing):
    compress, full decode, and single-tile region decode per registered
    predictor — the benchmarked hot path is the public path.

    The region row reports the speedup over full decode — random-access
    reads must only pay for intersecting entropy lanes (target >= 4x at the
    full-size 128^3/64^3 setting, where 1 of 8 lanes intersects)."""
    from repro.sz import tiled

    x = jnp.asarray(nyx_like_field(TILED_VOLUME, "temperature", seed=7))
    nbytes = x.size * 4
    for pred in ("lorenzo", "interp"):
        vol, us = timed(
            lambda: api.compress(x, eb=1e-3, tiled=True, tile=TILED_TILE,
                                 predictor=pred), repeats=1)
        art = vol.artifact
        emit(f"throughput/tiled/compress/{pred}", us,
             f"MBps={nbytes/us:.1f};cr={nbytes/vol.nbytes:.1f};tiles={art.n_tiles}")

        # fresh handle per call: full decode is cached once per volume
        full, us_full = timed(
            lambda: np.asarray(api.CompressedVolume(art)), repeats=3)
        emit(f"throughput/tiled/decompress_full/{pred}", us_full,
             f"MBps={nbytes/us_full:.1f}")

        roi = tuple(slice(0, t) for t in art.tile)  # exactly one tile
        reg, us_reg = timed(lambda: vol[roi], repeats=3)
        assert np.array_equal(reg, full[roi]), \
            "façade slicing must equal the full decode's crop"
        lanes = tiled.DECODE_STATS["tiles_decoded"]
        emit(f"throughput/tiled/region_decode/{pred}", us_reg,
             f"MBps={reg.size*4/us_reg:.1f};speedup_vs_full={us_full/us_reg:.1f}x;"
             f"lanes={lanes}/{art.n_tiles}")


def _stream_bench() -> None:
    """Streaming (out-of-core) vs eager compress, with peak-RSS columns.

    The streamed run compresses off an ``.npy`` memmap against a budget of
    a quarter of the volume, so multiple batches are exercised; its row
    reports the executor-tracked peak (the bounded working set) next to
    process peak RSS, and the eager row reports the same RSS column for the
    whole-volume path.  Decodes are asserted identical (lorenzo's integer
    transform makes streamed and eager artifacts byte-equal)."""
    import os
    import tempfile

    from repro.exec import stream_compress

    x = np.asarray(nyx_like_field(TILED_VOLUME, "temperature", seed=11), np.float32)
    nbytes = x.size * 4
    budget = max(nbytes // 4, 1 << 20)
    src = tempfile.mktemp(suffix=".npy")
    np.save(src, x)
    try:
        out = tempfile.mktemp(suffix=".gwtc")
        rep, us_s = timed(lambda: stream_compress(
            src, out, tile=TILED_TILE, rel_eb=1e-3, predictor="lorenzo",
            mem_budget=budget), repeats=1)
        emit("throughput/stream/compress/lorenzo", us_s,
             f"MBps={nbytes/us_s:.1f};peak_trackedMB={rep.peak_tracked_bytes/2**20:.1f};"
             f"budgetMB={budget/2**20:.1f};rssMB={peak_rss_mb():.0f};"
             f"batches={rep.n_batches}")

        vol, us_e = timed(lambda: api.compress(
            x, eb=1e-3, tiled=True, tile=TILED_TILE, predictor="lorenzo"),
            repeats=1)
        emit("throughput/stream/eager_compress/lorenzo", us_e,
             f"MBps={nbytes/us_e:.1f};rssMB={peak_rss_mb():.0f};"
             f"stream_vs_eager={us_e/us_s:.2f}x")

        with api.open(out) as vs:
            assert np.array_equal(np.asarray(vs), np.asarray(vol)), \
                "streamed artifact must decode identically to the eager path"
        os.unlink(out)
    finally:
        os.unlink(src)


def _cached_region_bench() -> None:
    """Repeated region reads through the handle's decoded-tile LRU cache:
    the second read of the same ROI must skip entropy decode entirely."""
    import time

    x = jnp.asarray(nyx_like_field(TILED_VOLUME, "temperature", seed=13))
    vol = api.compress(x, eb=1e-3, tiled=True, tile=TILED_TILE, predictor="lorenzo")
    roi = tuple(slice(0, t) for t in vol.artifact.tile)
    vol[tuple(slice(0, 1) for _ in vol.shape)]  # compile warmup off one tile
    vol.tile_cache.clear()
    t0 = time.perf_counter()  # timed() warms up first, which would fill the cache
    cold = vol[roi]
    us_cold = (time.perf_counter() - t0) * 1e6
    warm, us_warm = timed(lambda: vol[roi], repeats=3)
    assert np.array_equal(cold, warm)
    assert vol.stats.cache_hits > 0, "warm reads must hit the tile cache"
    emit("throughput/tiled/region_cached/lorenzo", us_warm,
         f"MBps={warm.size*4/us_warm:.1f};speedup_vs_cold={us_cold/us_warm:.1f}x;"
         f"hits={vol.stats.cache_hits}")


def _verify_overhead_bench() -> None:
    """Integrity-check overhead (docs/ROBUSTNESS.md): open + full decode of
    an on-disk container with lane CRCs checked up front (``verify="full"``)
    vs skipped entirely (``verify="none"``).  The overhead column is the
    price of checksumming every lane with the stdlib's C crc32."""
    import os
    import tempfile

    x = jnp.asarray(nyx_like_field(TILED_VOLUME, "temperature", seed=17))
    nbytes = x.size * 4
    vol = api.compress(x, eb=1e-3, tiled=True, tile=TILED_TILE,
                       predictor="lorenzo")
    path = tempfile.mktemp(suffix=".gwtc")
    api.save(path, vol)
    try:
        def run(policy: str) -> np.ndarray:
            with api.open(path, verify=policy) as v:
                return np.asarray(v)

        off, us_off = timed(lambda: run("none"), repeats=3)
        on, us_on = timed(lambda: run("full"), repeats=3)
        assert np.array_equal(off, on), \
            "verification must not change a clean decode"
        emit("throughput/verify/off", us_off, f"MBps={nbytes/us_off:.1f}")
        emit("throughput/verify/full", us_on,
             f"MBps={nbytes/us_on:.1f};overhead_vs_off={(us_on/us_off-1)*100:.1f}%")
    finally:
        os.unlink(path)


def _tile_enhance_bench() -> None:
    """Batched (lax.map) tile enhancement vs the per-tile Python loop.

    Both paths are bit-identical (asserted); the batched row reports the
    measured speedup from collapsing ~n_tiles jit dispatches into one."""
    from repro.core.pipeline import GWLZ, deserialize_model
    from repro.core.trainer import GWLZTrainConfig, enhance_tiles, enhance_tiles_looped
    from repro.sz import tiled

    x = jnp.asarray(nyx_like_field(TILED_VOLUME, "temperature", seed=9))
    tile = tuple(t // 2 for t in TILED_TILE)  # more tiles -> dispatch-bound loop
    gw = GWLZ(train_cfg=GWLZTrainConfig(
        n_groups=4, epochs=2, batch_size=8, min_group_pixels=64))
    art, _ = gw.compress_tiled(x, tile, rel_eb=1e-3)
    model = deserialize_model(art.extras["gwlz"])
    recon_tiles, _ = tiled.decode_lanes(art, range(art.n_tiles))

    batched, us_b = timed(
        lambda: enhance_tiles(recon_tiles, model).block_until_ready(), repeats=3)
    looped, us_l = timed(
        lambda: enhance_tiles_looped(recon_tiles, model).block_until_ready(), repeats=3)
    assert np.array_equal(np.asarray(batched), np.asarray(looped)), \
        "batched tile enhancement must be bit-identical to the looped path"
    emit("throughput/tiled/enhance_batched", us_b,
         f"MBps={batched.size*4/us_b:.1f};speedup_vs_loop={us_l/us_b:.2f}x;"
         f"tiles={art.n_tiles}")


def _bucketed_decode_bench() -> None:
    """Bucketed (compile-cached) lane decode vs the unbucketed path over
    assorted ragged lane counts, bit-identity asserted.

    Bucket padding rounds each batch up to a power-of-two width so every
    decode reuses one of a bounded set of compiled programs; the info
    column reports the compile-cache hit rate over the timed window
    (1 - programs/dispatches) and the padded-tile overhead."""
    from repro.sz import tiled

    x = jnp.asarray(nyx_like_field(TILED_VOLUME, "temperature", seed=7))
    vol = api.compress(x, eb=1e-3, tiled=True, tile=TILED_TILE,
                       predictor="lorenzo")
    art = vol.artifact
    # ragged lane counts: full batch plus off-bucket subsets that need padding
    counts = sorted({art.n_tiles, max(1, art.n_tiles - 1), 3,
                     min(5, art.n_tiles)})

    def run(cap):
        return [np.asarray(tiled.decode_lanes(art, range(n),
                                              bucket_cap=cap)[0])
                for n in counts]

    before = tiled.dispatch_stats()
    bucketed, us_b = timed(lambda: run(None), repeats=3)
    after = tiled.dispatch_stats()
    plain, us_u = timed(lambda: run(0), repeats=3)
    for a, b in zip(bucketed, plain):
        assert np.array_equal(a, b), \
            "bucketed decode must be bit-identical to the unbucketed path"
    dispatches = after["dispatches"] - before["dispatches"]
    programs = after["programs"] - before["programs"]
    padded = after["padded_tiles"] - before["padded_tiles"]
    hit = 1.0 - programs / max(dispatches, 1)
    emit("throughput/tiled/decode_bucketed", us_b,
         f"vs_unbucketed={us_u/us_b:.2f}x;compile_hit_rate={hit:.3f};"
         f"dispatches={dispatches};programs={programs};padded_tiles={padded}")


def _serve_warm_cold_bench() -> None:
    """Region read through an in-process ``VolumePool`` (admission + shared
    tile cache + bucketed decode): first touch pays entropy decode and
    device dispatch, the warm re-read must come out of the shared cache."""
    import time

    from repro.serve import VolumePool

    x = jnp.asarray(nyx_like_field(TILED_VOLUME, "temperature", seed=19))
    vol = api.compress(x, eb=1e-3, tiled=True, tile=TILED_TILE,
                       predictor="lorenzo")
    pool = VolumePool(cache_bytes=64 << 20)
    pool.add_volume("bench", api.CompressedVolume(
        vol.artifact, tile_cache=pool.cache, cache_ns="bench"))
    roi = ",".join(f"0:{t}" for t in vol.artifact.tile)  # one lane

    t0 = time.perf_counter()  # timed() warms up first, which would fill the cache
    cold, _ = pool.region("bench", roi)
    us_cold = (time.perf_counter() - t0) * 1e6
    (warm, _meta), us_warm = timed(lambda: pool.region("bench", roi), repeats=3)
    assert np.array_equal(cold, warm), \
        "warm region read must be byte-equal to the cold decode"
    info = pool.cache.info()
    assert info["hits"] > 0, "warm reads must hit the pool's shared cache"
    emit("throughput/serve/region_warm_vs_cold", us_warm,
         f"cold_us={us_cold:.0f};speedup={us_cold/us_warm:.1f}x;"
         f"hits={info['hits']};misses={info['misses']}")


def _lint_gate_bench() -> None:
    """The RA001–RA005 static-analysis gate (docs/ANALYSIS.md) runs on
    every CI push; this row guards that a full-tree lint stays interactive
    — one shared parse + walk per file must keep it in the single-digit
    seconds, or the gate starts costing more than it saves."""
    from repro.analysis import run_analysis
    from repro.analysis.engine import default_root

    findings, us = timed(run_analysis, repeats=1)
    assert not findings, "lint gate must be clean on the benchmarked tree"
    assert us < 10e6, f"full-tree lint took {us / 1e6:.1f}s (budget: a few seconds)"
    files = sum(1 for p in default_root().rglob("*.py")
                if "__pycache__" not in p.parts)
    emit("throughput/analysis/lint_full_tree", us,
         f"files={files};findings=0;files_per_s={files / (us / 1e6):.0f}")


def main() -> None:
    x = jnp.asarray(nyx_like_field(VOLUME, "temperature", seed=1))
    nbytes = x.size * 4

    for pred in ("lorenzo", "interp"):
        for backend in BACKENDS:
            # monolithic rows go through the façade too (public == hot path)
            vol, us = timed(
                lambda: api.compress(x, eb=1e-3, predictor=pred, backend=backend),
                repeats=2)
            art = vol.artifact
            emit(f"throughput/compress/{pred}/{backend}", us,
                 f"MBps={nbytes/us:.1f};cr={nbytes/vol.nbytes:.1f}")
            _, us = timed(lambda: np.asarray(api.CompressedVolume(art)), repeats=2)
            emit(f"throughput/decompress/{pred}/{backend}", us, f"MBps={nbytes/us:.1f}")
            # per-stage: entropy decode alone (the former Python-loop bottleneck)
            shape = art.padded_shape if pred == "interp" else art.shape
            codes_mb = int(np.prod(shape)) * 4
            _, us = timed(lambda: decode_codes(art.code_blob, shape), repeats=3)
            emit(f"throughput/entropy_decode/{pred}/{backend}", us, f"MBps={codes_mb/us:.1f}")

    _entropy_stage_bench()
    _entropy_device_bench()
    _tiled_bench()
    _stream_bench()
    _verify_overhead_bench()
    _cached_region_bench()
    _tile_enhance_bench()
    _bucketed_decode_bench()
    _serve_warm_cold_bench()
    _lint_gate_bench()

    # kernels (interpret mode on CPU: correctness-path timing only)
    _, us = timed(lambda: ops.lorenzo_quant_op(x, 1.0, use_pallas=False).block_until_ready(), repeats=3)
    emit("throughput/kernel/lorenzo_ref", us, f"MBps={nbytes/us:.1f}")

    import jax

    p = E.init_params(jax.random.PRNGKey(0))
    s = E.init_state()
    slices = x[:16]
    _, us = timed(lambda: ops.enhancer_fused_op(slices, p, s, use_pallas=False).block_until_ready(), repeats=3)
    emit("throughput/kernel/enhancer_ref", us, f"MBps={slices.size*4/us:.1f}")

    edges = jnp.linspace(float(x.min()), float(x.max()) + 1, 21)
    n = (x.size // 128) * 128
    xf = x.ravel()[:n]
    _, us = timed(lambda: ops.group_hist_op(xf.reshape(-1, 128), edges, n_groups=20, use_pallas=False)[0].block_until_ready(), repeats=3)
    emit("throughput/kernel/group_hist_ref", us, f"MBps={n*4/us:.1f}")

    codes_i32 = jnp.asarray(np.asarray(ops.lorenzo_quant_op(x, 1.0, use_pallas=False)))
    span = int(codes_i32.max() - codes_i32.min()) + 1
    shifted = codes_i32 - codes_i32.min()
    _, us = timed(lambda: ops.symbol_hist_op(shifted, n_bins=span, use_pallas=False).block_until_ready(), repeats=3)
    emit("throughput/kernel/symbol_hist_ref", us, f"MBps={n*4/us:.1f}")


if __name__ == "__main__":
    main()
