"""Compression/decompression + kernel throughput (host CPU; the TPU path is
characterized by the dry-run roofline, EXPERIMENTS.md §Roofline)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import FAST, VOLUME, emit, timed
from repro.core import enhancer as E
from repro.data import nyx_like_field
from repro.kernels import ops
from repro.sz import SZCompressor


def main() -> None:
    x = jnp.asarray(nyx_like_field(VOLUME, "temperature", seed=1))
    nbytes = x.size * 4

    for pred in ("lorenzo", "interp"):
        comp = SZCompressor(predictor=pred, backend="zlib")
        (art, recon), us = timed(lambda: comp.compress(x, rel_eb=1e-3), repeats=2)
        emit(f"throughput/compress/{pred}", us, f"MBps={nbytes/us:.1f};cr={nbytes/art.nbytes:.1f}")
        _, us = timed(lambda: comp.decompress(art), repeats=2)
        emit(f"throughput/decompress/{pred}", us, f"MBps={nbytes/us:.1f}")

    # kernels (interpret mode on CPU: correctness-path timing only)
    _, us = timed(lambda: ops.lorenzo_quant_op(x, 1.0, use_pallas=False).block_until_ready(), repeats=3)
    emit("throughput/kernel/lorenzo_ref", us, f"MBps={nbytes/us:.1f}")

    import jax

    p = E.init_params(jax.random.PRNGKey(0))
    s = E.init_state()
    slices = x[:16]
    _, us = timed(lambda: ops.enhancer_fused_op(slices, p, s, use_pallas=False).block_until_ready(), repeats=3)
    emit("throughput/kernel/enhancer_ref", us, f"MBps={slices.size*4/us:.1f}")

    edges = jnp.linspace(float(x.min()), float(x.max()) + 1, 21)
    n = (x.size // 128) * 128
    xf = x.ravel()[:n]
    _, us = timed(lambda: ops.group_hist_op(xf.reshape(-1, 128), edges, n_groups=20, use_pallas=False)[0].block_until_ready(), repeats=3)
    emit("throughput/kernel/group_hist_ref", us, f"MBps={n*4/us:.1f}")


if __name__ == "__main__":
    main()
