"""End-to-end GWLZ: the paper's pipeline (Figs. 1-2) on synthetic Nyx."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import GWLZ, GWLZTrainConfig, deserialize_model, metrics, serialize_model
from repro.core.trainer import enhance, train_enhancers
from repro.sz import compress
from repro.sz.szjax import SZCompressed


@pytest.fixture(scope="module")
def compressed(nyx_small):
    x = jnp.asarray(nyx_small)
    cfg = GWLZTrainConfig(n_groups=4, epochs=40, batch_size=8, min_group_pixels=256)
    art, stats = GWLZ(train_cfg=cfg).compress(x, rel_eb=5e-3)
    return x, art, stats


def test_psnr_improves(compressed):
    x, art, stats = compressed
    # the gate guarantees enhancement never hurts on the training volume
    assert stats.psnr_gwlz >= stats.psnr_sz - 1e-3


def test_decompress_matches_compress_side(compressed):
    x, art, stats = compressed
    art2 = SZCompressed.from_bytes(art.to_bytes())
    out = GWLZ().decompress(art2)
    assert abs(float(metrics.psnr(x, out)) - stats.psnr_gwlz) < 1e-3


def test_overhead_accounting(compressed):
    x, art, stats = compressed
    assert stats.overhead > 0  # enhancer weights attached
    assert stats.cr_gwlz <= stats.cr_sz
    # ~200 params/model * 4 groups * 4B plus metadata
    assert stats.n_model_params < 1000


def test_model_serialization_roundtrip(compressed):
    x, art, stats = compressed
    model = deserialize_model(art.extras["gwlz"])
    blob2 = serialize_model(model)
    assert blob2 == art.extras["gwlz"]


def test_clamp_mode_bounds_error_at_2eb(nyx_small):
    """Clamped enhancement: |x_hat - x| <= 2e worst case (x and x_hat both lie
    in [x'-e, x'+e]); unclamped enhancement has no such guarantee."""
    x = jnp.asarray(nyx_small)
    cfg = GWLZTrainConfig(n_groups=2, epochs=15, batch_size=8)
    art, stats = GWLZ(train_cfg=cfg, clamp_to_bound=True).compress(x, rel_eb=1e-3)
    assert stats.max_err_gwlz <= 2 * art.eb_abs * (1 + 1e-5)


def test_groups_never_hurt(nyx_small):
    """With gating, any group count is >= the SZ baseline (the Table 3 trend
    itself is measured at benchmark scale — 48^3 / 150 epochs; a 32^3 CI
    volume is too noisy for strict monotonicity)."""
    x = jnp.asarray(nyx_small)
    art, recon = compress(x, rel_eb=5e-3, backend="zlib")
    resid = x - recon
    base = float(metrics.psnr(x, recon))
    for g in (1, 4):
        cfg = GWLZTrainConfig(n_groups=g, epochs=60, batch_size=8, min_group_pixels=256, seed=1)
        model, _ = train_enhancers(recon, resid, cfg)
        p = float(metrics.psnr(x, enhance(recon, model)))
        assert p >= base - 1e-3, (g, p, base)


def test_residual_beats_regular(nyx_small):
    """Paper Fig. 5: residual learning reconstructs better than direct
    regression (compared in the *denormalized* volume domain — the raw losses
    live in different normalized units)."""
    from repro.core import metrics

    x = jnp.asarray(nyx_small)
    art, recon = compress(x, rel_eb=5e-3, backend="zlib")
    resid = x - recon
    out_mse = {}
    for mode in (True, False):
        cfg = GWLZTrainConfig(n_groups=1, epochs=25, batch_size=8,
                              residual_learning=mode, gate_groups=False, seed=0)
        model, hist = train_enhancers(recon, resid, cfg)
        out = enhance(recon, model)
        out_mse[mode] = float(metrics.mse(x, out))
    assert out_mse[True] < out_mse[False]
