"""MoE dispatch and attention-variant correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as A
from repro.models import mlp as M


def _dense_moe_reference(params, x, cfg, act):
    """All-experts dense evaluation weighted by router probs (no capacity)."""
    B, S, d = x.shape
    xt = x.reshape(-1, d)
    logits = jnp.einsum("td,de->te", xt, params["router"])
    w, idx = M._route(logits, cfg)
    if act == "silu":
        h = jax.nn.silu(jnp.einsum("td,edf->tef", xt, params["we_gate"]))
        h = h * jnp.einsum("td,edf->tef", xt, params["we_up"])
    else:
        h = jax.nn.gelu(jnp.einsum("td,edf->tef", xt, params["we_up"]))
    ye = jnp.einsum("tef,efd->ted", h, params["we_down"])  # [T,E,d]
    onehot = jax.nn.one_hot(idx, cfg.n_experts)  # [T,k,E]
    comb = jnp.einsum("tke,tk->te", onehot, w)
    out = jnp.einsum("ted,te->td", ye, comb).reshape(B, S, d)
    if "shared" in params:
        out = out + M.apply_mlp(params["shared"], x, act)
    return out


@pytest.mark.parametrize("router,top_k", [("softmax", 2), ("sigmoid", 2), ("softmax", 1)])
def test_moe_matches_dense_reference_with_ample_capacity(router, top_k):
    cfg = M.MoEConfig(n_experts=4, top_k=top_k, d_ff=32, router=router,
                      capacity_factor=4.0, n_shared=1, shared_d_ff=16)
    params = M.init_moe_params(jax.random.PRNGKey(0), 16, cfg, "silu")
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))
    got, aux = M.apply_moe(params, x, cfg, "silu")
    want = _dense_moe_reference(params, x, cfg, "silu")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-3)
    assert float(aux) >= 0


def test_moe_capacity_drops_tokens():
    cfg = M.MoEConfig(n_experts=2, top_k=1, d_ff=8, capacity_factor=0.25)
    params = M.init_moe_params(jax.random.PRNGKey(0), 8, cfg, "silu")
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 8))
    out, _ = M.apply_moe(params, x, cfg, "silu")
    # at capacity 0.25 most tokens are dropped -> many zero rows
    zero_rows = (jnp.abs(out[0]).sum(-1) < 1e-6).sum()
    assert int(zero_rows) >= 8


def _naive_attention(q, k, v, mask):
    H = q.shape[2] // k.shape[2]
    kk = jnp.repeat(k, H, axis=2)
    vv = jnp.repeat(v, H, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kk) / q.shape[-1] ** 0.5
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, -1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vv)


@pytest.mark.parametrize("mode,window", [
    (A.MASK_CAUSAL, 0), (A.MASK_SLIDING, 3), (A.MASK_CHUNKED, 4), (A.MASK_BIDIR, 0),
])
def test_attend_matches_naive(mode, window):
    B, S, H, K, D = 2, 10, 4, 2, 8
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, K, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, K, D))
    pos = jnp.arange(S)
    got = A.attend(q, k, v, pos, pos, mask_mode=mode, window=window, q_block=4)
    i, j = pos[:, None], pos[None, :]
    if mode == A.MASK_BIDIR:
        mask = jnp.ones((S, S), bool)
    elif mode == A.MASK_CAUSAL:
        mask = j <= i
    elif mode == A.MASK_SLIDING:
        mask = (j <= i) & (j > i - window)
    else:
        mask = (j <= i) & (j // window == i // window)
    want = _naive_attention(q, k, v, mask[None, None])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5, rtol=1e-4)


def test_ring_cache_equals_full_cache_for_sliding():
    """Sliding-window ring buffer (size=window) must reproduce full-cache decode."""
    cfg = A.AttnConfig(n_heads=2, n_kv_heads=1, head_dim=8, d_model=16)
    params = A.init_gqa_params(jax.random.PRNGKey(0), cfg)
    S, W = 12, 4
    x = jax.random.normal(jax.random.PRNGKey(1), (1, S, 16))
    pos = jnp.arange(S)
    full, _ = A.gqa_attention(params, cfg, x, pos, mask_mode=A.MASK_SLIDING, window=W)
    ring = A.init_gqa_cache(1, S, cfg, window=W, dtype=jnp.float32)
    outs = []
    for t in range(S):
        y, ring = A.gqa_attention(params, cfg, x[:, t : t + 1], pos[t : t + 1],
                                  mask_mode=A.MASK_SLIDING, window=W, cache=ring)
        outs.append(y)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full), atol=1e-5, rtol=1e-4)


def test_mla_decode_cache_is_latent_sized():
    mla = A.MLAConfig(q_lora=16, kv_lora=8, rope_dim=4, nope_dim=8, v_dim=8)
    cfg = A.AttnConfig(n_heads=2, n_kv_heads=2, head_dim=12, d_model=16, mla=mla)
    cache = A.init_mla_cache(3, 64, cfg)
    assert cache["c_kv"].shape == (3, 64, 8)      # latent, not per-head
    assert cache["k_rope"].shape == (3, 64, 4)    # shared rope key
