"""End-to-end system behaviour: the paper's pipeline + the drivers."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import GWLZ, GWLZTrainConfig, metrics
from repro.data import nyx_like_field


def test_paper_pipeline_end_to_end():
    """Compression module -> stream -> reconstruction module (Figs. 1-2)."""
    x = jnp.asarray(nyx_like_field((32, 32, 32), "temperature", seed=11))
    cfg = GWLZTrainConfig(n_groups=4, epochs=30, batch_size=8, min_group_pixels=256)
    gwlz = GWLZ(train_cfg=cfg)
    artifact, stats = gwlz.compress(x, rel_eb=5e-3)
    assert stats.psnr_gwlz >= stats.psnr_sz - 1e-3   # gate guarantees no regression
    assert stats.overhead < 5.0   # 32^3 volume: a few KB of models vs a tiny stream
    out = gwlz.decompress(type(artifact).from_bytes(artifact.to_bytes()))
    assert float(metrics.psnr(x, out)) == pytest.approx(stats.psnr_gwlz, abs=1e-3)


def test_train_driver_with_failure_and_gwlz_ckpt(tmp_path):
    """The production driver: deterministic pipeline, checkpoint/restart with
    an injected failure, GWLZ-compressed checkpoint tensors."""
    from repro.launch import train as train_driver

    losses = train_driver.main([
        "--arch", "granite-3-8b", "--reduced",
        "--steps", "40", "--batch", "4", "--seq", "16",
        "--lr", "3e-3",
        "--ckpt-every", "8", "--ckpt-dir", str(tmp_path),
        "--inject-failure-at", "12",
        "--gwlz-ckpt-eb", "1e-4",
    ])
    assert len(losses) >= 40
    # the tiny random-token task still has learnable unigram structure
    assert min(losses[-8:]) < losses[0]


def test_serve_driver_generates(tmp_path):
    from repro.launch import serve as serve_driver

    gen = serve_driver.main([
        "--arch", "gemma3-1b", "--reduced", "--batch", "2",
        "--prompt-len", "4", "--gen-len", "8", "--ctx", "32",
    ])
    assert gen.shape == (2, 8)
    assert np.all(gen >= 0)


def test_distributed_gwlz_step_runs():
    """The gwlz-nyx dry-run cell's train step executes on the host mesh."""
    import jax

    from repro.core import grouping
    from repro.launch.gwlz_dist import DistGWLZConfig, build_state, make_dist_train_step
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh()
    cfg = DistGWLZConfig(n_groups=2, volume=16, batch_slices=4, grad_compress=True)
    step, _, _ = make_dist_train_step(cfg, mesh)
    state = build_state(cfg)
    x = jnp.asarray(nyx_like_field((16, 16, 16), "temperature", seed=0))
    edges = grouping.compute_edges(x, 2)
    batch = {"x": x[:4], "r": x[:4] * 1e-3, "edges": edges,
             "rscale": jnp.ones(2) * float(jnp.abs(x).max()) * 1e-3}
    state2, losses = jax.jit(step)(state, batch)
    assert np.isfinite(np.asarray(losses)).all()
    # a second step with error-feedback state
    state3, losses2 = jax.jit(step)(state2, batch)
    assert np.isfinite(np.asarray(losses2)).all()
