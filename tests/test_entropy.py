"""Chunked entropy codec: round trips, chunk boundaries, legacy-format decode,
accelerator-backed frequency counting (docs/ENTROPY_FORMAT.md)."""
import numpy as np
import pytest

from repro.sz.entropy import (
    DEFAULT_CHUNK,
    HuffmanCodec,
    decode_codes,
    decode_codes_range,
    encode_codes,
    encode_codes_legacy,
    shannon_bits,
)

BACKENDS = ("zlib", "huffman", "huffman+zlib")


def _cases():
    rng = np.random.default_rng(7)
    return {
        "skewed": rng.choice([0] * 8 + [1, -1, 2, -2, 9], size=60000).astype(np.int32),
        "uniform_wide": rng.integers(-600, 600, size=37777).astype(np.int32),
        "single_symbol": np.full(1234, -3, np.int32),
        "empty": np.zeros(0, np.int32),
        "one_element": np.array([5], np.int32),
        "big_magnitude": rng.integers(-(2**17), 2**17, size=4000).astype(np.int32),
        "extreme_magnitude": np.array([2**30, -(2**30), 0, 0, 7], np.int32),
    }


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("name", list(_cases()))
def test_roundtrip_distributions(name, backend):
    codes = _cases()[name]
    blob = encode_codes(codes, backend)
    np.testing.assert_array_equal(decode_codes(blob, codes.shape), codes)


@pytest.mark.parametrize("n", [
    0, 1, 7, DEFAULT_CHUNK - 1, DEFAULT_CHUNK, DEFAULT_CHUNK + 1,
    4 * DEFAULT_CHUNK - 1, 4 * DEFAULT_CHUNK, 4 * DEFAULT_CHUNK + 1,
])
def test_chunk_boundaries(n):
    rng = np.random.default_rng(n)
    codes = rng.integers(-9, 9, size=n).astype(np.int32)
    for cs in (8, 64, DEFAULT_CHUNK):
        blob = encode_codes(codes, "huffman", chunk_size=cs)
        np.testing.assert_array_equal(decode_codes(blob, codes.shape), codes)
        blob = encode_codes(codes, "huffman+zlib", chunk_size=cs)
        np.testing.assert_array_equal(decode_codes(blob, codes.shape), codes)


def test_chunked_decode_worker_counts():
    rng = np.random.default_rng(11)
    codes = rng.choice([0, 0, 0, 1, -1, 4], size=10000).astype(np.int32)
    blob = encode_codes(codes, "huffman+zlib", chunk_size=32)
    for workers in (1, 2, 5):
        np.testing.assert_array_equal(
            decode_codes(blob, codes.shape, workers=workers), codes)


@pytest.mark.parametrize("backend", ["huffman", "huffman+zlib"])
def test_legacy_tags_still_decode(backend):
    """Seed hf/hz blobs (pre-chunking format) must keep decoding bit-exactly."""
    rng = np.random.default_rng(3)
    for codes in (
        rng.choice([0, 0, 0, 1, -2], size=5000).astype(np.int32),
        np.full(10, 4, np.int32),
        np.zeros(0, np.int32),
    ):
        blob = encode_codes_legacy(codes, backend)
        assert blob[4:6] in (b"hf", b"hz")
        np.testing.assert_array_equal(decode_codes(blob, codes.shape), codes)


def test_new_tags_are_chunked():
    codes = np.arange(1000, dtype=np.int32) % 17
    assert encode_codes(codes, "huffman")[4:6] == b"hc"
    assert encode_codes(codes, "huffman+zlib")[4:6] == b"hZ"


def test_chunked_matches_bitwalk_reference():
    """The vectorized LUT decode must agree with the seed per-symbol walk."""
    rng = np.random.default_rng(5)
    codes = rng.choice([0] * 20 + list(range(-40, 40)), size=20000).astype(np.int32)
    codec = HuffmanCodec.fit(codes)
    stream = codec.encode(codes)
    want = codec.decode_bitwalk(stream, codes.size)
    blob = encode_codes(codes, "huffman")
    got = decode_codes(blob, codes.shape)
    np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(got, codes)


def test_long_codes_take_escape_path():
    """An alphabet skewed enough to exceed the 12-bit LUT still decodes (the
    per-length escape table resolves the long codes)."""
    sizes = [2 ** i for i in range(18, 0, -1)] + [1, 1]  # ~20 lengths, max > 12
    codes = np.repeat(np.arange(len(sizes), dtype=np.int32), sizes)
    rng = np.random.default_rng(0)
    rng.shuffle(codes)
    codec = HuffmanCodec.fit(codes)
    assert int(codec.lengths.max()) > 12, "test needs codes longer than the LUT"
    blob = encode_codes(codes, "huffman+zlib")
    np.testing.assert_array_equal(decode_codes(blob, codes.shape), codes)


def test_code_lengths_are_limited():
    """Pathological (Fibonacci-like) skew must not exceed the 32-bit cap."""
    from repro.sz.entropy import _limited_code_lengths

    counts = np.asarray([1, 1] + [2 ** i for i in range(1, 45)], np.int64)
    lengths = _limited_code_lengths(counts)
    assert int(lengths.max()) <= 32
    assert lengths.size == counts.size


def test_fit_accel_parity():
    """Accelerator-backed frequency counting gives the identical codec."""
    rng = np.random.default_rng(13)
    codes = rng.choice([0, 0, 0, 0, 1, -1, 2, -3, 8], size=30000).astype(np.int32)
    a = HuffmanCodec.fit(codes, use_accel=True)
    b = HuffmanCodec.fit(codes, use_accel=False)
    np.testing.assert_array_equal(a.alphabet, b.alphabet)
    np.testing.assert_array_equal(a.lengths, b.lengths)
    np.testing.assert_array_equal(a.codes, b.codes)


def test_huffman_near_shannon():
    rng = np.random.default_rng(3)
    codes = rng.choice([0, 0, 0, 0, 0, 1, -1, 2], size=50000).astype(np.int32)
    codec = HuffmanCodec.fit(codes)
    enc = codec.encode(codes)
    ideal = shannon_bits(codes) / 8
    assert len(enc) - 8 <= ideal * 1.25 + 64


def test_chunk_table_overhead_is_small():
    """Chunking must not meaningfully hurt compression (paper §4.3 claim)."""
    rng = np.random.default_rng(2)
    codes = np.round(rng.normal(0, 3, size=64**3)).astype(np.int32)
    new = len(encode_codes(codes, "huffman+zlib"))
    old = len(encode_codes_legacy(codes, "huffman+zlib"))
    assert new <= old * 1.03, (new, old)


def test_truncated_stream_raises():
    codes = np.arange(100, dtype=np.int32) % 7
    blob = encode_codes(codes, "huffman")
    with pytest.raises(ValueError):
        decode_codes(blob[:-4], codes.shape)


def test_roundtrip_fuzz():
    """Seeded sweep over alphabet sizes, skews, and stream lengths."""
    rng = np.random.default_rng(99)
    for _ in range(25):
        n = int(rng.integers(1, 3000))
        alpha = int(rng.integers(1, 200))
        base = int(rng.integers(-(2**16), 2**16))
        p = rng.dirichlet(np.full(alpha, float(rng.uniform(0.05, 2.0))))
        codes = (base + rng.choice(alpha, size=n, p=p)).astype(np.int32)
        for backend in BACKENDS:
            blob = encode_codes(codes, backend)
            np.testing.assert_array_equal(decode_codes(blob, codes.shape), codes)


# ---------------------------------------------------------------------------
# shannon_bits: bincount fast path == np.unique reference (satellite)
# ---------------------------------------------------------------------------


def test_shannon_bits_matches_unique_reference():
    """The dense-alphabet bincount path and the sparse/float unique path must
    compute the identical entropy (and empty input is 0.0, not NaN)."""

    def want(x):
        flat = np.asarray(x).ravel()
        _, counts = np.unique(flat, return_counts=True)
        p = counts / flat.size
        return float(-(p * np.log2(p)).sum() * flat.size)

    rng = np.random.default_rng(31)
    dense_or_sparse = [
        rng.integers(-500, 500, size=20000).astype(np.int32),  # dense bincount
        np.full(100, 7, np.int32),                              # one symbol
        rng.choice([0, 1], size=64).astype(np.int64),
        np.array([-(2**40), 0, 2**40, 2**40], np.int64),        # sparse span
        rng.normal(size=3000),                                  # float: unique
    ]
    for x in dense_or_sparse:
        assert shannon_bits(x) == pytest.approx(want(x), rel=1e-12)
    assert shannon_bits(np.zeros(0, np.int32)) == 0.0


# ---------------------------------------------------------------------------
# device (Pallas interpret) codec path: byte identity with the host pack
# ---------------------------------------------------------------------------

HUFF_BACKENDS = ("huffman", "huffman+zlib")


def _device_cases():
    rng = np.random.default_rng(21)
    return {
        "skewed": rng.choice([0] * 8 + [1, -1, 2, -2, 9], size=6000).astype(np.int32),
        "wide_alphabet": rng.integers(-600, 600, size=4097).astype(np.int32),
        "single_symbol": np.full(1234, -3, np.int32),
        "one_element": np.array([5], np.int32),
        "empty": np.zeros(0, np.int32),
    }


@pytest.mark.parametrize("cs", [8, 64, DEFAULT_CHUNK])
@pytest.mark.parametrize("name", list(_device_cases()))
def test_device_blob_bytes_identical(name, cs):
    """Device encode must emit the SAME hc/hZ blob as the host pack, and the
    device decode must invert it — the container format cannot fork on the
    execution path."""
    codes = _device_cases()[name]
    for backend in HUFF_BACKENDS:
        host = encode_codes(codes, backend, chunk_size=cs, use_pallas=False)
        dev = encode_codes(codes, backend, chunk_size=cs, use_pallas=True)
        assert dev == host, f"{name}/{backend}/cs={cs} device blob diverged"
        np.testing.assert_array_equal(
            decode_codes(dev, codes.shape, use_pallas=True), codes)


@pytest.mark.parametrize("n", [
    1, 7, DEFAULT_CHUNK - 1, DEFAULT_CHUNK, DEFAULT_CHUNK + 1,
    4 * DEFAULT_CHUNK - 1, 4 * DEFAULT_CHUNK + 1,
])
def test_device_chunk_boundaries(n):
    """Short last chunks, exact multiples, and one-over lengths all pack to
    host-identical bytes (the pad lanes must contribute zero bits)."""
    rng = np.random.default_rng(n)
    codes = rng.integers(-9, 9, size=n).astype(np.int32)
    for cs in (8, DEFAULT_CHUNK):
        host = encode_codes(codes, "huffman", chunk_size=cs, use_pallas=False)
        dev = encode_codes(codes, "huffman", chunk_size=cs, use_pallas=True)
        assert dev == host
        np.testing.assert_array_equal(
            decode_codes(dev, codes.shape, use_pallas=True), codes)


def test_device_escape_path_parity():
    """Codes longer than the 12-bit LUT must flow through the kernel's
    binary-search escape and still match the host bytes exactly."""
    sizes = [2 ** i for i in range(14, 0, -1)] + [1, 1]
    codes = np.repeat(np.arange(len(sizes), dtype=np.int32), sizes)
    rng = np.random.default_rng(0)
    rng.shuffle(codes)
    codec = HuffmanCodec.fit(codes)
    assert int(codec.lengths.max()) > 12, "test needs codes longer than the LUT"
    for backend in HUFF_BACKENDS:
        host = encode_codes(codes, backend, chunk_size=64, use_pallas=False)
        dev = encode_codes(codes, backend, chunk_size=64, use_pallas=True)
        assert dev == host
        np.testing.assert_array_equal(
            decode_codes(dev, codes.shape, use_pallas=True), codes)


def test_device_decodes_host_blob_and_vice_versa():
    """Cross-path decode: blobs are one format, so either decoder must accept
    either encoder's output."""
    rng = np.random.default_rng(43)
    codes = rng.choice([0] * 5 + list(range(-15, 15)), size=3000).astype(np.int32)
    host = encode_codes(codes, "huffman+zlib", use_pallas=False)
    dev = encode_codes(codes, "huffman+zlib", use_pallas=True)
    np.testing.assert_array_equal(decode_codes(host, codes.shape, use_pallas=True), codes)
    np.testing.assert_array_equal(decode_codes(dev, codes.shape, use_pallas=False), codes)


def test_device_range_decode_matches_host():
    """decode_codes_range on the device path == host path == the slice."""
    rng = np.random.default_rng(17)
    codes = rng.choice([0] * 6 + list(range(-20, 20)), size=5000).astype(np.int32)
    blob = encode_codes(codes, "huffman+zlib", chunk_size=64, use_pallas=False)
    for lo, hi in [(0, 1), (63, 65), (100, 1000), (4990, 5000), (0, 5000),
                   (777, 777)]:
        got = decode_codes_range(blob, lo, hi, use_pallas=True)
        np.testing.assert_array_equal(got, codes[lo:hi])
        np.testing.assert_array_equal(
            got, decode_codes_range(blob, lo, hi, use_pallas=False))


def test_device_host_fuzz_parity():
    """Seeded fuzz: random alphabets, skews, lengths, and chunk sizes — the
    device blob must stay bit-identical and decode must invert."""
    rng = np.random.default_rng(123)
    for _ in range(8):
        n = int(rng.integers(1, 2000))
        alpha = int(rng.integers(1, 300))
        p = rng.dirichlet(np.full(alpha, float(rng.uniform(0.05, 2.0))))
        codes = (rng.choice(alpha, size=n, p=p).astype(np.int32) - alpha // 2)
        cs = int(rng.choice([8, 32, DEFAULT_CHUNK]))
        backend = HUFF_BACKENDS[int(rng.integers(2))]
        host = encode_codes(codes, backend, chunk_size=cs, use_pallas=False)
        dev = encode_codes(codes, backend, chunk_size=cs, use_pallas=True)
        assert dev == host, f"n={n} alpha={alpha} cs={cs} {backend}"
        np.testing.assert_array_equal(
            decode_codes(dev, codes.shape, use_pallas=True), codes)
