"""Chunked entropy codec: round trips, chunk boundaries, legacy-format decode,
accelerator-backed frequency counting (docs/ENTROPY_FORMAT.md)."""
import numpy as np
import pytest

from repro.sz.entropy import (
    DEFAULT_CHUNK,
    HuffmanCodec,
    decode_codes,
    encode_codes,
    encode_codes_legacy,
    shannon_bits,
)

BACKENDS = ("zlib", "huffman", "huffman+zlib")


def _cases():
    rng = np.random.default_rng(7)
    return {
        "skewed": rng.choice([0] * 8 + [1, -1, 2, -2, 9], size=60000).astype(np.int32),
        "uniform_wide": rng.integers(-600, 600, size=37777).astype(np.int32),
        "single_symbol": np.full(1234, -3, np.int32),
        "empty": np.zeros(0, np.int32),
        "one_element": np.array([5], np.int32),
        "big_magnitude": rng.integers(-(2**17), 2**17, size=4000).astype(np.int32),
        "extreme_magnitude": np.array([2**30, -(2**30), 0, 0, 7], np.int32),
    }


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("name", list(_cases()))
def test_roundtrip_distributions(name, backend):
    codes = _cases()[name]
    blob = encode_codes(codes, backend)
    np.testing.assert_array_equal(decode_codes(blob, codes.shape), codes)


@pytest.mark.parametrize("n", [
    0, 1, 7, DEFAULT_CHUNK - 1, DEFAULT_CHUNK, DEFAULT_CHUNK + 1,
    4 * DEFAULT_CHUNK - 1, 4 * DEFAULT_CHUNK, 4 * DEFAULT_CHUNK + 1,
])
def test_chunk_boundaries(n):
    rng = np.random.default_rng(n)
    codes = rng.integers(-9, 9, size=n).astype(np.int32)
    for cs in (8, 64, DEFAULT_CHUNK):
        blob = encode_codes(codes, "huffman", chunk_size=cs)
        np.testing.assert_array_equal(decode_codes(blob, codes.shape), codes)
        blob = encode_codes(codes, "huffman+zlib", chunk_size=cs)
        np.testing.assert_array_equal(decode_codes(blob, codes.shape), codes)


def test_chunked_decode_worker_counts():
    rng = np.random.default_rng(11)
    codes = rng.choice([0, 0, 0, 1, -1, 4], size=10000).astype(np.int32)
    blob = encode_codes(codes, "huffman+zlib", chunk_size=32)
    for workers in (1, 2, 5):
        np.testing.assert_array_equal(
            decode_codes(blob, codes.shape, workers=workers), codes)


@pytest.mark.parametrize("backend", ["huffman", "huffman+zlib"])
def test_legacy_tags_still_decode(backend):
    """Seed hf/hz blobs (pre-chunking format) must keep decoding bit-exactly."""
    rng = np.random.default_rng(3)
    for codes in (
        rng.choice([0, 0, 0, 1, -2], size=5000).astype(np.int32),
        np.full(10, 4, np.int32),
        np.zeros(0, np.int32),
    ):
        blob = encode_codes_legacy(codes, backend)
        assert blob[4:6] in (b"hf", b"hz")
        np.testing.assert_array_equal(decode_codes(blob, codes.shape), codes)


def test_new_tags_are_chunked():
    codes = np.arange(1000, dtype=np.int32) % 17
    assert encode_codes(codes, "huffman")[4:6] == b"hc"
    assert encode_codes(codes, "huffman+zlib")[4:6] == b"hZ"


def test_chunked_matches_bitwalk_reference():
    """The vectorized LUT decode must agree with the seed per-symbol walk."""
    rng = np.random.default_rng(5)
    codes = rng.choice([0] * 20 + list(range(-40, 40)), size=20000).astype(np.int32)
    codec = HuffmanCodec.fit(codes)
    stream = codec.encode(codes)
    want = codec.decode_bitwalk(stream, codes.size)
    blob = encode_codes(codes, "huffman")
    got = decode_codes(blob, codes.shape)
    np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(got, codes)


def test_long_codes_take_escape_path():
    """An alphabet skewed enough to exceed the 12-bit LUT still decodes (the
    per-length escape table resolves the long codes)."""
    sizes = [2 ** i for i in range(18, 0, -1)] + [1, 1]  # ~20 lengths, max > 12
    codes = np.repeat(np.arange(len(sizes), dtype=np.int32), sizes)
    rng = np.random.default_rng(0)
    rng.shuffle(codes)
    codec = HuffmanCodec.fit(codes)
    assert int(codec.lengths.max()) > 12, "test needs codes longer than the LUT"
    blob = encode_codes(codes, "huffman+zlib")
    np.testing.assert_array_equal(decode_codes(blob, codes.shape), codes)


def test_code_lengths_are_limited():
    """Pathological (Fibonacci-like) skew must not exceed the 32-bit cap."""
    from repro.sz.entropy import _limited_code_lengths

    counts = np.asarray([1, 1] + [2 ** i for i in range(1, 45)], np.int64)
    lengths = _limited_code_lengths(counts)
    assert int(lengths.max()) <= 32
    assert lengths.size == counts.size


def test_fit_accel_parity():
    """Accelerator-backed frequency counting gives the identical codec."""
    rng = np.random.default_rng(13)
    codes = rng.choice([0, 0, 0, 0, 1, -1, 2, -3, 8], size=30000).astype(np.int32)
    a = HuffmanCodec.fit(codes, use_accel=True)
    b = HuffmanCodec.fit(codes, use_accel=False)
    np.testing.assert_array_equal(a.alphabet, b.alphabet)
    np.testing.assert_array_equal(a.lengths, b.lengths)
    np.testing.assert_array_equal(a.codes, b.codes)


def test_huffman_near_shannon():
    rng = np.random.default_rng(3)
    codes = rng.choice([0, 0, 0, 0, 0, 1, -1, 2], size=50000).astype(np.int32)
    codec = HuffmanCodec.fit(codes)
    enc = codec.encode(codes)
    ideal = shannon_bits(codes) / 8
    assert len(enc) - 8 <= ideal * 1.25 + 64


def test_chunk_table_overhead_is_small():
    """Chunking must not meaningfully hurt compression (paper §4.3 claim)."""
    rng = np.random.default_rng(2)
    codes = np.round(rng.normal(0, 3, size=64**3)).astype(np.int32)
    new = len(encode_codes(codes, "huffman+zlib"))
    old = len(encode_codes_legacy(codes, "huffman+zlib"))
    assert new <= old * 1.03, (new, old)


def test_truncated_stream_raises():
    codes = np.arange(100, dtype=np.int32) % 7
    blob = encode_codes(codes, "huffman")
    with pytest.raises(ValueError):
        decode_codes(blob[:-4], codes.shape)


def test_roundtrip_fuzz():
    """Seeded sweep over alphabet sizes, skews, and stream lengths."""
    rng = np.random.default_rng(99)
    for _ in range(25):
        n = int(rng.integers(1, 3000))
        alpha = int(rng.integers(1, 200))
        base = int(rng.integers(-(2**16), 2**16))
        p = rng.dirichlet(np.full(alpha, float(rng.uniform(0.05, 2.0))))
        codes = (base + rng.choice(alpha, size=n, p=p)).astype(np.int32)
        for backend in BACKENDS:
            blob = encode_codes(codes, backend)
            np.testing.assert_array_equal(decode_codes(blob, codes.shape), codes)
