"""Hypothesis property tests for the tiled engine: random shapes, tile sizes
that don't divide the volume, random error bounds — the round trip is always
error-bounded and region decode always equals the full decode's crop.

Split from test_tiled.py so that module still runs when hypothesis isn't
installed (same convention as test_sz_properties.py)."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.sz import tiled

pytestmark = pytest.mark.hypothesis


@st.composite
def volume_and_tile(draw):
    ndim = draw(st.integers(min_value=1, max_value=3))
    shape = tuple(draw(st.integers(min_value=1, max_value=14)) for _ in range(ndim))
    tile = tuple(draw(st.integers(min_value=1, max_value=9)) for _ in range(ndim))
    seed = draw(st.integers(min_value=0, max_value=2**31))
    return shape, tile, seed


@st.composite
def roi_for(draw, shape):
    roi = []
    for d in shape:
        lo = draw(st.integers(min_value=0, max_value=d - 1))
        hi = draw(st.integers(min_value=lo + 1, max_value=d))
        roi.append(slice(lo, hi))
    return tuple(roi)


def _field(shape, seed):
    rng = np.random.default_rng(seed)
    x = np.cumsum(rng.normal(size=shape), axis=0) * draw_scale(rng)
    return jnp.asarray(x.astype(np.float32))


def draw_scale(rng):
    return float(10.0 ** rng.uniform(-2, 3))


def _abs_eb(x, reb):
    """Bound scaled to the data magnitude: random shapes include constant
    and single-element volumes, where a range-relative eb degenerates to the
    f32 tiny floor and trips the representability guard (by design)."""
    return reb * max(float(jnp.max(jnp.abs(x))), 1e-3)


@settings(max_examples=30, deadline=None)
@given(vt=volume_and_tile(), reb=st.sampled_from([1e-2, 1e-3, 1e-4]),
       pred=st.sampled_from(["lorenzo", "interp"]))
def test_tiled_roundtrip_error_bounded(vt, reb, pred):
    shape, tile, seed = vt
    x = _field(shape, seed)
    art, recon = tiled.compress_tiled(x, tile, abs_eb=_abs_eb(x, reb), predictor=pred)
    full = tiled.decompress_tiled(tiled.TiledCompressed.from_bytes(art.to_bytes()))
    assert full.shape == x.shape
    assert float(jnp.max(jnp.abs(full - x))) <= art.eb_abs * (1 + 1e-5)
    # the compression-side reconstruction IS the decode output
    np.testing.assert_array_equal(np.asarray(full), np.asarray(recon))


@settings(max_examples=30, deadline=None)
@given(data=st.data(), vt=volume_and_tile(),
       pred=st.sampled_from(["lorenzo", "interp"]))
def test_region_decode_matches_full_crop(data, vt, pred):
    shape, tile, seed = vt
    x = _field(shape, seed)
    art, _ = tiled.compress_tiled(x, tile, abs_eb=_abs_eb(x, 1e-3), predictor=pred)
    full = np.asarray(tiled.decompress_tiled(art))
    roi = data.draw(roi_for(shape))
    reg = tiled.decompress_region(art, roi)
    np.testing.assert_array_equal(np.asarray(reg), full[roi])
    assert tiled.DECODE_STATS["tiles_decoded"] <= tiled.DECODE_STATS["tiles_total"]


@settings(max_examples=20, deadline=None)
@given(data=st.data(), vt=volume_and_tile(),
       pred=st.sampled_from(["lorenzo", "interp"]),
       cap=st.sampled_from([1, 2, 4, 8]))
def test_bucketed_decode_bit_identical(data, vt, pred, cap):
    """Bucket padding (ISSUE 10) must never change bytes: full and region
    decode under any bucket cap equal the unbucketed (``bucket_cap=0``)
    path exactly, for both predictors — pad rows are repeats of row 0 and
    no per-tile program mixes batch rows, so the crop restores identity."""
    shape, tile, seed = vt
    x = _field(shape, seed)
    art, _ = tiled.compress_tiled(x, tile, abs_eb=_abs_eb(x, 1e-3),
                                  predictor=pred)
    plain = np.asarray(tiled.decompress_tiled(art, bucket_cap=0))
    bucketed = np.asarray(tiled.decompress_tiled(art, bucket_cap=cap))
    np.testing.assert_array_equal(bucketed, plain)
    roi = data.draw(roi_for(shape))
    reg = tiled.decompress_region(art, roi, bucket_cap=cap)
    np.testing.assert_array_equal(np.asarray(reg), plain[roi])


@settings(max_examples=15, deadline=None)
@given(vt=volume_and_tile(), cap=st.sampled_from([1, 2, 4]))
def test_bucketed_quarantine_fill_survives_padding(vt, cap):
    """A quarantined lane must come out fill-valued (NaN here, so nothing
    can fake it) under any bucket cap, identical to the unbucketed decode
    of the same tampered container — padding repeats row 0, which may BE
    the quarantined row, so the fill must be re-asserted after cropping."""
    shape, tile, seed = vt
    x = _field(shape, seed)
    art, _ = tiled.compress_tiled(x, tile, abs_eb=_abs_eb(x, 1e-2))
    blob = art.to_bytes()

    def tampered():
        # fresh artifact per decode: lane verification caches CRC passes
        # (``_verified``), so a reused handle would skip the tampered check
        a = tiled.TiledCompressed.from_bytes(blob)
        assert a.lane_crcs is not None, "v3 containers always carry CRCs"
        a.lane_crcs = a.lane_crcs.copy()
        a.lane_crcs[0] ^= 0xDEAD
        a.verify, a.on_corrupt = "lazy", "quarantine"
        a.fill_value = float("nan")
        return a

    plain = np.asarray(tiled.decompress_tiled(tampered(), bucket_cap=0))
    bucketed = np.asarray(tiled.decompress_tiled(tampered(), bucket_cap=cap))
    assert np.isnan(bucketed).any(), "tampered lane 0 must be quarantined"
    np.testing.assert_array_equal(bucketed, plain)  # NaN == NaN here


@settings(max_examples=20, deadline=None)
@given(data=st.data(), vt=volume_and_tile())
def test_region_as_bound_pairs(data, vt):
    """(lo, hi) pair ROIs (incl. negative indices) behave like slices."""
    shape, tile, seed = vt
    x = _field(shape, seed)
    art, _ = tiled.compress_tiled(x, tile, abs_eb=_abs_eb(x, 1e-2))
    full = np.asarray(tiled.decompress_tiled(art))
    roi_sl = data.draw(roi_for(shape))
    roi_pairs = tuple((s.start - d, s.stop) if s.start > 0 else (s.start, s.stop)
                      for s, d in zip(roi_sl, shape))
    reg = tiled.decompress_region(art, roi_pairs)
    np.testing.assert_array_equal(np.asarray(reg), full[roi_sl])
