"""Concurrent region-serving daemon + read-path concurrency fixes.

Covers the ISSUE 7 tentpole and bugfix satellites: exact lock-guarded
``DecodeStats`` under a thread hammer; ``TileCache`` counter/lock fixes and
single-flight claim coalescing; the shared-cache injection path through
``api.open``; the ``repro.serve`` pool + HTTP daemon (bit-equal regions
under concurrency, including quarantined volumes); admission control; and
the CLI's normalized exit codes (0 ok / 1 integrity / 2 usage).
"""
from __future__ import annotations

import threading

import numpy as np
import pytest

from repro import api, cli
from repro.data import nyx_like_field
from repro.exec.cache import TileCache
from repro.exec.plan import max_inflight_tiles, tile_working_bytes
from repro.serve import (
    AdmissionController,
    RegionServer,
    RequestRejected,
    VolumePool,
    fetch_json,
    fetch_region,
)
from repro.sz import tiled


@pytest.fixture(scope="module")
def field():
    return np.asarray(nyx_like_field((24, 24, 24), "temperature", seed=5),
                      np.float32)


@pytest.fixture(scope="module")
def tiled_vol(field):
    return api.compress(field, abs_eb=float(np.ptp(field)) * 1e-3, tiled=True,
                        tile=(8, 8, 8), predictor="lorenzo")


@pytest.fixture(scope="module")
def full(tiled_vol):
    return np.asarray(api.CompressedVolume(tiled_vol.artifact))


def _gwtc_path(tmp_path, vol, name="v.gwtc"):
    out = tmp_path / name
    api.save(out, vol)
    return out


# ---------------------------------------------------------------------------
# TileCache: lock fixes, counters, single-flight
# ---------------------------------------------------------------------------


def test_cache_counters_and_hit_rate():
    cache = TileCache(1 << 20)
    a = np.zeros(16, np.float32)
    cache.put("k", a)
    assert cache.get_many(["k", "missing"]).keys() == {"k"}
    info = cache.info()
    assert (info["hits"], info["misses"]) == (1, 1)
    assert info["hit_rate"] == 0.5
    assert cache.hits == 1 and cache.misses == 1
    # nbytes/__len__ are lock-guarded snapshots, still correct values
    assert cache.nbytes == a.nbytes and len(cache) == 1


def test_cache_claim_partitions_atomically():
    cache = TileCache(1 << 20)
    cache.put(1, np.zeros(4, np.float32))
    found, mine, theirs = cache.claim([1, 2, 3])
    assert set(found) == {1} and mine == [2, 3] and theirs == {}
    # a second claimant sees the first one's in-flight keys, owns nothing
    found2, mine2, theirs2 = cache.claim([2, 3])
    assert found2 == {} and mine2 == [] and set(theirs2) == {2, 3}
    v = np.ones(4, np.float32)
    cache.fulfill(2, v)
    got = cache.wait(theirs2[2], timeout=5)
    np.testing.assert_array_equal(got, v)
    # abandon wakes waiters empty-handed; the key is claimable again
    cache.abandon([3])
    assert cache.wait(theirs2[3], timeout=5) is None
    _f, mine3, theirs3 = cache.claim([3])
    assert mine3 == [3] and theirs3 == {}
    cache.abandon([3])


@pytest.mark.parametrize("capacity", [1 << 20, 0])
def test_cache_single_flight_under_contention(capacity):
    """Threads racing for one missing key: owners are elected through the
    in-flight registry and every non-owner receives the decoded value via
    the flight hand-off — even with a ZERO-capacity cache that can never
    retain the tile (there, a claim arriving after a fulfill legitimately
    elects a new owner, but no claim is ever left hanging)."""
    cache = TileCache(capacity)
    owners: list[int] = []
    values: list[np.ndarray] = []
    lock = threading.Lock()
    gate = threading.Barrier(16)

    def worker(seed: int) -> None:
        gate.wait()
        found, mine, theirs = cache.claim(["tile"])
        if mine:
            with lock:
                owners.append(seed)
            cache.fulfill("tile", np.full(8, seed, np.float32))
        elif theirs:
            v = cache.wait(theirs["tile"], timeout=10)
            with lock:
                values.append(v)
        else:
            with lock:
                values.append(found["tile"])

    ts = [threading.Thread(target=worker, args=(s,)) for s in range(16)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert len(owners) + len(values) == 16
    assert len(owners) >= 1
    assert all(v is not None and v[0] in owners for v in values), \
        "every waiter must receive some owner's decoded value"
    if capacity:  # retained tile: later claims hit the cache, one owner ever
        assert len(owners) == 1
        assert all(v[0] == owners[0] for v in values)
    assert cache.info()["inflight"] == 0


def test_cache_namespace_drop():
    cache = TileCache(1 << 20)
    for ns in ("a", "b"):
        for i in range(3):
            cache.put((ns, i), np.zeros(8, np.float32))
    assert cache.drop_namespace("a") == 3
    assert len(cache) == 3
    assert set(cache.get_many([("b", i) for i in range(3)])) \
        == {("b", i) for i in range(3)}


# ---------------------------------------------------------------------------
# DecodeStats: exact counters under a thread hammer (bugfix satellite)
# ---------------------------------------------------------------------------


def test_decode_stats_exact_under_hammer(field, full):
    """N threads hammer overlapping ROIs on ONE handle: with lock-guarded
    stats and single-flight decode the counters are EXACT — every lane
    decodes once, and decoded + hits equals the total lane touches."""
    vol = api.compress(field, abs_eb=float(np.ptp(field)) * 1e-3, tiled=True,
                       tile=(8, 8, 8), predictor="lorenzo")
    rois = [(slice(0, 12), slice(0, 24), slice(4, 20)),
            (slice(8, 24), slice(8, 16), slice(0, 8)),
            (slice(0, 8), slice(0, 8), slice(0, 24))]
    touches_per_pass = sum(api.region_lane_count(vol, r)[0] for r in rois)
    union = set()
    for r in rois:
        ids, _ = tiled.region_tiles(vol.artifact, r)
        union.update(ids.tolist())
    n_threads, errors = 12, []
    gate = threading.Barrier(n_threads)

    def worker() -> None:
        gate.wait()
        try:
            for r in rois:
                np.testing.assert_array_equal(vol[r], np.asarray(full)[r])
        except Exception as e:  # pragma: no cover - surfaced below
            errors.append(e)

    ts = [threading.Thread(target=worker) for _ in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert not errors, errors[0]
    assert vol.stats.tiles_decoded == len(union), \
        "single-flight must decode each lane exactly once"
    assert vol.stats.tiles_decoded + vol.stats.cache_hits \
        == n_threads * touches_per_pass, "no lost counter updates"


def test_shared_cache_injection_and_close(tmp_path, tiled_vol, full):
    """Two handles share one injected cache under distinct namespaces;
    closing one evicts only its own tiles."""
    p1 = _gwtc_path(tmp_path, tiled_vol, "a.gwtc")
    p2 = _gwtc_path(tmp_path, tiled_vol, "b.gwtc")
    shared = TileCache(8 << 20)
    v1 = api.open(p1, tile_cache=shared, cache_ns="a")
    v2 = api.open(p2, tile_cache=shared, cache_ns="b")
    roi = (slice(0, 8),) * 3
    np.testing.assert_array_equal(v1[roi], full[roi])
    np.testing.assert_array_equal(v2[roi], full[roi])
    assert len(shared) == 2  # one tile each, namespaced apart
    v1.close()
    assert len(shared) == 1, "closing a pooled handle keeps its neighbors"
    np.testing.assert_array_equal(v2[roi], full[roi])
    assert v2.stats.cache_hits >= 1
    v2.close()
    assert len(shared) == 0


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------


def test_admission_budget_and_oversize():
    adm = AdmissionController(100, max_queue=8, timeout=5.0)
    adm.admit(60)
    done = threading.Event()

    def second() -> None:
        adm.admit(60)  # must wait: 120 > 100
        done.set()

    t = threading.Thread(target=second)
    t.start()
    assert not done.wait(0.15), "over-budget request must queue"
    adm.release(60)
    assert done.wait(5), "release must wake the waiter"
    adm.release(60)
    t.join()
    # oversize: admitted alone rather than deadlocking
    adm.admit(10_000)
    adm.release(10_000)
    assert adm.info()["inflight_bytes"] == 0


def test_admission_queue_full_rejects():
    adm = AdmissionController(10, max_queue=1, timeout=5.0)
    adm.admit(10)
    blocked = threading.Thread(target=lambda: (adm.admit(5), adm.release(5)))
    blocked.start()
    for _ in range(100):
        if adm.info()["queue_depth"] == 1:
            break
        threading.Event().wait(0.01)
    with pytest.raises(RequestRejected):
        adm.admit(5)  # queue already holds max_queue waiters
    assert adm.info()["rejected"] == 1
    adm.release(10)
    blocked.join()


def test_admission_cost_uses_plan_estimate(tmp_path, tiled_vol):
    pool = VolumePool({"v": _gwtc_path(tmp_path, tiled_vol)},
                      cache_bytes=1 << 20, mem_budget=32 << 20)
    with pool:
        vol = pool.volume("v")
        art = vol.artifact
        per = tile_working_bytes(art.tile, art.predictor, art.levels)
        _block, meta = pool.region("v", "0:8,0:8,0:8")
        assert meta["cost_bytes"] == meta["lanes"] * per
        assert max_inflight_tiles(32 << 20, art.tile) == (32 << 20) // per


# ---------------------------------------------------------------------------
# the pool + daemon
# ---------------------------------------------------------------------------


def test_pool_region_info_metrics(tmp_path, tiled_vol, full):
    pool = VolumePool({"nyx": _gwtc_path(tmp_path, tiled_vol)},
                      cache_bytes=8 << 20, mem_budget=8 << 20)
    with pool:
        block, meta = pool.region("nyx", "0:12,:,4:20")
        np.testing.assert_array_equal(block, full[0:12, :, 4:20])
        lanes = api.region_lane_count(pool.volume("nyx"),
                                      (slice(0, 12), slice(None),
                                       slice(4, 20)))[0]
        assert meta["lanes"] == lanes and meta["lanes_total"] == 27
        pool.region("nyx", "0:12,:,4:20")  # repeat: all hits
        info = pool.info("nyx")
        assert info["stats"]["cache_hits"] >= lanes
        m = pool.metrics_snapshot()
        assert m["requests"] == 2 and m["cache"]["hit_rate"] > 0
        assert m["latency_ms"]["count"] == 2
        assert m["volumes"]["nyx"]["tiles_decoded"] == lanes
        with pytest.raises(KeyError, match="no volume"):
            pool.region("nope", "0:4")
        with pytest.raises(ValueError):
            pool.add_volume("nyx", _gwtc_path(tmp_path, tiled_vol))


def test_daemon_concurrent_http_bit_equal(tmp_path, tiled_vol, field, full):
    """Tentpole acceptance (scaled for tier-1): concurrent clients fetching
    overlapping ROIs over real HTTP get bytes bit-equal to ``full[roi]``,
    including from an ``on_corrupt="quarantine"`` volume in the same pool,
    while the shared cache reports a true hit rate."""
    good = _gwtc_path(tmp_path, tiled_vol, "good.gwtc")
    blob = bytearray(good.read_bytes())
    blob[tiled._HDR_V3.size + 16 * 3 + 7] ^= 0x10  # flip a bit in lane 0
    bad = tmp_path / "bad.gwtc"
    bad.write_bytes(bytes(blob))

    pool = VolumePool(cache_bytes=16 << 20, mem_budget=16 << 20,
                      on_corrupt="quarantine", fill_value=-7.0)
    pool.add_volume("good", good)
    pool.add_volume("quar", bad)
    # reference decodes through independent handles with the same policy
    with api.open(bad, on_corrupt="quarantine", fill_value=-7.0) as ref:
        quar_full = np.asarray(ref).copy()
    assert np.all(quar_full[:8, :8, :8] == -7.0)

    errors: list[Exception] = []
    gate = threading.Barrier(8)

    def client(seed: int) -> None:
        rng = np.random.default_rng(seed)
        try:
            gate.wait()
            for _ in range(6):
                lo = rng.integers(0, 16, 3)
                hi = lo + rng.integers(4, 12, 3)
                roi = ",".join(f"{a}:{min(int(b), 24)}"
                               for a, b in zip(lo, hi))
                sl = tuple(slice(*map(int, t.split(":")))
                           for t in roi.split(","))
                name, want = (("good", full) if seed % 2 else
                              ("quar", quar_full))
                arr, meta = fetch_region(server.url, name, roi)
                np.testing.assert_array_equal(arr, want[sl])
                assert meta["lanes_total"] == 27
        except Exception as e:  # pragma: no cover - surfaced below
            errors.append(e)

    with RegionServer(pool) as server:
        ts = [threading.Thread(target=client, args=(s,)) for s in range(8)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        assert not errors, errors[0]

        health = fetch_json(server.url, "/healthz")
        assert health == {"status": "ok", "volumes": ["good", "quar"]}
        m = fetch_json(server.url, "/metrics")
        assert m["requests"] == 48 and m["errors"] == 0
        assert m["cache"]["hit_rate"] > 0, "overlapping ROIs must share"
        assert m["volumes"]["quar"]["quarantined"] == 1
        assert m["latency_ms"]["p99"] >= m["latency_ms"]["p50"]
        # error surface: unknown volume 404, bad roi 400, bad route 404
        with pytest.raises(RuntimeError, match="404"):
            fetch_region(server.url, "nope", "0:4")
        with pytest.raises(RuntimeError, match="400"):
            fetch_region(server.url, "good", "banana")
        info = fetch_json(server.url, "/v/good/info")
        assert info["tiled"] and info["n_lanes"] == 27
    assert len(pool.names) == 0, "server close must close the pool"


# ---------------------------------------------------------------------------
# ETag revalidation: 304 without decode (ISSUE 8 satellite)
# ---------------------------------------------------------------------------


def test_pool_region_etag_canonical(tmp_path, tiled_vol):
    """ETags hash the CANONICAL ROI: equivalent spellings revalidate each
    other, different regions never collide, and the tag is a strong quoted
    token stable across calls."""
    pool = VolumePool({"nyx": _gwtc_path(tmp_path, tiled_vol)},
                      cache_bytes=1 << 20, mem_budget=8 << 20)
    with pool:
        e1, _ = pool.region_etag("nyx", "0:8,0:8,0:8")
        e2, _ = pool.region_etag("nyx", ":8,:8,:8")
        e3, _ = pool.region_etag("nyx", "0:8,0:8,0:8")
        assert e1 == e2 == e3
        assert e1.startswith('"') and e1.endswith('"')
        e4, _ = pool.region_etag("nyx", "8:16,0:8,0:8")
        assert e4 != e1
        with pytest.raises(KeyError):
            pool.region_etag("nope", "0:4")


def test_daemon_etag_304_skips_decode(tmp_path, tiled_vol, full):
    """Revalidating with the returned ETag answers 304 with an empty body:
    no decode work runs (tiles_decoded frozen, no latency sample), yet the
    request and the not_modified counter both advance."""
    pool = VolumePool({"nyx": _gwtc_path(tmp_path, tiled_vol)},
                      cache_bytes=8 << 20, mem_budget=8 << 20)
    with RegionServer(pool) as srv:
        arr1, meta1 = fetch_region(srv.url, "nyx", "0:8,8:16,0:8")
        np.testing.assert_array_equal(arr1, full[0:8, 8:16, 0:8])
        assert meta1["etag"]
        m1 = fetch_json(srv.url, "/metrics")

        # exact and canonical-equivalent ROI spellings both revalidate
        arr2, meta2 = fetch_region(srv.url, "nyx", "0:8,8:16,0:8",
                                   etag=meta1["etag"])
        assert arr2 is None and meta2["etag"] == meta1["etag"]
        arr3, _ = fetch_region(srv.url, "nyx", ":8,8:16,:8",
                               etag=meta1["etag"])
        assert arr3 is None

        m2 = fetch_json(srv.url, "/metrics")
        assert m2["not_modified"] == 2
        assert m2["requests"] == m1["requests"] + 2
        assert m2["volumes"]["nyx"]["tiles_decoded"] \
            == m1["volumes"]["nyx"]["tiles_decoded"], "304 must not decode"
        assert m2["latency_ms"]["count"] == m1["latency_ms"]["count"], \
            "304s take no latency sample"

        # a stale tag for a DIFFERENT region is a miss: full 200 + new tag
        arr4, meta4 = fetch_region(srv.url, "nyx", "8:16,8:16,0:8",
                                   etag=meta1["etag"])
        np.testing.assert_array_equal(arr4, full[8:16, 8:16, 0:8])
        assert meta4["etag"] != meta1["etag"]
        m3 = fetch_json(srv.url, "/metrics")
        assert m3["not_modified"] == 2 and m3["errors"] == 0


# ---------------------------------------------------------------------------
# CLI: normalized exit codes (0 ok / 1 integrity / 2 usage) + serve
# ---------------------------------------------------------------------------


def _exit_code(argv) -> int:
    try:
        rc = cli.main(argv)
    except SystemExit as e:
        return int(e.code or 0)
    return int(rc or 0)


def test_cli_usage_errors_exit_2(tmp_path, tiled_vol):
    out = _gwtc_path(tmp_path, tiled_vol)
    assert _exit_code(["region", str(tmp_path / "missing.gwtc"),
                       "--roi", "0:4"]) == 2
    assert _exit_code(["region", str(out), "--roi", "banana"]) == 2
    assert _exit_code(["region", str(out), "--roi", "0:4", "--field", "t"]) == 2
    assert _exit_code(["verify", str(out), "--field", "t"]) == 2
    assert _exit_code(["decompress", str(tmp_path / "missing.gwtc"),
                       str(tmp_path / "o.npy")]) == 2
    assert _exit_code(["compress", str(tmp_path / "missing.npy"),
                       str(tmp_path / "o.gwtc"), "--eb", "1e-3"]) == 2
    assert _exit_code(["compress", "synthetic:temperature:8",
                       str(tmp_path / "o.gwtc"), "--eb", "1e-3",
                       "--resume"]) == 2


def test_cli_integrity_errors_exit_1(tmp_path, tiled_vol):
    out = _gwtc_path(tmp_path, tiled_vol)
    blob = bytearray(out.read_bytes())
    blob[tiled._HDR_V3.size + 16 * 3 + 5] ^= 0x10
    bad = tmp_path / "bad.gwtc"
    bad.write_bytes(bytes(blob))
    assert _exit_code(["verify", str(bad)]) == 1
    assert _exit_code(["region", str(bad), "--roi", "0:8,0:8,0:8"]) == 1
    assert _exit_code(["verify", str(out)]) == 0
    assert _exit_code(["region", str(out), "--roi", "0:8,0:8,0:8"]) == 0


def test_cli_serve_smoke_and_usage(tmp_path, tiled_vol, capsys):
    out = _gwtc_path(tmp_path, tiled_vol, "nyx.gwtc")
    assert _exit_code(["serve", f"v={out}", "--port", "0", "--smoke"]) == 0
    text = capsys.readouterr().out
    assert "smoke ok" in text and "hit_rate" in text
    assert _exit_code(["serve", f"a={out}", f"a={out}", "--port", "0"]) == 2
    assert _exit_code(["serve", str(tmp_path / "missing.gwtc"),
                       "--port", "0"]) == 2
    assert _exit_code(["serve", f"v={out}", "--port", "0",
                       "--cache-bytes", "banana"]) == 2


# ---------------------------------------------------------------------------
# DecodeBatcher: cross-request micro-batched dispatch (ISSUE 10)
# ---------------------------------------------------------------------------


def test_decode_batcher_coalesces_across_threads():
    """N concurrent single-lane submits to one volume must collapse into ONE
    decode call: with ``max_batch_tiles == N`` the leader cannot drain until
    every submitter has arrived, so the round is deterministic."""
    from repro.exec.cache import DecodeBatcher

    calls: list[list[int]] = []
    lock = threading.Lock()

    def decode(ids):
        with lock:
            calls.append(list(ids))
        return {i: i * 10 for i in ids}

    n = 8
    b = DecodeBatcher(max_wait_ms=5000.0, max_batch_tiles=n)
    gate = threading.Barrier(n)
    out: dict[int, dict] = {}

    def worker(i):
        gate.wait()
        got = b.submit("vol", [i], decode)
        with lock:
            out[i] = got

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(calls) == 1 and sorted(calls[0]) == list(range(n))
    assert out == {i: {i: i * 10} for i in range(n)}
    assert b.dispatches == 1 and b.submits == n
    assert b.coalesced_submits == n - 1
    assert b.pending_tiles == 0 and b.peak_pending_tiles == n
    info = b.info()
    assert info["batch_hist"] == {str(n): 1}


def test_decode_batcher_propagates_leader_error():
    """A decode failure in the leader must surface in EVERY submitter of the
    round — a follower silently getting an empty dict would serve garbage."""
    from repro.exec.cache import DecodeBatcher

    def boom(ids):
        raise RuntimeError("lane decode failed")

    n = 4
    b = DecodeBatcher(max_wait_ms=5000.0, max_batch_tiles=n)
    gate = threading.Barrier(n)
    errs: list[str] = []
    lock = threading.Lock()

    def worker(i):
        gate.wait()
        try:
            b.submit("vol", [i], boom)
        except RuntimeError as e:
            with lock:
                errs.append(str(e))

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(errs) == n and all("lane decode failed" in e for e in errs)
    assert b.pending_tiles == 0, "a failed round must not leak queue depth"


def test_decode_batcher_dedups_and_empty():
    from repro.exec.cache import DecodeBatcher

    seen: list[list[int]] = []

    def decode(ids):
        seen.append(list(ids))
        return {i: -i for i in ids}

    b = DecodeBatcher(max_wait_ms=0.0, max_batch_tiles=64)
    assert b.submit("v", [], decode) == {}
    got = b.submit("v", [3, 3, 5], decode)
    assert seen == [[3, 5]], "duplicate lane ids must decode once"
    assert got == {3: -3, 5: -5}


def test_pool_batcher_metrics_and_bucketed_cost(tmp_path, tiled_vol, full):
    """The pool prices admission on the PADDED batch (6 lanes bucket to 8),
    routes decodes through its batcher, and exposes both the batcher and the
    process-wide compile/dispatch counters in /metrics."""
    from repro.exec.plan import bucketed_batch_tiles

    pool = VolumePool({"v": _gwtc_path(tmp_path, tiled_vol)},
                      cache_bytes=1 << 20, mem_budget=32 << 20,
                      batch_wait_ms=1.0)
    with pool:
        art = pool.volume("v").artifact
        per = tile_working_bytes(art.tile, art.predictor, art.levels)
        roi = "0:17,0:9,0:8"  # 3*2*1 = 6 lanes -> one width-8 bucket
        block, meta = pool.region("v", roi)
        np.testing.assert_array_equal(block, full[0:17, 0:9, 0:8])
        assert meta["lanes"] == 6
        assert bucketed_batch_tiles(6) == 8
        assert meta["cost_bytes"] == 8 * per, \
            "admission must price the padded batch, not the raw lane count"
        m = pool.metrics_snapshot()
        assert m["batcher"]["dispatches"] >= 1
        assert m["batcher"]["submits"] >= 1
        assert m["decode"]["programs"] >= 1
        assert m["decode"]["dispatches"] >= 1
        assert all(isinstance(k, str) for k in m["decode"]["batch_hist"])


def test_pool_no_batcher_mode(tmp_path, tiled_vol, full):
    """``batch_wait_ms=None`` (the CLI's ``--no-batcher``) must serve the
    same bytes with no batcher block in /metrics."""
    pool = VolumePool({"v": _gwtc_path(tmp_path, tiled_vol)},
                      cache_bytes=1 << 20, mem_budget=32 << 20,
                      batch_wait_ms=None)
    with pool:
        assert pool.batcher is None
        block, _ = pool.region("v", "0:12,:,4:20")
        np.testing.assert_array_equal(block, full[0:12, :, 4:20])
        assert "batcher" not in pool.metrics_snapshot()
