"""Group partitioning invariants (paper §3.3)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import grouping


@pytest.mark.parametrize("strategy", grouping.STRATEGIES)
@pytest.mark.parametrize("n_groups", [1, 5, 20])
def test_partition_complete_and_disjoint(nyx_small, strategy, n_groups):
    x = jnp.asarray(nyx_small)
    edges = grouping.compute_edges(x, n_groups, strategy)
    assert bool(jnp.all(jnp.diff(edges) > 0)), "edges must be strictly increasing"
    ids = grouping.assign_groups(x, edges)
    assert int(ids.min()) >= 0 and int(ids.max()) < n_groups
    masks = grouping.group_masks(ids, n_groups)
    # every element in exactly one group
    assert bool(jnp.all(masks.sum(axis=0) == 1))


def test_quantile_balances_mass(dm_small):
    x = jnp.asarray(dm_small)
    n = 8
    edges = grouping.compute_edges(x, n, "quantile")
    ids = grouping.assign_groups(x, edges)
    counts = np.asarray(grouping.group_stats(x, ids, n)["count"])
    # quantile grouping should be far more balanced than range grouping
    edges_r = grouping.compute_edges(x, n, "range")
    counts_r = np.asarray(grouping.group_stats(x, grouping.assign_groups(x, edges_r), n)["count"])
    assert counts.std() < counts_r.std()


def test_group_stats_minmax_within_edges(nyx_small):
    x = jnp.asarray(nyx_small)
    edges = grouping.compute_edges(x, 5, "quantile")
    ids = grouping.assign_groups(x, edges)
    st_ = grouping.group_stats(x, ids, 5)
    for g in range(5):
        if st_["count"][g] > 0:
            assert st_["min"][g] >= float(edges[0]) - 1e-3
            assert st_["max"][g] <= float(edges[-1]) + 1e-3


# hypothesis-based property tests live in test_grouping_properties.py so this
# module keeps running when hypothesis isn't installed
