import os
import sys

# Tests run on the single host device (the 512-device override belongs ONLY
# to launch/dryrun.py).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def nyx_small():
    from repro.data import nyx_like_field

    return nyx_like_field((32, 32, 32), "temperature", seed=7)


@pytest.fixture(scope="session")
def dm_small():
    from repro.data import nyx_like_field

    return nyx_like_field((32, 32, 32), "dark_matter_density", seed=3)
