"""Per-arch smoke tests: reduced config, one forward + one train step on CPU,
output shapes + finiteness; decode==prefill consistency for representative
archs (the serving-correctness contract)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, build_model, get_family
from repro.launch.steps import TrainOptions, make_train_step
from repro.optim import adamw


def _batch_for(arch, cfg, fam, B=2, S=16, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)).astype(np.int32)),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)).astype(np.int32)),
    }
    if cfg.attn is not None and cfg.attn.mrope_sections:
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(S)[None, None, :], (B, 3, S)
        ).astype(jnp.int32)
    if fam == "encdec":
        batch["enc_feats"] = jnp.asarray(
            rng.normal(size=(B, cfg.enc_seq, cfg.d_model)).astype(np.float32), cfg.compute_dtype
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch):
    model, cfg = build_model(arch, reduced=True)
    fam = get_family(arch)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch_for(arch, cfg, fam)

    step, adam_cfg = make_train_step(model, cfg, TrainOptions(lr=1e-3, warmup=1, total_steps=10))
    opt = adamw.init(params, adam_cfg)
    p2, opt2, m = jax.jit(step)(params, opt, batch, jax.random.PRNGKey(1))
    assert np.isfinite(float(m["loss"]))
    # params actually changed
    delta = sum(float(jnp.abs(a - b).sum()) for a, b in zip(
        jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(p2)))
    assert delta > 0
    # a second step must also be finite (optimizer state sane)
    _, _, m2 = jax.jit(step)(p2, opt2, batch, jax.random.PRNGKey(2))
    assert np.isfinite(float(m2["loss"]))


@pytest.mark.parametrize("arch", [
    "granite-3-8b",      # full attention + rope + tied embeddings
    "gemma3-1b",         # sliding-window ring cache + qk-norm
    "rwkv6-7b",          # recurrent state decode
    "zamba2-1.2b",       # mamba2 + shared attention block
    "deepseek-v3-671b",  # MLA absorbed decode + MoE
    "qwen2-vl-7b",       # M-RoPE decode positions
    "llama4-scout-17b-a16e",  # chunked-local ring + NoPE global + MoE top-1
])
def test_decode_matches_prefill(arch):
    model, cfg = build_model(arch, reduced=True)
    cfg = type(cfg)(**{**cfg.__dict__, "dtype": "fp32", "remat": False})
    model = type(model)(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    if cfg.attn is not None and cfg.attn.mrope_sections:
        pos = jnp.broadcast_to(jnp.arange(S)[None, None, :], (B, 3, S)).astype(jnp.int32)
    else:
        pos = jnp.arange(S)
    logits_full, _, _ = model.apply(params, toks, pos)
    cache = model.init_cache(B, S, dtype=jnp.float32)
    outs = []
    for t in range(S):
        lg, cache = model.decode_step(params, cache, toks[:, t : t + 1], jnp.asarray(t))
        outs.append(lg)
    logits_dec = jnp.stack(outs, axis=1)
    scale = float(jnp.max(jnp.abs(logits_full))) + 1e-9
    rel = float(jnp.max(jnp.abs(logits_full - logits_dec))) / scale
    assert rel < 2e-3, f"{arch}: decode/prefill mismatch rel={rel}"


def test_whisper_decode_matches_prefill():
    model, cfg = build_model("whisper-small", reduced=True)
    cfg = type(cfg)(**{**cfg.__dict__, "dtype": "fp32", "remat": False})
    from repro.models.encdec import EncDecLM

    model = EncDecLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 8
    feats = jax.random.normal(jax.random.PRNGKey(2), (B, cfg.enc_seq, cfg.d_model))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    enc_out = model.encode(params, feats)
    logits_full, _ = model.decode(params, enc_out, toks, jnp.arange(S))
    cache = model.init_cache(B, S, dtype=jnp.float32)
    outs = []
    for t in range(S):
        lg, cache = model.decode_step(params, cache, enc_out, toks[:, t : t + 1], jnp.asarray(t))
        outs.append(lg)
    logits_dec = jnp.stack(outs, axis=1)
    scale = float(jnp.max(jnp.abs(logits_full))) + 1e-9
    rel = float(jnp.max(jnp.abs(logits_full - logits_dec))) / scale
    assert rel < 2e-3, f"whisper decode/prefill mismatch rel={rel}"


def test_stage_grouping_compact():
    """Pattern grouping keeps HLO small: homogeneous stacks scan as ONE stage."""
    from repro.models.decoder import build_stages

    model, cfg = build_model("llama3-405b", reduced=False)
    assert len(model.stages) == 1 and model.stages[0].count == 126
    model, cfg = build_model("gemma3-1b", reduced=False)
    assert sum(st.count * len(st.specs) for st in model.stages) == 26
    model, cfg = build_model("deepseek-v3-671b", reduced=False)
    assert sum(st.count * len(st.specs) for st in model.stages) == 61
