"""Tiled engine (GWTC): tiled-vs-untiled parity, container round trip,
random-access region decode (structural: only intersecting lanes are
entropy-decoded), sharded dispatch, and the GWLZ tiled path."""
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.pipeline import GWLZ
from repro.core.trainer import GWLZTrainConfig
from repro.data import nyx_like_field
from repro.sz import SZCompressor, tiled

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


@pytest.fixture(scope="module")
def vol():
    return jnp.asarray(nyx_like_field((20, 33, 17), "temperature", seed=2))


# -- parity vs the untiled path ------------------------------------------------


def test_tiled_recon_matches_untiled_lorenzo(vol):
    """The Lorenzo transform is lossless, so tiling changes the codes but not
    the reconstruction: tiled recon == untiled lorenzo recon bit-for-bit."""
    c = SZCompressor(predictor="lorenzo")
    art_t, recon_t = c.compress_tiled(vol, (8, 16, 8), rel_eb=1e-3)
    art_u, recon_u = c.compress(vol, abs_eb=art_t.eb_abs)
    np.testing.assert_array_equal(np.asarray(recon_t), np.asarray(recon_u))
    assert float(jnp.max(jnp.abs(recon_t - vol))) <= art_t.eb_abs * (1 + 1e-6)
    # and both decompress to the same volume
    full_t = c.decompress_tiled(art_t)
    full_u = c.decompress(art_u)
    np.testing.assert_array_equal(np.asarray(full_t), np.asarray(full_u))


def test_tiled_codes_bitexact_off_carry_planes(vol):
    """Quant codes agree exactly wherever the Lorenzo stencil does not cross
    a tile boundary (the cut prediction carry only touches the planes at
    multiples of the tile pitch)."""
    tile = (8, 16, 8)
    from repro.kernels import ref

    c = SZCompressor(predictor="lorenzo")
    art_t, _ = c.compress_tiled(vol, tile, rel_eb=1e-3)
    eb = art_t.eb_abs
    from repro.sz.entropy import decode_codes

    codes_t = np.stack([decode_codes(b, tile) for b in art_t.tile_blobs])
    stitched = np.asarray(tiled.stitch_tiles(jnp.asarray(codes_t), art_t.grid))
    cropped = stitched[tuple(slice(0, d) for d in vol.shape)]
    codes_u = np.asarray(ref.lorenzo_quant_ref(vol, eb))
    interior = np.ones(vol.shape, bool)
    for ax, t in enumerate(tile):
        coord = np.arange(vol.shape[ax])
        on_carry = (coord % t == 0) & (coord > 0)
        sl = [None] * vol.ndim
        sl[ax] = slice(None)
        interior &= ~on_carry[tuple(sl)]
    assert interior.any() and not interior.all()
    np.testing.assert_array_equal(cropped[interior], codes_u[interior])


@pytest.mark.parametrize("backend", ["zlib", "huffman", "huffman+zlib"])
@pytest.mark.parametrize("pred", ["lorenzo", "interp"])
def test_container_roundtrip_all_backends(vol, backend, pred):
    art, recon = tiled.compress_tiled(vol, (8, 16, 8), rel_eb=1e-3,
                                      backend=backend, predictor=pred)
    art.extras["meta"] = b"\x01\x02"
    art2 = tiled.TiledCompressed.from_bytes(art.to_bytes())
    assert art2.shape == art.shape and art2.tile == art.tile
    assert art2.backend == backend and art2.extras == {"meta": b"\x01\x02"}
    assert art2.eb_abs == art.eb_abs
    assert (art2.predictor, art2.order, art2.levels) == \
        (pred, art.order, art.levels)
    out = tiled.decompress_tiled(art2)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(recon))


# -- predictor-pluggable tiled path --------------------------------------------


def test_tiled_interp_roundtrip_error_bounded(vol):
    """compress_tiled(predictor="interp") holds the bound end to end through
    the container byte round trip."""
    for order in ("linear", "cubic"):
        art, recon = tiled.compress_tiled(vol, (8, 16, 8), rel_eb=1e-3,
                                          predictor="interp", order=order)
        assert art.predictor == "interp" and art.levels >= 1
        full = tiled.decompress_tiled(tiled.TiledCompressed.from_bytes(art.to_bytes()))
        assert float(jnp.max(jnp.abs(full - vol))) <= art.eb_abs * (1 + 1e-6)
        np.testing.assert_array_equal(np.asarray(full), np.asarray(recon))


def test_tiled_interp_region_matches_full_crop(vol):
    """Interp tiles are independent prediction domains: a region decode
    (different batch size through the vmapped decode) must reproduce the full
    decode's crop bit-for-bit."""
    art, _ = tiled.compress_tiled(vol, (8, 16, 8), rel_eb=1e-3, predictor="interp")
    full = np.asarray(tiled.decompress_tiled(art))
    for roi in [(slice(0, 8), slice(16, 32), slice(8, 16)),
                (slice(3, 19), slice(2, 33), slice(4, 13))]:
        reg = tiled.decompress_region(art, roi)
        np.testing.assert_array_equal(np.asarray(reg), full[roi])


def test_tiled_interp_beats_lorenzo_ratio(nyx_small):
    """The point of the predictor layer: tiled interp should compress a
    smooth field tighter than tiled Lorenzo (the SZ3-lineage advantage the
    tiled path previously gave up).  Needs production-ish tile sizes — at
    tiny tiles the interp padded-grid overhead (+~20% symbols) dominates."""
    x = jnp.asarray(nyx_small)
    art_l, _ = tiled.compress_tiled(x, (16, 16, 16), rel_eb=1e-3, predictor="lorenzo")
    art_i, _ = tiled.compress_tiled(x, (16, 16, 16), rel_eb=1e-3, predictor="interp")
    assert art_i.nbytes < art_l.nbytes


def test_unknown_predictor_rejected(vol):
    with pytest.raises(ValueError, match="unknown predictor"):
        tiled.compress_tiled(vol, (8, 16, 8), rel_eb=1e-3, predictor="nope")


def test_szcompressor_routes_predictor(vol):
    """SZCompressor.compress_tiled honors self.predictor (unified stack) and
    the per-call override."""
    art, _ = SZCompressor(predictor="interp").compress_tiled(vol, (8, 16, 8), rel_eb=1e-3)
    assert art.predictor == "interp"
    art, _ = SZCompressor(predictor="interp").compress_tiled(
        vol, (8, 16, 8), rel_eb=1e-3, predictor="lorenzo")
    assert art.predictor == "lorenzo"


def test_decode_lanes_returns_lane_count(vol):
    art, _ = tiled.compress_tiled(vol, (8, 16, 8), rel_eb=1e-3)
    recon, lanes = tiled.decode_lanes(art, [0, 5, 7])
    assert lanes == 3 and recon.shape == (3, 8, 16, 8)


@pytest.mark.parametrize("shape,tile", [((100,), (32,)), ((40, 52), (16, 24))])
def test_tiled_low_rank_volumes(shape, tile):
    rng = np.random.default_rng(0)
    x = jnp.asarray((rng.normal(size=shape) * 10).astype(np.float32))
    art, recon = tiled.compress_tiled(x, tile, abs_eb=0.01)
    full = tiled.decompress_tiled(tiled.TiledCompressed.from_bytes(art.to_bytes()))
    assert float(jnp.max(jnp.abs(full - x))) <= 0.01 * (1 + 1e-6)
    np.testing.assert_array_equal(np.asarray(full), np.asarray(recon))


def test_decode_workers_param(vol):
    art, _ = tiled.compress_tiled(vol, (8, 8, 8), rel_eb=1e-3)
    serial = tiled.decompress_tiled(art, workers=1)
    threaded = tiled.decompress_tiled(art, workers=4)
    np.testing.assert_array_equal(np.asarray(serial), np.asarray(threaded))


def test_roi_validation(vol):
    art, _ = tiled.compress_tiled(vol, (8, 16, 8), rel_eb=1e-3)
    with pytest.raises(ValueError):
        tiled.decompress_region(art, (slice(0, 5), slice(0, 5)))  # rank mismatch
    with pytest.raises(ValueError):
        tiled.decompress_region(art, (slice(5, 5), slice(0, 5), slice(0, 5)))
    with pytest.raises(ValueError):
        tiled.decompress_region(art, (slice(0, 5, 2), slice(0, 5), slice(0, 5)))


# -- random-access decode ------------------------------------------------------


def test_region_decode_touches_only_intersecting_lanes(vol, monkeypatch):
    """decompress_region must entropy-decode ONLY the intersecting tiles —
    counted at the decode_codes call site, not inferred from timings."""
    import repro.sz.entropy as entropy

    art, _ = tiled.compress_tiled(vol, (8, 16, 8), rel_eb=1e-3)  # 3x3x3 grid
    calls = []
    orig = entropy.decode_codes

    def counting(blob, shape, **kw):
        calls.append(int(np.prod(shape)))
        return orig(blob, shape, **kw)

    monkeypatch.setattr(entropy, "decode_codes", counting)
    full = tiled.decompress_tiled(art)
    assert len(calls) == art.n_tiles
    calls.clear()
    roi = (slice(0, 8), slice(16, 32), slice(8, 16))  # exactly one tile
    reg = tiled.decompress_region(art, roi)
    assert len(calls) == 1 and sum(calls) == int(np.prod(art.tile))
    assert tiled.DECODE_STATS == {"tiles_decoded": 1, "tiles_total": 27}
    np.testing.assert_array_equal(np.asarray(reg), np.asarray(full)[roi])


@pytest.mark.slow
def test_single_tile_region_decode_128cube():
    """Acceptance: one tile of a 128^3 volume decodes without the full-volume
    entropy decode (1 of 8 lanes; 64^3 of 128^3 symbols touched)."""
    import repro.sz.entropy as entropy

    x = jnp.asarray(nyx_like_field((128, 128, 128), "temperature", seed=11))
    art, _ = tiled.compress_tiled(x, (64, 64, 64), rel_eb=1e-3)
    assert art.n_tiles == 8

    counted = {"symbols": 0, "lanes": 0}
    orig = entropy.decode_codes

    def counting(blob, shape, **kw):
        counted["symbols"] += int(np.prod(shape))
        counted["lanes"] += 1
        return orig(blob, shape, **kw)

    entropy.decode_codes, prev = counting, entropy.decode_codes
    try:
        reg = tiled.decompress_region(art, (slice(64, 128), slice(0, 64), slice(64, 128)))
    finally:
        entropy.decode_codes = prev
    assert counted == {"symbols": 64**3, "lanes": 1}  # not 128^3, not 8 lanes
    assert reg.shape == (64, 64, 64)
    assert float(jnp.max(jnp.abs(reg - x[64:128, 0:64, 64:128]))) <= art.eb_abs * (1 + 1e-6)


@pytest.mark.slow
def test_sharded_dispatch_multi_device_parity(vol):
    """Artifact bytes and reconstruction must not depend on the device count:
    re-run compress on 4 forced host devices and compare."""
    art, recon = tiled.compress_tiled(vol, (8, 16, 8), rel_eb=1e-3)
    code = (
        "import numpy as np, jax, jax.numpy as jnp\n"
        "assert len(jax.devices()) == 4\n"
        "from repro.data import nyx_like_field\n"
        "from repro.sz import tiled\n"
        "x = jnp.asarray(nyx_like_field((20, 33, 17), 'temperature', seed=2))\n"
        "art, recon = tiled.compress_tiled(x, (8, 16, 8), rel_eb=1e-3)\n"
        "full = tiled.decompress_tiled(art)\n"
        "np.testing.assert_array_equal(np.asarray(full), np.asarray(recon))\n"
        "import sys; sys.stdout.buffer.write(art.to_bytes())\n"
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(ROOT, "src"), env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=4").strip()
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, timeout=300)
    assert proc.returncode == 0, proc.stderr.decode()
    assert proc.stdout == art.to_bytes()


# -- GWLZ over the tile grid ---------------------------------------------------


def test_gwlz_tiled_roundtrip_and_region(vol):
    gw = GWLZ(train_cfg=GWLZTrainConfig(n_groups=4, epochs=3, batch_size=8,
                                        min_group_pixels=64))
    art, stats = gw.compress_tiled(vol, (8, 16, 8), rel_eb=1e-3)
    assert "gwlz" in art.extras and stats.n_model_params > 0
    assert stats.max_err_sz <= stats.eb_abs * (1 + 1e-6)
    art2 = tiled.TiledCompressed.from_bytes(art.to_bytes())
    full = gw.decompress_tiled(art2)
    assert full.shape == vol.shape
    roi = (slice(2, 18), slice(5, 30), (0, 9))
    reg = gw.decompress_region(art2, roi)
    np.testing.assert_array_equal(
        np.asarray(reg), np.asarray(full)[2:18, 5:30, 0:9])


@pytest.mark.parametrize("pred", ["lorenzo", "interp"])
def test_gwlz_tiled_region_bitexact_both_predictors(vol, pred):
    """The enhanced region decode equals the enhanced full decode's crop for
    every registered predictor (the tile_transform contract)."""
    gw = GWLZ(train_cfg=GWLZTrainConfig(n_groups=4, epochs=2, batch_size=8,
                                        min_group_pixels=64))
    art, _ = gw.compress_tiled(vol, (8, 16, 8), rel_eb=1e-3, predictor=pred)
    assert art.predictor == pred
    full = np.asarray(gw.decompress_tiled(art))
    roi = (slice(1, 17), slice(9, 31), slice(2, 14))
    np.testing.assert_array_equal(
        np.asarray(gw.decompress_region(art, roi)), full[roi])


def test_batched_tile_enhancement_bitexact_vs_loop(vol):
    """The lax.map batched enhancer must reproduce the per-tile Python loop
    bit-for-bit (with and without bound clamping) — it replaces that loop on
    the decode hot path."""
    from repro.core.pipeline import deserialize_model
    from repro.core.trainer import enhance_tiles, enhance_tiles_looped

    gw = GWLZ(train_cfg=GWLZTrainConfig(n_groups=4, epochs=2, batch_size=8,
                                        min_group_pixels=64))
    art, _ = gw.compress_tiled(vol, (8, 16, 8), rel_eb=1e-3)
    model = deserialize_model(art.extras["gwlz"])
    recon_tiles, lanes = tiled.decode_lanes(art, range(art.n_tiles))
    assert lanes == art.n_tiles
    for clamp in (None, art.eb_abs):
        batched = enhance_tiles(recon_tiles, model, clamp_eb=clamp)
        looped = enhance_tiles_looped(recon_tiles, model, clamp_eb=clamp)
        np.testing.assert_array_equal(np.asarray(batched), np.asarray(looped))


def test_gwlz_tiled_enhancement_improves_or_gates(vol):
    """With a real training budget the enhancer must help (or gate itself off
    to identity) — never hurt the tiled reconstruction."""
    gw = GWLZ(train_cfg=GWLZTrainConfig(n_groups=4, epochs=25, batch_size=8,
                                        min_group_pixels=64))
    _, stats = gw.compress_tiled(vol, (8, 16, 8), rel_eb=1e-3)
    assert stats.psnr_gwlz >= stats.psnr_sz - 1e-6


# -- bucketed dispatch + compile-cache accounting (ISSUE 10) -------------------


def test_bucket_helpers():
    assert tiled.bucket_for(1) == 1
    assert tiled.bucket_for(3) == 4
    assert tiled.bucket_for(32) == 32
    assert tiled.bucket_for(5, bucket_cap=4) == 4
    assert tiled.bucket_chunks(70, 32) == [32, 32, 8]
    assert tiled.bucket_chunks(5, 4) == [4, 1]
    assert tiled.bucket_chunks(7, 4) == [4, 4]
    assert tiled.bucket_chunks(70, 0) == [70]  # cap<=0 disables bucketing
    assert tiled.bucket_chunks(0) == []


def test_bucketed_decode_accounting(vol):
    """Dispatch/program counters must reflect the bucket plan exactly: 7
    lanes under cap 4 is two width-4 dispatches with one padded row, and the
    bucketed bytes equal the unbucketed ones."""
    art, _ = tiled.compress_tiled(
        vol, (8, 16, 8), abs_eb=float(jnp.max(vol) - jnp.min(vol)) * 1e-3)
    before = tiled.dispatch_stats()
    plain, _ = tiled.decode_lanes(art, range(7), bucket_cap=0)
    mid = tiled.dispatch_stats()
    # the unpadded call is still one counted device dispatch (width 7)
    assert mid["dispatches"] - before["dispatches"] == 1
    bucketed, _ = tiled.decode_lanes(art, range(7), bucket_cap=4)
    after = tiled.dispatch_stats()
    assert after["dispatches"] - mid["dispatches"] == 2  # chunks [4, 4]
    assert after["padded_tiles"] - mid["padded_tiles"] == 1  # 7 -> 4 + pad(3->4)
    assert after["batch_hist"].get(4, 0) - mid["batch_hist"].get(4, 0) == 2
    np.testing.assert_array_equal(np.asarray(bucketed), np.asarray(plain))


def test_register_program_key_counts_once():
    import random

    key = ("test-program", random.getrandbits(64))
    before = tiled.dispatch_stats()["programs"]
    assert tiled.register_program_key(key) is True, "first sighting compiles"
    assert tiled.register_program_key(key) is False, "re-registration is warm"
    assert tiled.dispatch_stats()["programs"] - before == 1


def test_quarantine_many_bad_lanes(vol):
    """The quarantine mask must be built in linear time and stay correct
    when MOST lanes are bad (the mask build used to rebuild ``set(good)``
    per lane, quadratic in the lane count) — every tampered lane decodes to
    the fill value, every healthy one to its clean bytes."""
    art, _ = tiled.compress_tiled(
        vol, (8, 16, 8), abs_eb=float(jnp.max(vol) - jnp.min(vol)) * 1e-3)
    clean = np.asarray(tiled.decode_lanes(art, range(art.n_tiles))[0])
    a = tiled.TiledCompressed.from_bytes(art.to_bytes())
    assert a.lane_crcs is not None
    keep = {3, 11, 20}
    a.lane_crcs = a.lane_crcs.copy()
    for i in range(a.n_tiles):
        if i not in keep:
            a.lane_crcs[i] ^= 0xBEEF
    a.verify, a.on_corrupt, a.fill_value = "lazy", "quarantine", -5.0
    recon, lanes, bad = tiled.decode_lanes(a, range(a.n_tiles),
                                           with_mask=True)
    assert lanes == len(keep)
    r = np.asarray(recon)
    for i in range(a.n_tiles):
        if i in keep:
            assert not bad[i]
            np.testing.assert_array_equal(r[i], clean[i])
        else:
            assert bad[i] and np.all(r[i] == -5.0)
    assert len(a.quarantined) == a.n_tiles - len(keep)
