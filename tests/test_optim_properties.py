"""Hypothesis property tests for the optimizer stack (split from
test_optim.py so that module still runs when hypothesis isn't installed)."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.optim.grad_compress import GradCompressConfig, quantize_leaf


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**30), st.sampled_from(["int8", "int16"]))
def test_ef_residual_bounded_property(seed, dtype):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=64).astype(np.float32))
    cfg = GradCompressConfig(rel_eb=0.1, code_dtype=dtype)
    codes, scale, new_err = quantize_leaf(g, jnp.zeros(64), cfg)
    bound = 127 if dtype == "int8" else 32767
    assert int(jnp.max(jnp.abs(codes.astype(jnp.int32)))) <= bound
    # EF residual == true quantization error
    ghat = codes.astype(jnp.float32) * scale
    np.testing.assert_allclose(np.asarray(new_err), np.asarray(g - ghat), atol=1e-6)
