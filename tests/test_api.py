"""The `repro.api` façade: one front door over both containers.

Covers the PR's acceptance surface: save -> open -> slice round trips for
monolithic, tiled (both predictors), and multi-field GWDS envelopes;
self-sniffing `api.open` on the pre-existing golden byte streams; lazy
slicing semantics (tiled slices decode only intersecting lanes and equal
the full decode's crop bit-for-bit); and the CLI smoke path in-process."""
import os

import numpy as np
import pytest

from repro import api, cli
from repro.core import GWLZ, GWLZTrainConfig
from repro.sz import artifact as A
from repro.sz import tiled
from repro.sz.szjax import SZCompressed, SZCompressor
from repro.sz.tiled import TiledCompressed

GOLDEN = os.path.join(os.path.dirname(__file__), "golden")


@pytest.fixture(scope="module")
def volume():
    return np.load(os.path.join(GOLDEN, "volume_12_20_9.npy"))


# ---------------------------------------------------------------------------
# handle semantics
# ---------------------------------------------------------------------------


def test_handle_metadata_and_protocol(volume):
    vol = api.compress(volume, abs_eb=1e-2)
    assert (vol.shape, vol.dtype, vol.ndim) == ((12, 20, 9), np.float32, 3)
    assert not vol.tiled and not vol.enhanced
    assert vol.nbytes == len(vol.to_bytes())
    assert vol.size_report()["total"] == vol.nbytes
    # both containers satisfy the common Artifact protocol
    assert isinstance(vol.artifact, A.Artifact)
    tv = api.compress(volume, abs_eb=1e-2, tiled=True, tile=(8, 8, 8))
    assert tv.tiled and isinstance(tv.artifact, A.Artifact)
    assert A.container_magics()[b"SZJX"] is SZCompressed
    assert A.container_magics()[b"GWTC"] is TiledCompressed


def test_monolithic_slicing_is_crop_after_decode(volume):
    vol = api.compress(volume, abs_eb=1e-2, predictor="interp")
    full = np.asarray(vol)
    assert full.shape == (12, 20, 9)
    assert np.max(np.abs(full - volume)) <= vol.eb_abs * (1 + 1e-6)
    # decode is cached once: slicing returns views of the same base buffer
    np.testing.assert_array_equal(vol[2:9, :, 3], full[2:9, :, 3])
    np.testing.assert_array_equal(vol[3], full[3])
    np.testing.assert_array_equal(vol[..., 1:7], full[..., 1:7])
    np.testing.assert_array_equal(vol[1:11:3, -2, ::2], full[1:11:3, -2, ::2])
    assert vol.decode() is vol.decode()
    # the cache is handed out directly, so it must be immutable ...
    assert not full.flags.writeable
    with pytest.raises(ValueError):
        full[0, 0, 0] = 1.0
    assert np.asarray(vol, dtype=np.float64).flags.writeable  # conversions copy
    # ... but slices are writable on BOTH containers (tiled ones are fresh
    # decodes, so monolithic crops copy out of the cache)
    assert vol[2:5].flags.writeable


def test_slicing_edge_cases(volume):
    vol = api.compress(volume, abs_eb=1e-2)
    full = np.asarray(vol)
    assert vol[5:5].shape == (0, 20, 9)
    np.testing.assert_array_equal(vol[-3:], full[-3:])
    with pytest.raises(IndexError):
        vol[0, 0, 0, 0]
    with pytest.raises(IndexError):
        vol[99]
    with pytest.raises(IndexError):
        vol[::-1]
    with pytest.raises(IndexError):
        vol[[1, 2]]


@pytest.mark.parametrize("pred", ["lorenzo", "interp"])
def test_tiled_slice_decodes_only_intersecting_lanes(volume, pred):
    """Acceptance: api.open(path)[roi] touches only intersecting lanes and is
    bit-identical to the same ROI cropped from np.asarray(vol)."""
    vol = api.compress(volume, abs_eb=1e-2, tiled=True, tile=(8, 8, 8),
                       predictor=pred)
    roi = (slice(2, 9), slice(8, 20), slice(0, 5))
    block = vol[roi]
    # grid is (2, 3, 2); the roi spans 2 x 2 x 1 of the 12 tiles
    assert (tiled.DECODE_STATS["tiles_decoded"], tiled.DECODE_STATS["tiles_total"]) == (4, 12)
    assert api.region_lane_count(vol, roi) == (4, 12)
    full = np.asarray(vol)
    np.testing.assert_array_equal(block, full[roi])
    # slicing stays a partial read even after the full decode warmed the cache
    vol[roi]
    assert tiled.DECODE_STATS["tiles_decoded"] == 4
    # int + stepped indexing rides the same region path
    np.testing.assert_array_equal(vol[3, 9:17:2, 1:8:3], full[3, 9:17:2, 1:8:3])


# ---------------------------------------------------------------------------
# persistence round trips
# ---------------------------------------------------------------------------


def test_monolithic_save_open_slice(tmp_path, volume):
    vol = api.compress(volume, abs_eb=1e-2)
    path = tmp_path / "mono.szjx"
    written = api.save(path, vol)
    assert written == os.path.getsize(path) == vol.nbytes
    vol2 = api.open(path)
    assert not vol2.tiled and vol2.shape == vol.shape
    np.testing.assert_array_equal(np.asarray(vol2), np.asarray(vol))
    np.testing.assert_array_equal(vol2[4:9, 2:5, :], np.asarray(vol)[4:9, 2:5, :])


@pytest.mark.parametrize("pred", ["lorenzo", "interp"])
def test_tiled_save_open_slice(tmp_path, volume, pred):
    vol = api.compress(volume, abs_eb=1e-2, tiled=True, tile=(8, 8, 8),
                       predictor=pred)
    path = tmp_path / f"tiled_{pred}.gwtc"
    assert api.save(path, vol) == os.path.getsize(path) == vol.nbytes
    vol2 = api.open(path)
    assert vol2.tiled and vol2.artifact.predictor == pred
    roi = (slice(0, 8), slice(10, 20), slice(1, 9))
    np.testing.assert_array_equal(vol2[roi], np.asarray(vol)[roi])


def test_enhanced_tiled_roundtrip_applies_enhancer_per_tile(tmp_path, volume):
    # normalize to O(1) so enhancement deltas are representable in f32
    x = volume / np.float32(np.abs(volume).max())
    cfg = GWLZTrainConfig(n_groups=2, epochs=4, batch_size=4, min_group_pixels=16)
    vol = api.compress(x, abs_eb=1e-3, tiled=True, tile=(8, 8, 8),
                       enhance=cfg, predictor="lorenzo")
    assert vol.enhanced and vol.stats is not None
    path = tmp_path / "enh.gwtc"
    api.save(path, vol)
    vol2 = api.open(path)
    assert vol2.enhanced, "enhancer model must survive the round trip"
    full = np.asarray(vol2)
    roi = (slice(2, 9), slice(8, 20), slice(0, 5))
    np.testing.assert_array_equal(vol2[roi], full[roi])
    # the decode really is the enhanced one, not the raw SZ recon
    raw = np.asarray(SZCompressor().decompress_tiled(vol2.artifact))
    assert not np.array_equal(full, raw)


def test_enhanced_monolithic_roundtrip(tmp_path, volume):
    cfg = GWLZTrainConfig(n_groups=2, epochs=2, batch_size=4, min_group_pixels=16)
    vol = api.compress(volume, abs_eb=1e-2, enhance=cfg)
    path = tmp_path / "enh.szjx"
    api.save(path, vol)
    vol2 = api.open(path)
    assert vol2.enhanced
    np.testing.assert_array_equal(np.asarray(vol2), np.asarray(vol))
    np.testing.assert_array_equal(
        np.asarray(vol2), np.asarray(GWLZ().decompress(vol.artifact)))


def test_gwds_multifield_roundtrip(tmp_path, volume):
    mono = api.compress(volume, abs_eb=1e-2)
    til = api.compress(volume, abs_eb=2e-2, tiled=True, tile=(8, 8, 8))
    path = tmp_path / "snap.gwds"
    written = api.save(path, {"temperature": mono, "baryon_density": til})
    assert written == os.path.getsize(path)
    ds = api.open(path)
    assert isinstance(ds, api.Dataset)
    assert ds.fields == ("temperature", "baryon_density") and len(ds) == 2
    assert set(ds.keys()) == {"temperature", "baryon_density"}
    np.testing.assert_array_equal(np.asarray(ds["temperature"]), np.asarray(mono))
    assert ds["baryon_density"].tiled
    np.testing.assert_array_equal(
        ds["baryon_density"][0:8, 2:11, :], np.asarray(til)[0:8, 2:11, :])
    rep = ds.size_report()
    assert rep["total"] == ds.nbytes == written
    assert rep["fields"]["temperature"] == mono.nbytes
    with pytest.raises(KeyError):
        ds["nope"]
    # a Dataset itself re-saves verbatim
    assert api.save(tmp_path / "snap2.gwds", ds) == written


def test_gwds_rejects_empty_and_bad_saves(tmp_path):
    with pytest.raises(ValueError):
        api.Dataset.build({})
    with pytest.raises(TypeError):
        api.save(tmp_path / "x", object())
    # uncompressed arrays inside a mapping get the friendly TypeError too
    with pytest.raises(TypeError, match="compress it first"):
        api.save(tmp_path / "x", {"temperature": np.zeros((4, 4, 4))})


def test_gwds_truncated_blob_raises_valueerror(tmp_path, volume):
    vol = api.compress(volume, abs_eb=1e-2)
    path = tmp_path / "snap.gwds"
    api.save(path, {"t": vol})
    blob = path.read_bytes()
    for cut in (6, 20, len(blob) - 50):  # mid-header, mid-index, mid-payload
        with pytest.raises(ValueError):
            api.from_bytes(blob[:cut])


def test_open_rejects_unknown_magic(tmp_path):
    path = tmp_path / "junk.bin"
    path.write_bytes(b"NOPE" + b"\x00" * 32)
    with pytest.raises(ValueError, match="unknown container magic"):
        api.open(path)


# ---------------------------------------------------------------------------
# golden byte streams keep opening through the façade
# ---------------------------------------------------------------------------


def test_open_golden_gwtc_v1():
    vol = api.open(os.path.join(GOLDEN, "gwtc_v1.bin"))
    assert vol.tiled and vol.artifact.predictor == "lorenzo"
    np.testing.assert_array_equal(
        np.asarray(vol), np.load(os.path.join(GOLDEN, "gwtc_v1_decode.npy")))


@pytest.mark.parametrize("pred", ["lorenzo", "interp"])
def test_open_golden_szjx(pred):
    vol = api.open(os.path.join(GOLDEN, f"szjx_{pred}.bin"))
    assert not vol.tiled and vol.artifact.predictor == pred
    np.testing.assert_array_equal(
        np.asarray(vol), np.load(os.path.join(GOLDEN, f"szjx_{pred}_decode.npy")))


# ---------------------------------------------------------------------------
# shims: the historical per-container GWLZ surface still works
# ---------------------------------------------------------------------------


def test_gwlz_decode_unifies_both_containers(volume):
    gw = GWLZ()
    art, _ = SZCompressor().compress(volume, abs_eb=1e-2)
    full = np.asarray(gw.decode(art))
    np.testing.assert_array_equal(np.asarray(gw.decompress(art)), full)
    roi = (slice(1, 7), slice(0, 9), slice(2, 8))
    np.testing.assert_array_equal(np.asarray(gw.decode(art, roi)), full[roi])

    tart, _ = SZCompressor().compress_tiled(volume, (8, 8, 8), abs_eb=1e-2)
    tfull = np.asarray(gw.decode(tart))
    np.testing.assert_array_equal(np.asarray(gw.decompress_tiled(tart)), tfull)
    np.testing.assert_array_equal(
        np.asarray(gw.decompress_region(tart, roi)), tfull[roi])


def test_compress_volume_matches_shim(volume):
    cfg = GWLZTrainConfig(n_groups=2, epochs=2, batch_size=4, min_group_pixels=16)
    vol = GWLZ(train_cfg=cfg).compress_volume(volume, abs_eb=1e-2)
    assert isinstance(vol, api.CompressedVolume) and vol.stats is not None
    assert vol.enhanced and vol.stats.eb_abs == vol.eb_abs


# ---------------------------------------------------------------------------
# CLI (in-process; CI runs the same flow as a subprocess smoke step)
# ---------------------------------------------------------------------------


def test_cli_roundtrip(tmp_path, volume):
    src = tmp_path / "x.npy"
    np.save(src, volume)
    out = tmp_path / "x.gwtc"
    assert cli.main(["compress", str(src), str(out), "--eb", "1e-3",
                     "--tiled", "--tile", "8"]) == 0
    assert cli.main(["info", str(out)]) == 0
    roi_npy = tmp_path / "roi.npy"
    assert cli.main(["region", str(out), "--roi", "2:9,8:20,0:5",
                     "--out", str(roi_npy)]) == 0
    full_npy = tmp_path / "full.npy"
    assert cli.main(["decompress", str(out), str(full_npy)]) == 0
    full = np.load(full_npy)
    np.testing.assert_array_equal(np.load(roi_npy), full[2:9, 8:20, 0:5])
    eb_abs = api.open(out).eb_abs
    assert np.max(np.abs(full - volume)) <= eb_abs * (1 + 1e-6)


def test_cli_synthetic_and_parse_roi(tmp_path):
    out = tmp_path / "s.szjx"
    assert cli.main(["compress", "synthetic:temperature:12", str(out),
                     "--eb", "1e-3"]) == 0
    assert cli.main(["info", str(out)]) == 0
    # region accepts everything vol[roi] accepts: steps, ints, partial rank
    assert cli.main(["region", str(out), "--roi", "0:8:2,3,:"]) == 0
    assert cli.main(["region", str(out), "--roi", "0:4"]) == 0
    assert cli.main(["region", str(out), "--roi", "2:2,:,:"]) == 0  # empty roi
    # bad ROIs exit cleanly instead of spilling tracebacks
    for bad in ("a:b", "0:8:-1,:,:", "99", "1,2,3,4"):
        with pytest.raises(SystemExit):
            cli.main(["region", str(out), "--roi", bad])


def test_cli_gwds_field_selection(tmp_path, volume, capsys):
    a = api.compress(volume, abs_eb=1e-2)
    path = tmp_path / "snap.gwds"
    api.save(path, {"t": a, "rho": a})
    out = tmp_path / "t.npy"
    assert cli.main(["decompress", str(path), str(out), "--field", "t"]) == 0
    np.testing.assert_array_equal(np.load(out), np.asarray(a))
    # usage errors print to stderr and exit 2 (the normalized CLI contract)
    with pytest.raises(SystemExit) as ei:
        cli.main(["decompress", str(path), str(out)])
    assert ei.value.code == 2
    assert "pick one with --field" in capsys.readouterr().err
    with pytest.raises(SystemExit) as ei:
        cli.main(["decompress", str(path), str(out), "--field", "nope"])
    assert ei.value.code == 2
    assert "no field" in capsys.readouterr().err
    with pytest.raises(SystemExit) as ei:
        out2 = tmp_path / "m.szjx"
        api.save(out2, a)
        cli.main(["decompress", str(out2), str(out), "--field", "t"])
    assert ei.value.code == 2
    assert "--field only applies" in capsys.readouterr().err
    assert cli.parse_roi("8:40,:,16:32") == (slice(8, 40), slice(None), slice(16, 32))
    assert cli.parse_roi("3,::2") == (3, slice(None, None, 2))
    with pytest.raises(ValueError):
        cli.parse_roi("1:2:3:4")
