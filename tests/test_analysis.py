"""Static-analysis suite tests (docs/ANALYSIS.md).

One violating + one clean fixture per rule RA001..RA005, the suppression /
RA000 engine contract, and the CLI integration: ``python -m repro.cli lint``
must exit 0 on this repo's own tree, 1 with a structured JSON report on a
tree with an injected violation, and 2 on usage errors.
"""
import json
import os
import subprocess
import sys
import textwrap
import time

import pytest

from repro.analysis import analyze_source, run_analysis

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _src(s: str) -> str:
    return textwrap.dedent(s).lstrip("\n")


def _rules(findings) -> list:
    return [f.rule for f in findings]


# ---------------------------------------------------------------------------
# RA001 lock discipline
# ---------------------------------------------------------------------------

RA001_BAD = _src("""
    import threading

    class Cache:
        def __init__(self):
            self._lock = threading.Lock()
            self._hits = 0  # guarded-by: _lock

        def bump(self):
            self._hits += 1
""")

RA001_CLEAN = _src("""
    import threading

    class Cache:
        def __init__(self):
            self._lock = threading.Lock()
            self._hits = 0  # guarded-by: _lock

        def bump(self):
            with self._lock:
                self._hits += 1
""")


def test_ra001_unlocked_mutation_flagged():
    findings = analyze_source(RA001_BAD, rules=["RA001"])
    assert _rules(findings) == ["RA001"]
    assert findings[0].line == 9
    assert "_hits" in findings[0].message and "_lock" in findings[0].message


def test_ra001_locked_mutation_clean():
    assert analyze_source(RA001_CLEAN, rules=["RA001"]) == []


def test_ra001_guarded_dict_registry():
    src = _src("""
        class Pool:
            GUARDED = {"items": "_lock"}

            def __init__(self, lock):
                self._lock = lock
                self.items = []

            def add(self, x):
                self.items.append(x)
    """)
    findings = analyze_source(src, rules=["RA001"])
    assert _rules(findings) == ["RA001"]
    assert "items" in findings[0].message


def test_ra001_init_and_wrong_lock():
    # __init__ writes are exempt; a mutation under the WRONG lock still fires
    src = _src("""
        class C:
            def __init__(self):
                self._lock = object()
                self._other = object()
                self.n = 0  # guarded-by: _lock
                self.n = 1

            def bump(self):
                with self._other:
                    self.n += 1
    """)
    findings = analyze_source(src, rules=["RA001"])
    assert len(findings) == 1 and findings[0].line == 10


def test_ra001_mutating_method_and_subscript():
    src = _src("""
        class C:
            def __init__(self):
                self._lock = object()
                self._d = {}  # guarded-by: _lock

            def put(self, k, v):
                self._d[k] = v

            def drop(self, k):
                self._d.pop(k)
    """)
    findings = analyze_source(src, rules=["RA001"])
    assert _rules(findings) == ["RA001", "RA001"]


# ---------------------------------------------------------------------------
# RA002 tracer safety
# ---------------------------------------------------------------------------

RA002_BAD = _src("""
    import jax
    import numpy as np

    @jax.jit
    def step(x, n):
        if x:
            x = x + 1
        y = np.sum(x)
        print(y)
        return y
""")

RA002_CLEAN = _src("""
    import jax
    import jax.numpy as jnp
    from functools import partial

    @partial(jax.jit, static_argnames=("mode",))
    def step(x, mode, rng=None):
        if mode == "fast":        # static arg: fine
            x = x * 2
        if x.ndim == 3:           # attribute read: static fact
            x = x[None]
        if rng is None:           # identity vs None: no tracer bool()
            rng = jax.random.PRNGKey(0)
        return jnp.sum(x) + jax.random.uniform(rng)
""")


def test_ra002_traced_hazards_flagged():
    findings = analyze_source(RA002_BAD, rules=["RA002"])
    msgs = " | ".join(f.message for f in findings)
    assert _rules(findings).count("RA002") == 3
    assert "branch on traced value 'x'" in msgs
    assert "numpy call" in msgs
    assert "print()" in msgs


def test_ra002_static_args_attrs_and_none_identity_clean():
    assert analyze_source(RA002_CLEAN, rules=["RA002"]) == []


def test_ra002_function_passed_to_wrapper():
    src = _src("""
        import jax

        def body(carry, x):
            if carry:
                return carry, x
            return carry + 1, x

        out = jax.lax.map(body, data)
    """)
    findings = analyze_source(src, rules=["RA002"])
    assert _rules(findings) == ["RA002"]
    assert "carry" in findings[0].message


def test_ra002_bucketed_dispatch_host_loop_clean():
    """The bucketed-dispatch idiom (``sz/tiled.py::dispatch_bucketed``):
    chunk widths, slice bounds, and the pad decision are host-side ints,
    and the lambdas handed to ``jax.tree.map`` slice by those static bounds
    — none of it may trip the tracer-safety rule."""
    src = _src("""
        import jax
        import jax.numpy as jnp

        def dispatch_bucketed(fn, tree, n, widths):
            outs, off = [], 0
            for width in widths:              # host ints: static loop
                take = min(width, n - off)
                part = jax.tree.map(lambda a: a[off:off + take], tree)
                pad = width - take
                if pad:                       # host int: static branch
                    part = jax.tree.map(
                        lambda a: jnp.concatenate(
                            [a, jnp.repeat(a[:1], pad, axis=0)]), part)
                outs.append(fn(part)[:take])
                off += take
            return outs[0] if len(outs) == 1 else jnp.concatenate(outs)
    """)
    assert analyze_source(src, rules=["RA002"]) == []


def test_ra002_bucketed_decode_fn_traced_branch_flagged():
    """The anti-pattern the clean variant avoids: a decode fn handed to
    ``jax.lax.map`` that branches on its traced payload (say, to skip pad
    rows) would crash or silently specialize under jit — flagged."""
    src = _src("""
        import jax

        def decode_one(payload):
            if payload:
                return payload + 1
            return payload

        recon = jax.lax.map(decode_one, batch)
    """)
    findings = analyze_source(src, rules=["RA002"])
    assert _rules(findings) == ["RA002"]
    assert "payload" in findings[0].message


# ---------------------------------------------------------------------------
# RA004 exception hygiene
# ---------------------------------------------------------------------------


def test_ra004_broad_except_flagged():
    src = _src("""
        def f():
            try:
                g()
            except Exception:
                pass

        def h():
            try:
                g()
            except:
                pass
    """)
    findings = analyze_source(src, rules=["RA004"])
    assert _rules(findings) == ["RA004", "RA004"]


def test_ra004_narrow_and_cleanup_reraise_clean():
    src = _src("""
        def f():
            try:
                g()
            except ValueError:
                pass

        def h(res):
            try:
                g()
            except BaseException:
                res.close()
                raise
    """)
    assert analyze_source(src, rules=["RA004"]) == []


def test_ra004_integrity_module_raises():
    src = _src("""
        from repro.errors import CorruptContainerError

        def from_bytes(blob):
            if len(blob) < 4:
                raise ValueError("too short")
            assert blob[:4] == b"XXXX"
            return blob

        def parse_header(blob):
            raise CorruptContainerError("bad", offset=0)
    """)
    # integrity raise rules only apply inside the container modules
    findings = analyze_source(src, rules=["RA004"], rel="sz/tiled.py")
    msgs = " | ".join(f.message for f in findings)
    assert len(findings) == 2
    assert "raises bare ValueError" in msgs and "assert" in msgs
    assert analyze_source(src, rules=["RA004"], rel="core/other.py") == []


def test_ra004_suppression_needs_reason():
    with_reason = _src("""
        def f():
            try:
                g()
            except Exception:  # lint: allow RA004 -- report harness keeps sweeping
                pass
    """)
    assert analyze_source(with_reason, rules=["RA004"]) == []
    reasonless = with_reason.replace(" -- report harness keeps sweeping", "")
    findings = analyze_source(reasonless, rules=["RA004"])
    # a reasonless annotation suppresses NOTHING: the RA004 still fires,
    # and RA000 reports the missing justification on top
    assert _rules(findings) == ["RA000", "RA004"]
    assert "reason" in findings[0].message


def test_suppression_on_line_above():
    src = _src("""
        def f():
            try:
                g()
            # lint: allow RA004 -- tolerated in this fixture
            except Exception:
                pass
    """)
    assert analyze_source(src, rules=["RA004"]) == []


# ---------------------------------------------------------------------------
# RA005 container-tag drift
# ---------------------------------------------------------------------------


def test_ra005_duplicated_tag_literals_flagged():
    src = _src("""
        MAGIC = b"GWTC"
        _VERSION = 3

        def sniff(blob):
            return blob[:4] == b"GWDS"
    """)
    findings = analyze_source(src, rules=["RA005"])
    assert _rules(findings) == ["RA005", "RA005", "RA005"]
    msgs = " | ".join(f.message for f in findings)
    assert "GWTC" in msgs and "GWDS" in msgs and "_VERSION" in msgs


def test_ra005_registry_module_and_aliases_clean():
    src = _src("""
        from repro.sz import artifact as A

        _MAGIC = A.GWTC_MAGIC
        _VERSION = A.GWTC_VERSION
        OTHER = b"OTHR"
    """)
    assert analyze_source(src, rules=["RA005"]) == []
    # literals are allowed in the registry module itself
    literal = 'GWTC_MAGIC, GWTC_VERSION = b"GWTC", 3\n'
    assert analyze_source(literal, rules=["RA005"], rel="sz/artifact.py") == []


# ---------------------------------------------------------------------------
# RA003 kernel-triple parity (project rule: needs a tree on disk)
# ---------------------------------------------------------------------------

KERNEL_MOD = _src("""
    from jax.experimental import pallas as pl

    def my_kernel_fn(x):
        return pl.pallas_call(lambda r, o: None, out_shape=x)(x)
""")


def _write_tree(root, files):
    for rel, text in files.items():
        p = root / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(text)
    return root


def test_ra003_complete_triple_clean(tmp_path):
    pkg = _write_tree(tmp_path / "pkg", {
        "kernels/__init__.py": "",
        "kernels/mykern.py": KERNEL_MOD,
        "kernels/ref.py": "def my_ref(x):\n    return x\n",
        "kernels/ops.py": _src("""
            from repro.kernels import ref
            from repro.kernels.mykern import my_kernel_fn

            def my_op(x, use_pallas=None):
                if use_pallas:
                    return my_kernel_fn(x)
                return ref.my_ref(x)
        """),
    })
    tests = tmp_path / "tests"
    tests.mkdir()
    (tests / "test_k.py").write_text("def test_my_op():\n    my_op\n")
    assert run_analysis(root=pkg, rules=["RA003"], tests_dir=tests) == []


def test_ra003_missing_oracle_dispatch_and_test(tmp_path):
    pkg = _write_tree(tmp_path / "pkg", {
        "kernels/__init__.py": "",
        "kernels/mykern.py": KERNEL_MOD,
        "kernels/orphan.py": KERNEL_MOD.replace("my_kernel_fn", "orphan_fn"),
        "kernels/ref.py": "",
        "kernels/ops.py": _src("""
            from repro.kernels.mykern import my_kernel_fn

            def my_op(x, use_pallas=False):
                return my_kernel_fn(x)
        """),
    })
    tests = tmp_path / "tests"
    tests.mkdir()
    (tests / "test_k.py").write_text("# nothing covered\n")
    findings = run_analysis(root=pkg, rules=["RA003"], tests_dir=tests)
    msgs = " | ".join(f.message for f in findings)
    assert all(f.rule == "RA003" for f in findings) and len(findings) == 4
    assert "orphan.py" in msgs                      # kernel not dispatchable
    assert "never calls a ref.* oracle" in msgs     # no reference path
    assert "use_pallas: bool | None = None" in msgs  # auto-detect contract
    assert "appears in no test" in msgs             # parity test required


def test_ra003_missing_ops_layer(tmp_path):
    pkg = _write_tree(tmp_path / "pkg", {
        "kernels/__init__.py": "",
        "kernels/mykern.py": KERNEL_MOD,
    })
    findings = run_analysis(root=pkg, rules=["RA003"])
    assert _rules(findings) == ["RA003"]
    assert "no kernels/ops.py" in findings[0].message


# ---------------------------------------------------------------------------
# engine: RA000 meta-findings, rule selection, determinism
# ---------------------------------------------------------------------------


def test_syntax_error_becomes_ra000(tmp_path):
    (tmp_path / "broken.py").write_text("def f(:\n")
    findings = run_analysis(root=tmp_path)
    assert _rules(findings) == ["RA000"]
    assert "syntax error" in findings[0].message


def test_unknown_rule_raises():
    with pytest.raises(ValueError, match="RA999"):
        run_analysis(rules=["RA999"])


def test_repo_tree_is_clean_and_fast():
    t0 = time.monotonic()
    findings = run_analysis()
    elapsed = time.monotonic() - t0
    assert findings == [], "\n".join(f.render() for f in findings)
    # single parse + walk per file keeps a full-tree lint interactive
    assert elapsed < 10.0, f"lint took {elapsed:.1f}s over src/repro"


# ---------------------------------------------------------------------------
# CLI integration: python -m repro.cli lint
# ---------------------------------------------------------------------------


def _lint(*argv, cwd=ROOT):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(ROOT, "src"), env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    env.setdefault("JAX_PLATFORMS", "cpu")
    return subprocess.run(
        [sys.executable, "-m", "repro.cli", "lint", *argv],
        cwd=cwd, env=env, capture_output=True, text=True, timeout=300)


def test_cli_lint_repo_clean():
    proc = _lint("--json")
    assert proc.returncode == 0, proc.stdout + proc.stderr
    doc = json.loads(proc.stdout)
    assert doc["clean"] is True and doc["findings"] == []
    assert doc["rules"] == ["RA001", "RA002", "RA003", "RA004", "RA005"]


def test_cli_lint_violation_exits_1_with_structured_json(tmp_path):
    bad = tmp_path / "pkg"
    bad.mkdir()
    (bad / "drift.py").write_text('MAGIC = b"GWTC"\n_VERSION = 3\n')
    proc = _lint("--json", "--root", str(bad))
    assert proc.returncode == 1, proc.stdout + proc.stderr
    doc = json.loads(proc.stdout)
    assert doc["clean"] is False and doc["counts"] == {"RA005": 2}
    f = doc["findings"][0]
    assert f["path"] == "drift.py" and f["line"] == 1 and f["rule"] == "RA005"


def test_cli_lint_usage_errors_exit_2(tmp_path):
    assert _lint("--rule", "RA999").returncode == 2
    assert _lint("--root", str(tmp_path / "missing")).returncode == 2
    assert _lint("--write-baseline").returncode == 2


def test_cli_lint_baseline_roundtrip(tmp_path):
    bad = tmp_path / "pkg"
    bad.mkdir()
    (bad / "drift.py").write_text('MAGIC = b"SZJX"\n')
    base = tmp_path / "baseline.json"
    wrote = _lint("--root", str(bad), "--baseline", str(base), "--write-baseline")
    assert wrote.returncode == 0 and base.is_file()
    accepted = _lint("--root", str(bad), "--baseline", str(base))
    assert accepted.returncode == 0, accepted.stdout + accepted.stderr
    # without the baseline the same tree still fails
    assert _lint("--root", str(bad)).returncode == 1
