"""Data pipelines + sharding rules."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.data import field_stats, nyx_like_field
from repro.data.tokens import NyxBlockPipeline, TokenPipeline, TokenPipelineConfig
from repro.launch.sharding import ShardingOptions, cache_pspecs, param_pspecs


def test_nyx_temperature_matches_table1_stats():
    x = nyx_like_field((48, 48, 48), "temperature", seed=1)
    st = field_stats(x)
    assert st["min"] == pytest.approx(2281.0, rel=1e-3)
    assert st["max"] == pytest.approx(4.78e6, rel=1e-3)
    assert 3e3 < st["avg"] < 5e4  # heavily skewed like the real field


def test_dm_density_mean_one():
    x = nyx_like_field((32, 32, 32), "dark_matter_density", seed=2)
    assert float(x.mean()) == pytest.approx(1.0, abs=1e-3)
    assert float(x.min()) >= 0.0


def test_token_pipeline_deterministic_and_replayable():
    cfg = TokenPipelineConfig(vocab=128, batch=4, seq=16, seed=3)
    p1, p2 = TokenPipeline(cfg), TokenPipeline(cfg)
    b1, b2 = p1.batch_at(17), p2.batch_at(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(p1.batch_at(18)["tokens"], b1["tokens"])
    assert b1["tokens"].max() < 128


def test_token_prefetch_matches_batch_at():
    cfg = TokenPipelineConfig(vocab=64, batch=2, seq=8, seed=0)
    pipe = TokenPipeline(cfg)
    gen = pipe.prefetch(5)
    for want_step in (5, 6, 7):
        step, batch = next(gen)
        assert step == want_step
        np.testing.assert_array_equal(batch["tokens"], pipe.batch_at(step)["tokens"])
    gen.close()


def test_block_pipeline_shards_cover_volume():
    vol = np.arange(4 * 4 * 4, dtype=np.float32).reshape(4, 4, 4)
    pipe = NyxBlockPipeline(vol, (2, 2, 2))
    seen = set()
    for host in range(2):
        for coords, blk in pipe.shard(host, 2):
            assert blk.shape == (2, 2, 2)
            assert coords not in seen
            seen.add(coords)
    assert len(seen) == 8


# -- sharding rules --------------------------------------------------------


def _mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_param_pspecs_divisibility_guard():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    params = {"attn": {"wk": jax.ShapeDtypeStruct((4, 64, 1, 16), "float32")}}
    specs = param_pspecs(params, ShardingOptions(), mesh)
    # kv_heads=1 indivisible by model size 1? size 1 divides everything; spec kept
    assert specs["attn"]["wk"] == P(None, None, "model", None)


def test_param_pspecs_drop_indivisible():
    mesh = jax.make_mesh((2,), ("model",)) if jax.device_count() >= 2 else None
    if mesh is None:
        # emulate with axis size from a 1-device mesh reshaped: use the rule fn directly
        from repro.launch.sharding import _resolve

        spec = _resolve(("model", None), (3, 16), ShardingOptions(), {"model": 2})
        assert spec == P(None, None)  # 3 % 2 != 0 -> dropped
    else:
        params = {"wq": jax.ShapeDtypeStruct((3, 16), "float32")}
        specs = param_pspecs(params, ShardingOptions(), mesh)
        assert specs["wq"] == P(None, None)


def test_moe_expert_rule():
    mesh = _mesh()
    params = {"ffn": {"we_up": jax.ShapeDtypeStruct((4, 16, 64, 32), "float32")}}
    specs = param_pspecs(params, ShardingOptions(fsdp=True), mesh)
    assert specs["ffn"]["we_up"] == P(None, "model", "data", None)


def test_cache_pspecs_seq_axis():
    mesh = _mesh()
    cache = {"k": jax.ShapeDtypeStruct((2, 1, 64, 4, 16), "bfloat16"),
             "pos": jax.ShapeDtypeStruct((), "int32")}
    specs = cache_pspecs(cache, mesh, ShardingOptions(seq_axis="model"))
    assert specs["k"] == P(None, ("data",), "model", None, None)
    assert specs["pos"] == P()
