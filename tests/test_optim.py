"""Optimizer: AdamW reference match, compressed moments, gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import AdamWConfig, adamw
from repro.optim.grad_compress import (
    GradCompressConfig,
    apply as gc_apply,
    init_ef,
    quantize_leaf,
)
from repro.optim.schedule import step_decay, warmup_cosine


def _rosenbrock_ish(p):
    return jnp.sum((p["a"] - 1.0) ** 2) + 2.0 * jnp.sum((p["b"] + 0.5) ** 2)


@pytest.mark.parametrize("moment_dtype", ["fp32", "bf16", "int8"])
def test_adamw_converges(moment_dtype):
    cfg = AdamWConfig(moment_dtype=moment_dtype)
    params = {"a": jnp.zeros(4), "b": jnp.ones(3)}
    opt = adamw.init(params, cfg)
    loss0 = float(_rosenbrock_ish(params))
    for i in range(300):
        g = jax.grad(_rosenbrock_ish)(params)
        params, opt = adamw.update(params, opt, g, 0.05, cfg, jax.random.PRNGKey(i))
    assert float(_rosenbrock_ish(params)) < loss0 * 0.05


def test_adamw_fp32_matches_manual_reference():
    cfg = AdamWConfig()
    p = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    p_np = np.asarray(p["w"]).copy()  # update() donates its inputs
    opt = adamw.init(p, cfg)
    g = {"w": jnp.asarray([0.1, 0.2, -0.3])}
    g_np = np.asarray(g["w"]).copy()
    p2, opt2 = adamw.update(p, opt, g, 0.01, cfg)
    # manual Adam step 1
    m = 0.1 * g_np
    v = 0.001 * g_np ** 2
    mh = m / (1 - 0.9)
    vh = v / (1 - 0.999)
    want = p_np - 0.01 * mh / (np.sqrt(vh) + 1e-8)
    np.testing.assert_allclose(np.asarray(p2["w"]), want, rtol=1e-5)


def test_int8_moments_are_int8():
    cfg = AdamWConfig(moment_dtype="int8")
    p = {"w": jnp.ones((32, 32))}
    opt = adamw.init(p, cfg)
    assert opt["m"]["w"]["q"].dtype == jnp.int8
    g = {"w": jnp.full((32, 32), 0.01)}
    _, opt2 = adamw.update(p, opt, g, 0.01, cfg, jax.random.PRNGKey(0))
    assert opt2["m"]["w"]["q"].dtype == jnp.int8


def test_schedules():
    s = step_decay(1e-3, 0.5, 10)
    assert float(s(0)) == pytest.approx(1e-3)
    assert float(s(10)) == pytest.approx(5e-4)
    w = warmup_cosine(1e-3, 10, 100)
    assert float(w(0)) == pytest.approx(1e-4)  # (step+1)/warmup: lr > 0 at step 0
    assert float(w(10)) == pytest.approx(1e-3, rel=1e-3)
    assert float(w(100)) < float(w(50))


# -- gradient compression ------------------------------------------------------


def test_quantize_leaf_error_bound():
    g = jax.random.normal(jax.random.PRNGKey(0), (128,))
    err = jnp.zeros(128)
    cfg = GradCompressConfig(rel_eb=1e-2, code_dtype="int16")
    codes, scale, new_err = quantize_leaf(g, err, cfg)
    ghat = codes.astype(jnp.float32) * scale
    eb = 1e-2 * float(jnp.sqrt(jnp.mean(g ** 2)))
    assert float(jnp.max(jnp.abs(ghat - g))) <= eb * (1 + 1e-4)


def test_error_feedback_makes_sgd_converge():
    """With EF, heavily-quantized SGD still converges (beyond-paper §8.3)."""
    w = jnp.asarray([5.0, -3.0])
    cfg = GradCompressConfig(rel_eb=0.5, code_dtype="int8")  # brutal quantization
    ef = init_ef({"w": w})
    cur = {"w": w}
    for _ in range(400):
        g = {"w": 2 * (cur["w"] - jnp.asarray([1.0, 2.0]))}
        gq, ef = gc_apply(g, ef, cfg)
        cur = {"w": cur["w"] - 0.05 * gq["w"]}
    np.testing.assert_allclose(np.asarray(cur["w"]), [1.0, 2.0], atol=0.05)


# hypothesis-based property tests live in test_optim_properties.py so this
# module keeps running when hypothesis isn't installed
