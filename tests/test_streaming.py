"""The out-of-core streaming execution layer (docs/STREAMING.md).

Acceptance surface of the streaming PR: bounded-memory compress of a volume
larger than the budget (tracked peak <= 2x budget) whose artifact decodes
bit-identically to the eager path; the footer-indexed GWTC v3 / GWDS v2
containers (with golden-pinned back-compat for the v2/v1 layouts they
replace); mmap-backed lazy `api.open` with close()/context-manager
lifecycle; the per-handle decoded-tile LRU under concurrent readers; and
the entropy sub-lane range decode."""
import os
import threading

import numpy as np
import pytest

from repro import api, cli
from repro.core.trainer import GWLZTrainConfig, TileReservoir
from repro.exec import (
    GWDSWriter,
    GWTCWriter,
    IterSource,
    TileCache,
    as_source,
    plan_stream,
    stream_compress,
)
from repro.sz import tiled
from repro.sz.entropy import decode_codes, decode_codes_range, encode_codes

GOLDEN = os.path.join(os.path.dirname(__file__), "golden")


@pytest.fixture(scope="module")
def volume():
    return np.load(os.path.join(GOLDEN, "volume_12_20_9.npy"))


@pytest.fixture(scope="module")
def field():
    from repro.data import nyx_like_field

    x = np.asarray(nyx_like_field((40, 40, 40), "temperature", seed=5), np.float32)
    return x / np.float32(np.abs(x).max())


# ---------------------------------------------------------------------------
# acceptance: bounded-memory streaming compress == eager
# ---------------------------------------------------------------------------


def test_stream_compress_bounded_memory_bit_identical(tmp_path, field):
    """A volume larger than the budget streams through in multiple batches,
    tracked peak stays under 2x the budget, and the artifact is BYTE-equal
    to the eager tiled path (lorenzo's integer transform is batch-exact)."""
    src = tmp_path / "src.npy"
    np.save(src, field)
    out = tmp_path / "out.gwtc"
    budget = field.nbytes // 4  # 64 KB budget vs a 256 KB volume
    rep = api.compress_stream(str(src), str(out), abs_eb=1e-3, tile=(16, 16, 16),
                              mem_budget=budget)
    assert rep.n_batches > 1, "volume must not fit one batch"
    assert rep.peak_tracked_bytes <= 2 * budget, \
        f"peak {rep.peak_tracked_bytes} vs budget {budget}"
    assert rep.nbytes == os.path.getsize(out)

    eager = api.compress(field, abs_eb=1e-3, tiled=True, tile=(16, 16, 16),
                         predictor="lorenzo")
    with api.open(out) as vol:
        assert vol.to_bytes() == eager.to_bytes(), \
            "streamed container must be byte-identical to eager to_bytes()"
        np.testing.assert_array_equal(np.asarray(vol), np.asarray(eager))


def test_stream_compress_rel_eb_prepass_matches_eager(tmp_path, field):
    out = tmp_path / "out.gwtc"
    api.compress_stream(field, out, eb=1e-3, tile=(16, 16, 16),
                        mem_budget=200_000)
    eager = api.compress(field, eb=1e-3, tiled=True, tile=(16, 16, 16),
                         predictor="lorenzo")
    with api.open(out) as vol:
        assert vol.eb_abs == eager.eb_abs
        assert vol.to_bytes() == eager.to_bytes()


def test_stream_compress_interp_bound_and_region(tmp_path, field):
    """Interp streams too: the bound holds by the straggler-promotion
    construction (up to the documented f32 ulp-at-magnitude slack), and
    region decode equals the full decode's crop bit-for-bit."""
    out = tmp_path / "out.gwtc"
    rep = api.compress_stream(field, out, abs_eb=1e-3, tile=(16, 16, 16),
                              predictor="interp", mem_budget=2_000_000)
    assert rep.predictor == "interp"
    with api.open(out) as vol:
        full = np.asarray(vol)
        slack = float(np.spacing(np.abs(field).max(), dtype=np.float32))
        assert np.max(np.abs(full - field)) <= vol.eb_abs + slack
        roi = (slice(4, 20), slice(3, 9), slice(0, 40))
        np.testing.assert_array_equal(vol[roi], full[roi])


def test_stream_iterator_source_and_reservoir_enhance(tmp_path, field):
    slabs = (field[i : i + 8] for i in range(0, 40, 8))
    out = tmp_path / "out.gwtc"
    cfg = GWLZTrainConfig(n_groups=2, epochs=2, batch_size=4, min_group_pixels=16)
    rep = api.compress_stream(slabs, out, abs_eb=1e-3, tile=(8, 8, 8),
                              shape=field.shape, mem_budget=150_000, enhance=cfg)
    assert rep.enhanced and rep.reservoir_tiles > 0
    with api.open(out) as vol:
        assert vol.enhanced, "streamed enhancer model must ride in the extras"
        full = np.asarray(vol)
        roi = (slice(3, 11), slice(0, 40), slice(2, 9))
        np.testing.assert_array_equal(vol[roi], full[roi])
        # enhancement really applied (decode differs from the raw SZ recon)
        raw = np.asarray(tiled.decompress_tiled(vol.artifact))
        assert not np.array_equal(full, raw)


def test_stream_iterator_source_requires_abs_eb(field):
    with pytest.raises(ValueError, match="abs_eb"):
        stream_compress(iter([field]), "/tmp/never.gwtc", rel_eb=1e-3,
                        tile=(8, 8, 8), shape=field.shape)


def test_stream_eb_overflow_guard(tmp_path, field):
    with pytest.raises(ValueError, match="too small for data magnitude"):
        api.compress_stream(field * 1e7, tmp_path / "x.gwtc", abs_eb=1e-9,
                            tile=(8, 8, 8), mem_budget=1 << 20)


def test_plan_stream_geometry():
    plan = plan_stream((40, 40, 40), (8, 8, 8), mem_budget=10 * 8**3 * 12 * 2,
                       predictor="lorenzo", devices=1)
    assert plan.n_tiles == 125
    ids = [i for run in plan.batches() for i in run]
    assert ids == list(range(125)), "batches must cover ids in row-major order"
    assert all(len(r) <= plan.batch_tiles for r in plan.batches())
    tiny = plan_stream((40, 40, 40), (8, 8, 8), mem_budget=1, devices=1)
    assert tiny.batch_tiles == 1, "a starved budget still makes progress"


# ---------------------------------------------------------------------------
# containers: GWTC v3 footer layout + back-compat, incremental writers
# ---------------------------------------------------------------------------


def test_current_gwtc_writer_emits_v3_footer(volume):
    art, _ = tiled.compress_tiled(volume, (8, 8, 8), abs_eb=1e-2)
    blob = art.to_bytes()
    assert blob[:4] == b"GWTC" and blob[4] == 3
    # footer locates extras + index; lanes start right after the dims
    extras_off, index_off = tiled._FOOTER_V3.unpack_from(
        blob, len(blob) - tiled._FOOTER_V3.size)
    lens = np.frombuffer(blob, np.uint64, art.n_tiles, offset=index_off)
    assert int(lens.sum()) == extras_off - (tiled._HDR_V3.size + 16 * 3)
    art2 = tiled.TiledCompressed.from_bytes(blob)
    np.testing.assert_array_equal(
        np.asarray(tiled.decompress_tiled(art2)),
        np.asarray(tiled.decompress_tiled(art)))


def test_golden_gwtc_v2_still_decodes():
    """v2 (index-first) blobs written by the pre-streaming code keep
    decoding bit-exactly — the layout the v3 footer bump replaced."""
    with open(os.path.join(GOLDEN, "gwtc_v2.bin"), "rb") as f:
        blob = f.read()
    assert blob[4] == 2
    art = tiled.TiledCompressed.from_bytes(blob)
    assert art.predictor == "interp" and art.extras["meta"] == b"\x07golden"
    np.testing.assert_array_equal(
        np.asarray(tiled.decompress_tiled(art)),
        np.load(os.path.join(GOLDEN, "gwtc_v2_decode.npy")))
    # and through the façade
    vol = api.open(os.path.join(GOLDEN, "gwtc_v2.bin"))
    np.testing.assert_array_equal(
        np.asarray(vol), np.load(os.path.join(GOLDEN, "gwtc_v2_decode.npy")))
    vol.close()


def test_golden_gwds_v1_still_opens():
    """v1 (header-count, index-first) envelopes keep opening now that the
    builder emits footer-indexed v2."""
    path = os.path.join(GOLDEN, "gwds_v1.bin")
    with open(path, "rb") as f:
        assert f.read(5)[4] == 1
    with api.open(path) as ds:
        assert ds.fields == ("temperature", "baryon_density")
        np.testing.assert_array_equal(
            np.asarray(ds["temperature"]),
            np.load(os.path.join(GOLDEN, "gwds_v1_temperature_decode.npy")))
        np.testing.assert_array_equal(
            np.asarray(ds["baryon_density"]),
            np.load(os.path.join(GOLDEN, "gwds_v1_baryon_density_decode.npy")))


def test_gwds_v2_build_roundtrip_and_streamed_field(tmp_path, volume):
    mono = api.compress(volume, abs_eb=1e-2)
    blob = api.Dataset.build({"t": mono})
    assert blob[4] == 2, "builder must emit the footer-indexed v2 envelope"

    # streamed field: a GWTC container written THROUGH the envelope
    x = np.ascontiguousarray(volume[:8, :16, :8])
    path = tmp_path / "snap.gwds"
    w = GWDSWriter(path)
    w.add_field("t", mono)
    gw = w.stream_field("rho", shape=x.shape, tile=(8, 8, 8), eb_abs=1e-2)
    stream_compress(x, gw, abs_eb=1e-2, tile=(8, 8, 8), mem_budget=1 << 20)
    w.finalize()
    eager = api.compress(x, abs_eb=1e-2, tiled=True, tile=(8, 8, 8),
                         predictor="lorenzo")
    with api.open(path) as ds:
        np.testing.assert_array_equal(np.asarray(ds["t"]), np.asarray(mono))
        assert ds["rho"].to_bytes() == eager.to_bytes()


def test_gwtc_writer_validates_lane_count(tmp_path):
    w = GWTCWriter(tmp_path / "x.gwtc", shape=(16, 16, 16), tile=(8, 8, 8),
                   eb_abs=1e-3)
    assert w.n_tiles == 8
    w.append_lane(b"abc")
    with pytest.raises(ValueError, match="needs 8 lanes"):
        w.finalize()
    for _ in range(7):
        w.append_lane(b"xy")
    w.finalize()
    with pytest.raises(ValueError, match="already finalized"):
        w.append_lane(b"z")


def test_gwds_writer_rejects_duplicates_and_empty(tmp_path, volume):
    mono = api.compress(volume, abs_eb=1e-2)
    w = GWDSWriter(tmp_path / "a.gwds")
    with pytest.raises(ValueError, match="at least one field"):
        w.finalize()
    w2 = GWDSWriter(tmp_path / "b.gwds")
    w2.add_field("t", mono)
    with pytest.raises(ValueError, match="duplicate"):
        w2.add_field("t", mono)


# ---------------------------------------------------------------------------
# mmap-backed lazy open + lifecycle
# ---------------------------------------------------------------------------


def test_open_is_lazy_and_closeable(tmp_path, volume):
    vol = api.compress(volume, abs_eb=1e-2, tiled=True, tile=(8, 8, 8))
    path = tmp_path / "x.gwtc"
    api.save(path, vol)
    full = np.asarray(vol)
    with api.open(path) as v2:
        # lanes live behind a LaneStore over the mmap, not materialized copies
        assert isinstance(v2.artifact.tile_blobs, tiled.LaneStore)
        assert v2.artifact.tile_blobs.nbytes == vol.size_report()["lanes"]
        roi = (slice(2, 9), slice(8, 20), slice(0, 5))
        np.testing.assert_array_equal(v2[roi], full[roi])
        assert (v2.stats.tiles_decoded, v2.stats.tiles_total) == (4, 12)
    # context exit closed it: decodes now fail, resources are released
    with pytest.raises(ValueError, match="closed"):
        v2[0:2]
    with pytest.raises(ValueError, match="closed"):
        np.asarray(v2)
    v2.close()  # idempotent

    # mmap=False keeps the old eager behavior (no resources to leak)
    v3 = api.open(path, mmap=False)
    assert isinstance(v3.artifact.tile_blobs, list)
    np.testing.assert_array_equal(v3[roi], full[roi])


def test_dataset_close_releases_fields(tmp_path, volume):
    a = api.compress(volume, abs_eb=1e-2, tiled=True, tile=(8, 8, 8))
    path = tmp_path / "s.gwds"
    api.save(path, {"t": a})
    ds = api.open(path)
    t = ds["t"]
    np.testing.assert_array_equal(t[0:4], np.asarray(a)[0:4])
    ds.close()
    with pytest.raises(ValueError, match="closed"):
        ds["t"]
    with pytest.raises(ValueError):
        t[0:4]  # field handle was closed with its parent
    ds.close()  # idempotent


# ---------------------------------------------------------------------------
# per-handle stats + concurrent tile cache
# ---------------------------------------------------------------------------


def test_per_handle_stats_and_cache_hits(volume):
    vol = api.compress(volume, abs_eb=1e-2, tiled=True, tile=(8, 8, 8))
    roi = (slice(2, 9), slice(8, 20), slice(0, 5))
    vol[roi]
    assert (vol.stats.tiles_decoded, vol.stats.tiles_total,
            vol.stats.cache_hits) == (4, 12, 0)
    vol[roi]  # all four tiles now come from the cache
    assert (vol.stats.tiles_decoded, vol.stats.cache_hits) == (4, 4)
    # deprecated module mirror still reports the touched lanes
    assert tiled.DECODE_STATS == {"tiles_decoded": 4, "tiles_total": 12}
    # train-stats forwarding: absent here -> helpful AttributeError
    with pytest.raises(AttributeError, match="GWLZStats"):
        vol.stats.psnr_gwlz


def test_cache_disabled_with_zero_budget(volume):
    vol = api.compress(volume, abs_eb=1e-2, tiled=True, tile=(8, 8, 8))
    vol.tile_cache = TileCache(0)
    roi = (slice(0, 8),) * 3
    vol[roi]
    vol[roi]
    assert vol.stats.cache_hits == 0 and vol.stats.tiles_decoded == 2


def test_tile_cache_lru_eviction_bounded():
    cache = TileCache(3 * 100)
    a = np.zeros(25, np.float32)  # 100 bytes
    for i in range(5):
        cache.put(i, a.copy())
        assert cache.nbytes <= 300
    assert len(cache) == 3
    assert set(cache.get_many(range(5))) == {2, 3, 4}
    cache.get_many([2])  # refresh 2 -> MRU
    cache.put(9, a.copy())
    assert 2 in cache.get_many([2]) and 3 not in cache.get_many([3])
    cache.clear()
    assert cache.nbytes == 0 and len(cache) == 0


def test_concurrent_readers_hit_shared_cache(field):
    """Acceptance: hammer one shared handle with threaded overlapping region
    reads — every read equals full[roi] bit-for-bit and the cache stays
    under its byte cap."""
    vol = api.compress(field, abs_eb=1e-3, tiled=True, tile=(8, 8, 8),
                       predictor="lorenzo")
    cap = 60 * 8 ** 3 * 4  # 60 of 125 tiles
    vol.tile_cache = TileCache(cap)
    full = np.asarray(api.CompressedVolume(vol.artifact))  # independent decode
    errors: list[Exception] = []

    def worker(seed: int) -> None:
        rng = np.random.default_rng(seed)
        try:
            for _ in range(25):
                lo = rng.integers(0, 32, 3)
                hi = lo + rng.integers(1, 12, 3)
                roi = tuple(slice(int(a), int(min(b, 40)))
                            for a, b in zip(lo, hi))
                np.testing.assert_array_equal(vol[roi], full[roi])
                assert vol.tile_cache.nbytes <= cap
        except Exception as e:  # pragma: no cover - surfaced via errors
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(s,)) for s in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors[0]
    assert vol.tile_cache.nbytes <= cap
    assert vol.stats.cache_hits > 0, "overlapping reads must share decodes"


# ---------------------------------------------------------------------------
# sources + reservoir
# ---------------------------------------------------------------------------


def test_iter_source_window_and_errors(field):
    src = IterSource(iter([field[:16], field[16:24], field[24:]]), field.shape)
    np.testing.assert_array_equal(
        src.read_block((0, 0, 0), (8, 40, 40)), field[:8])
    np.testing.assert_array_equal(
        src.read_block((16, 4, 8), (24, 9, 13)), field[16:24, 4:9, 8:13])
    with pytest.raises(ValueError, match="backwards"):
        src.read_block((0, 0, 0), (8, 40, 40))
    with pytest.raises(ValueError, match="exhausted"):
        IterSource(iter([field[:8]]), field.shape).read_block(
            (8, 0, 0), (16, 40, 40))
    with pytest.raises(ValueError, match="shape="):
        as_source(iter([field]))
    with pytest.raises(ValueError, match=".npy"):
        as_source("volume.h5")


def test_tile_reservoir_uniform_and_bounded():
    res = TileReservoir(8, seed=0)
    grown = res.offer(np.zeros((4, 2, 2, 2), np.float32),
                      np.zeros((4, 2, 2, 2), np.float32))
    assert grown == 4 * 2 * 8 * 4  # 4 pairs of 8-voxel f32 tiles
    for i in range(20):
        res.offer(np.full((5, 2, 2, 2), i, np.float32),
                  np.zeros((5, 2, 2, 2), np.float32))
    assert len(res) == 8 and res.n_seen == 104
    recon, resid = res.stacks()
    assert recon.shape == (8, 2, 2, 2) and resid.shape == recon.shape
    with pytest.raises(ValueError, match="capacity"):
        TileReservoir(0)
    with pytest.raises(ValueError, match="empty reservoir"):
        TileReservoir(2).stacks()


# ---------------------------------------------------------------------------
# entropy sub-lane range decode
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["huffman", "huffman+zlib", "zlib"])
def test_decode_codes_range_matches_full(backend):
    rng = np.random.default_rng(0)
    codes = rng.integers(-40, 40, size=5000).astype(np.int32)
    blob = encode_codes(codes, backend)
    flat = decode_codes(blob, (5000,)).ravel()
    for lo, hi in ((0, 5000), (17, 312), (4000, 5000), (255, 257),
                   (100, 100), (4999, 5000)):
        np.testing.assert_array_equal(decode_codes_range(blob, lo, hi),
                                      flat[lo:hi])
    with pytest.raises(ValueError, match="outside"):
        decode_codes_range(blob, 0, 5001)


# ---------------------------------------------------------------------------
# CLI streaming path
# ---------------------------------------------------------------------------


def test_cli_stream_compress_roundtrip(tmp_path, field):
    src = tmp_path / "x.npy"
    np.save(src, field)
    out = tmp_path / "x.gwtc"
    assert cli.main(["compress", str(src), str(out), "--abs-eb", "1e-3",
                     "--stream", "--mem-budget", "64K", "--tile", "16",
                     "--predictor", "lorenzo"]) == 0
    eager = api.compress(field, abs_eb=1e-3, tiled=True, tile=(16, 16, 16),
                         predictor="lorenzo")
    with api.open(out) as vol:
        assert vol.to_bytes() == eager.to_bytes()
    assert cli.main(["region", str(out), "--roi", "0:16,24:40,8:32"]) == 0
    assert cli.parse_size("256M") == 256 << 20
    assert cli.parse_size("64k") == 64 << 10
    assert cli.parse_size("1048576") == 1 << 20
    assert cli.parse_size("2G") == 2 << 30
    with pytest.raises(SystemExit):
        cli.main(["compress", str(src), str(out), "--abs-eb", "1e-3",
                  "--stream", "--mem-budget", "lots"])
