"""Benchmark harness smoke test: ``benchmarks/run.py --fast`` must execute
end-to-end so the scripts can't silently rot (imports all benchmark modules;
runs the throughput module at smoke settings)."""
import os
import subprocess
import sys

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def test_run_fast_smoke():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(ROOT, "src"), ROOT, env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--fast", "--only", "throughput"],
        cwd=ROOT, env=env, capture_output=True, text=True, timeout=840,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    lines = [l for l in proc.stdout.splitlines() if "," in l]
    assert lines and lines[0].startswith("name,"), proc.stdout
    assert not any(",0,ERROR" in l for l in lines), proc.stdout
    names = {l.split(",")[0] for l in lines[1:]}
    # the entropy-stage rows must be present (perf trajectory anchor)
    assert any(n.startswith("throughput/entropy/hcz_decode") for n in names), names
    assert any(n.startswith("throughput/entropy/decode_speedup") for n in names), names
    # device entropy rows (ISSUE 8): kernel encode/decode vs host plus the
    # executor's host-stage shrink, each reporting its speedup column
    for row in ("throughput/entropy/device/encode",
                "throughput/entropy/device/decode"):
        dev_rows = [l for l in lines[1:] if l.split(",")[0] == row]
        assert dev_rows and "speedup_vs_host=" in dev_rows[0], lines
    stage_rows = [l for l in lines[1:]
                  if l.split(",")[0] == "throughput/entropy/device/stream_host_stage"]
    assert stage_rows and "stage_reduction=" in stage_rows[0], lines
    assert any(n.startswith("throughput/compress/interp/huffman+zlib") for n in names), names
    # the tiled-engine rows must be present for BOTH registered predictors
    # (random-access decode anchor; the tiled path is predictor-pluggable)
    for pred in ("lorenzo", "interp"):
        assert f"throughput/tiled/compress/{pred}" in names, names
        tiled_rows = [l for l in lines[1:]
                      if l.split(",")[0] == f"throughput/tiled/region_decode/{pred}"]
        assert tiled_rows and "speedup_vs_full=" in tiled_rows[0], lines
    # batched tile enhancement must report its measured speedup over the
    # per-tile loop (bit-identity is asserted inside the benchmark itself)
    enh_rows = [l for l in lines[1:]
                if l.split(",")[0] == "throughput/tiled/enhance_batched"]
    assert enh_rows and "speedup_vs_loop=" in enh_rows[0], lines
    # bucketed decode must report its compile-cache hit rate (ISSUE 10;
    # bit-identity vs the unbucketed path is asserted inside the benchmark)
    bk_rows = [l for l in lines[1:]
               if l.split(",")[0] == "throughput/tiled/decode_bucketed"]
    assert bk_rows and "compile_hit_rate=" in bk_rows[0], lines
    # serving-layer warm re-read must report its speedup over the cold path
    wc_rows = [l for l in lines[1:]
               if l.split(",")[0] == "throughput/serve/region_warm_vs_cold"]
    assert wc_rows and "speedup=" in wc_rows[0], lines


def test_run_rejects_unknown_module():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(ROOT, "src"), ROOT, env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--fast", "--only", "nope"],
        cwd=ROOT, env=env, capture_output=True, text=True, timeout=300,
    )
    assert proc.returncode != 0
