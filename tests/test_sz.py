"""SZ substrate: error bounds, round trips, entropy backends."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.sz import compress, decompress
from repro.sz.entropy import HuffmanCodec, decode_codes, encode_codes, shannon_bits
from repro.sz.predictor import interp_decode, interp_encode, lorenzo_decode, lorenzo_encode


@pytest.mark.parametrize("predictor", ["lorenzo", "interp"])
@pytest.mark.parametrize("reb", [5e-3, 1e-4])
def test_error_bound_holds(nyx_small, predictor, reb):
    x = jnp.asarray(nyx_small)
    art, recon = compress(x, rel_eb=reb, predictor=predictor, backend="zlib")
    assert float(jnp.max(jnp.abs(recon - x))) <= art.eb_abs * (1 + 1e-6)


def test_bytes_roundtrip_exact_lorenzo(nyx_small):
    """The lorenzo path is integer-exact by construction: decode == encode-side
    reconstruction bitwise."""
    x = jnp.asarray(nyx_small)
    art, recon = compress(x, rel_eb=1e-3, predictor="lorenzo", backend="zlib")
    x2 = decompress(type(art).from_bytes(art.to_bytes()))
    np.testing.assert_array_equal(np.asarray(recon), np.asarray(x2))


def test_bytes_roundtrip_interp_ulp(nyx_small):
    """The interp path reproduces the encoder's reconstruction to <=2 ulp
    (XLA may fuse the prediction arithmetic differently in the two programs);
    the user-facing error bound carries the documented 1e-5 slack."""
    x = jnp.asarray(nyx_small)
    art, recon = compress(x, rel_eb=1e-3, predictor="interp", backend="zlib")
    x2 = decompress(type(art).from_bytes(art.to_bytes()))
    np.testing.assert_allclose(np.asarray(recon), np.asarray(x2), rtol=2e-6, atol=art.eb_abs * 1e-4)
    assert float(jnp.max(jnp.abs(x2 - x))) <= art.eb_abs * (1 + 1e-5)


def test_cr_monotone_in_eb(nyx_small):
    x = jnp.asarray(nyx_small)
    sizes = []
    for reb in (5e-3, 5e-4, 5e-5):
        art, _ = compress(x, rel_eb=reb, backend="zlib")
        sizes.append(art.nbytes)
    assert sizes[0] <= sizes[1] <= sizes[2]


def test_lorenzo_exact_integer_roundtrip():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(9, 17, 33)).astype(np.float32) * 50)
    eb = 0.01
    codes = lorenzo_encode(x, eb)
    x2 = lorenzo_decode(codes, eb)
    assert float(jnp.max(jnp.abs(x2 - x))) <= eb + 1e-6


@pytest.mark.parametrize("shape", [(64,), (33, 47), (16, 16, 16)])
@pytest.mark.parametrize("order", ["linear", "cubic"])
def test_interp_shapes_and_bound(shape, order):
    rng = np.random.default_rng(1)
    x = jnp.asarray(np.cumsum(rng.normal(size=shape), axis=-1).astype(np.float32))
    eb = 0.05
    codes, om, ov, recon, meta = interp_encode(x, eb, order=order)
    assert float(jnp.max(jnp.abs(recon[tuple(slice(0, d) for d in shape)] - x))) <= eb * (1 + 1e-6)
    dec = interp_decode(codes, om, ov, eb, meta, order=order)
    np.testing.assert_allclose(  # <=2 ulp: see test_bytes_roundtrip_interp_ulp
        np.asarray(dec), np.asarray(recon[tuple(slice(0, d) for d in shape)]),
        rtol=2e-6, atol=eb * 1e-4,
    )
    assert float(jnp.max(jnp.abs(dec - x))) <= eb * (1 + 1e-5)


def test_outlier_path():
    # data with one extreme spike -> spike must still be within bound
    x = np.zeros((8, 8, 8), np.float32)
    x[4, 4, 4] = 1e9
    art, recon = compress(jnp.asarray(x), abs_eb=0.5, predictor="interp", backend="zlib")
    assert abs(float(recon[4, 4, 4]) - 1e9) <= 0.5 * (1 + 1e-6) * max(1e9 * 1e-7, 1) or art.outlier_idx.size >= 0
    x2 = decompress(type(art).from_bytes(art.to_bytes()))
    assert float(jnp.max(jnp.abs(x2 - jnp.asarray(x)))) <= 0.5 * 1.001


# -- entropy ------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["zlib", "huffman", "huffman+zlib"])
def test_entropy_roundtrip(backend):
    rng = np.random.default_rng(2)
    codes = rng.integers(-40, 40, size=(11, 13, 7)).astype(np.int32)
    blob = encode_codes(codes, backend)
    out = decode_codes(blob, codes.shape)
    np.testing.assert_array_equal(codes, out)


def test_huffman_beats_shannon_bound_loosely():
    rng = np.random.default_rng(3)
    codes = rng.choice([0, 0, 0, 0, 0, 1, -1, 2], size=50000).astype(np.int32)
    codec = HuffmanCodec.fit(codes)
    enc = codec.encode(codes)
    ideal = shannon_bits(codes) / 8
    assert len(enc) - 8 <= ideal * 1.25 + 64  # canonical Huffman within 25% of entropy


# hypothesis-based property tests live in test_sz_properties.py so this
# module keeps running when hypothesis isn't installed
