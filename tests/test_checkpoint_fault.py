"""Checkpointing (incl. GWLZ-compressed), fault tolerance, elastic restore."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, compress_tensor, decompress_tensor
from repro.runtime import FailureInjector, HeartbeatMonitor, ResilientLoop, plan_remesh


@pytest.fixture
def state():
    k = jax.random.PRNGKey(0)
    return {
        "params": {"w": jax.random.normal(k, (32, 16)), "b": jnp.zeros(16)},
        "step": jnp.asarray(7),
    }


def test_save_restore_exact(tmp_path, state):
    m = CheckpointManager(str(tmp_path), async_save=False)
    m.save(7, state)
    out = m.restore(state)
    for a, b in zip(jax.tree_util.tree_leaves(state), jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_and_gc(tmp_path, state):
    m = CheckpointManager(str(tmp_path), keep=2, async_save=True)
    for s in (1, 2, 3, 4):
        m.save(s, state)
    m.wait()
    assert m.all_steps() == [3, 4]


def test_restore_with_shardings_host_mesh(tmp_path, state):
    from repro.launch.sharding import ShardingOptions, named, param_pspecs

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    m = CheckpointManager(str(tmp_path), async_save=False)
    m.save(1, state["params"])
    specs = param_pspecs(state["params"], ShardingOptions(), mesh)
    out = m.restore(state["params"], shardings=named(mesh, specs))
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(state["params"]["w"]))


def test_gwlz_tensor_roundtrip_bound():
    rng = np.random.default_rng(0)
    w = rng.normal(size=(64, 96)).astype(np.float32)
    blob = compress_tensor(w, rel_eb=1e-4)
    w2 = decompress_tensor(blob)
    eb = 1e-4 * (w.max() - w.min())
    assert w2.shape == w.shape and w2.dtype == w.dtype
    assert np.abs(w2 - w).max() <= eb * (1 + 1e-5)
    assert len(blob) < w.nbytes  # it actually compresses


def test_gwlz_checkpoint_manager_integration(tmp_path):
    rng = np.random.default_rng(1)
    state = {"big": rng.normal(size=(512, 256)).astype(np.float32),
             "small": rng.normal(size=(8,)).astype(np.float32)}
    m = CheckpointManager(str(tmp_path), async_save=False, gwlz_rel_eb=1e-4)
    m.save(1, state)
    out = m.restore(state)
    eb = 1e-4 * (state["big"].max() - state["big"].min())
    assert np.abs(out["big"] - state["big"]).max() <= eb * (1 + 1e-5)
    np.testing.assert_array_equal(out["small"], state["small"])  # small leaves exact


# -- fault tolerance -----------------------------------------------------------


def _toy_loop(tmp_path, fail_at=None, n=40, every=10):
    def step_fn(s, batch):
        w = s["w"] - 0.1 * (s["w"] - batch)
        return {"w": w, "step": s["step"] + 1}, {"w0": float(w[0])}

    def batch_fn(step):
        return jnp.full((4,), float(step % 5))

    m = CheckpointManager(str(tmp_path), async_save=False, keep=5)
    loop = ResilientLoop(step_fn, batch_fn, m, ckpt_every=every)
    inj = FailureInjector(fail_at or set())
    state = {"w": jnp.ones(4) * 10, "step": jnp.asarray(0)}
    return loop.run(state, n, injector=inj)


def test_resilient_loop_recovers_exactly(tmp_path):
    s_clean, log_clean, r0 = _toy_loop(tmp_path / "clean")
    s_fail, log_fail, r1 = _toy_loop(tmp_path / "fail", fail_at={17, 31})
    assert r0 == 0 and r1 == 2
    np.testing.assert_allclose(np.asarray(s_clean["w"]), np.asarray(s_fail["w"]), rtol=1e-6)
    assert int(s_fail["step"]) == 40


def test_straggler_detection():
    mon = HeartbeatMonitor(n_workers=4, straggler_factor=3.0)
    for step in range(8):
        for w in range(4):
            mon.beat(w, 1.0 if w != 2 else 10.0)
    assert mon.stragglers() == [2]


def test_plan_remesh_preserves_model_axis():
    assert plan_remesh((16, 16), 128) == (8, 16)
    assert plan_remesh((16, 16), 100) == (25, 4)
    assert plan_remesh((2, 16, 16), 256) == (16, 16)
