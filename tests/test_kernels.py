"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import enhancer as E
from repro.kernels import ops, ref


def _assert_codes_equivalent(a, b, x, eb):
    """Interpret-mode rint may break exact .5 ties the other way (XLA uses
    round-half-even; the interpreter's path differs at ~ulp-probability).
    Both stay within the error bound; require agreement elsewhere."""
    a, b = np.asarray(a), np.asarray(b)
    mism = a != b
    assert mism.mean() <= 1e-3, f"too many mismatches: {mism.mean()}"
    # decoded output from the kernel's codes still satisfies the bound
    from repro.sz.predictor import lorenzo_decode

    x2 = lorenzo_decode(jnp.asarray(a), eb)
    # a tie mis-round reconstructs exactly AT the bound (+ float noise)
    assert float(jnp.max(jnp.abs(x2 - x))) <= eb * (1 + 1e-3)


@pytest.mark.parametrize("shape", [(8, 16, 32), (16, 32, 64), (4, 64, 128), (32, 8, 256)])
@pytest.mark.parametrize("eb", [0.5, 0.01])
def test_lorenzo_quant_matches_ref(shape, eb):
    rng = np.random.default_rng(hash(shape) % 2**31)
    x = jnp.asarray((np.cumsum(rng.normal(size=shape), axis=0) * 10).astype(np.float32))
    a = ops.lorenzo_quant_op(x, eb, use_pallas=True, interpret=True)
    b = ref.lorenzo_quant_ref(x, eb)
    _assert_codes_equivalent(a, b, x, eb)


@pytest.mark.parametrize("block_z", [1, 2, 4, 8])
def test_lorenzo_block_sweep(block_z):
    from repro.kernels.lorenzo_quant import lorenzo_quant

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(8, 16, 32)).astype(np.float32))
    a = lorenzo_quant(x, 0.25, block_z=block_z, interpret=True)
    b = ref.lorenzo_quant_ref(x, 0.25)
    _assert_codes_equivalent(a, b, x, 0.25)


@pytest.mark.parametrize("shape", [(2, 8, 16, 32), (5, 4, 8, 128), (1, 16, 8, 32)])
@pytest.mark.parametrize("eb", [0.5, 0.01])
def test_lorenzo_tiles_matches_ref(shape, eb):
    rng = np.random.default_rng(hash(shape) % 2**31)
    x = jnp.asarray((np.cumsum(rng.normal(size=shape), axis=1) * 10).astype(np.float32))
    a = ops.lorenzo_quant_tiles_op(x, eb, use_pallas=True, interpret=True)
    b = ref.lorenzo_quant_tiles_ref(x, eb)
    a_np, b_np = np.asarray(a), np.asarray(b)
    assert (a_np != b_np).mean() <= 1e-3  # interpret-mode .5-tie rounding only
    # every tile's codes must decode within the bound via the production decoder
    from repro.sz.predictor import lorenzo_decode

    for t in range(shape[0]):
        x2 = lorenzo_decode(jnp.asarray(a_np[t]), eb)
        assert float(jnp.max(jnp.abs(x2 - x[t]))) <= eb * (1 + 1e-3)


def test_lorenzo_tiles_matches_per_tile_kernel():
    """Batched kernel == the unbatched kernel run tile by tile (carry reset)."""
    from repro.kernels.lorenzo_quant import lorenzo_quant, lorenzo_quant_tiles

    rng = np.random.default_rng(5)
    x = jnp.asarray((rng.normal(size=(3, 8, 16, 32)) * 20).astype(np.float32))
    batched = lorenzo_quant_tiles(x, 0.25, interpret=True)
    for t in range(x.shape[0]):
        single = lorenzo_quant(x[t], 0.25, interpret=True)
        np.testing.assert_array_equal(np.asarray(batched[t]), np.asarray(single))


def test_lorenzo_roundtrip_through_decoder():
    """Kernel codes must decode with the production cumsum decoder."""
    from repro.sz.predictor import lorenzo_decode

    rng = np.random.default_rng(1)
    x = jnp.asarray((rng.normal(size=(8, 16, 128)) * 100).astype(np.float32))
    eb = 0.5
    codes = ops.lorenzo_quant_op(x, eb, use_pallas=True, interpret=True)
    x2 = lorenzo_decode(codes, eb)
    assert float(jnp.max(jnp.abs(x2 - x))) <= eb * (1 + 1e-6)


@pytest.mark.parametrize("shape", [(1, 16, 32), (3, 32, 64), (2, 48, 48)])
def test_enhancer_fused_matches_ref(shape):
    rng = np.random.default_rng(shape[1])
    key = jax.random.PRNGKey(0)
    p = E.init_params(key)
    s = {"mean": jnp.asarray(rng.normal(size=9), jnp.float32),
         "var": jnp.asarray(rng.uniform(0.5, 2, size=9), jnp.float32)}
    x = jnp.asarray(rng.normal(size=shape).astype(np.float32))
    a = ops.enhancer_fused_op(x, p, s, use_pallas=True, interpret=True)
    b = ref.enhancer_fused_ref(x, p["w1"], p["b1"], p["gamma"], p["beta"],
                               s["mean"], s["var"], p["w2"], p["b2"])
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5, rtol=1e-5)


def test_enhancer_fused_matches_training_forward():
    """Fused kernel == the exact inference path used by the trainer."""
    key = jax.random.PRNGKey(3)
    p = E.init_params(key)
    s = E.init_state()
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 32, 32))
    want, _ = E.apply(p, s, x, train=False)
    got = ops.enhancer_fused_op(x, p, s, use_pallas=True, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=1e-5)


@pytest.mark.parametrize("n_groups", [2, 5, 16])
@pytest.mark.parametrize("rows", [16, 64])
def test_group_hist_matches_ref(n_groups, rows):
    rng = np.random.default_rng(n_groups * rows)
    x = jnp.asarray(rng.uniform(-5, 5, size=(rows, 128)).astype(np.float32))
    edges = jnp.asarray(np.quantile(np.asarray(x), np.linspace(0, 1, n_groups + 1)).astype(np.float32))
    ids_a, h_a = ops.group_hist_op(x, edges, n_groups=n_groups, use_pallas=True, interpret=True)
    ids_b, h_b = ref.group_hist_ref(x, edges)
    np.testing.assert_array_equal(np.asarray(ids_a), np.asarray(ids_b))
    np.testing.assert_array_equal(np.asarray(h_a), np.asarray(h_b))
    assert int(h_a.sum()) == x.size


@pytest.mark.parametrize("n_bins", [2, 37, 1000])
@pytest.mark.parametrize("size", [128, 50000])
def test_symbol_hist_matches_ref(n_bins, size):
    rng = np.random.default_rng(n_bins + size)
    vals = jnp.asarray(rng.integers(0, n_bins, size=size).astype(np.int32))
    h_pal = ops.symbol_hist_op(vals, n_bins=n_bins, use_pallas=True, interpret=True)
    h_ref = ops.symbol_hist_op(vals, n_bins=n_bins, use_pallas=False)
    want = np.bincount(np.asarray(vals), minlength=n_bins)
    np.testing.assert_array_equal(np.asarray(h_pal), want)
    np.testing.assert_array_equal(np.asarray(h_ref), want)
    assert int(h_pal.sum()) == size


def test_symbol_hist_ignores_out_of_range():
    vals = jnp.asarray(np.array([-3, 0, 1, 1, 2, 99], np.int32))
    h = ops.symbol_hist_op(vals, n_bins=3, use_pallas=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(h), [1, 2, 1])


def test_symbol_hist_feeds_huffman_fit():
    """The entropy stage's accelerated frequency count must match np.unique."""
    from repro.sz.entropy import HuffmanCodec

    rng = np.random.default_rng(4)
    codes = rng.choice([0, 0, 0, 1, -1, 2, -7], size=20000).astype(np.int32)
    codec = HuffmanCodec.fit(codes, use_accel=True)
    alphabet, counts = np.unique(codes, return_counts=True)
    np.testing.assert_array_equal(codec.alphabet, alphabet)
    # code lengths must come from the same counts either way
    ref_codec = HuffmanCodec.fit(codes, use_accel=False)
    np.testing.assert_array_equal(codec.lengths, ref_codec.lengths)


def _huffman_kernel_inputs(cs=64, size=4096, seed=77):
    """Codec + padded [C, cs] lens/codes arrays shaped for the encode op."""
    from repro.sz.entropy import HuffmanCodec

    rng = np.random.default_rng(seed)
    codes = rng.choice([0] * 10 + list(range(-30, 30)), size=size).astype(np.int32)
    codec = HuffmanCodec.fit(codes)
    inv = np.searchsorted(codec.alphabet, codes)
    C = -(-codes.size // cs)
    pad = C * cs - codes.size
    lens = np.pad(codec.lengths[inv].astype(np.int32), (0, pad)).reshape(C, cs)
    cws = np.pad(codec.codes[inv].astype(np.uint32).view(np.int32),
                 (0, pad)).reshape(C, cs)
    return codec, codes, lens, cws


@pytest.mark.parametrize("cs", [8, 64, 256])
def test_huffman_encode_matches_ref(cs):
    _codec, _codes, lens, cws = _huffman_kernel_inputs(cs=cs, size=4 * cs + 3)
    w_a, b_a = ops.huffman_encode_op(jnp.asarray(lens), jnp.asarray(cws),
                                     use_pallas=True, interpret=True)
    w_b, b_b = ref.huffman_encode_ref(jnp.asarray(lens), jnp.asarray(cws))
    np.testing.assert_array_equal(np.asarray(w_a), np.asarray(w_b))
    np.testing.assert_array_equal(np.asarray(b_a), np.asarray(b_b))
    # each chunk's bit total is the sum of its member code lengths
    np.testing.assert_array_equal(np.asarray(b_a), lens.sum(axis=1))


def test_huffman_decode_matches_ref():
    """Pallas decode probe == the pure-jnp block oracle == the source codes,
    through the real codec tables and a real packed stream."""
    cs = 64
    codec, codes, lens, cws = _huffman_kernel_inputs(cs=cs)
    stream, chunk_bits, _total = codec._device_pack(codes, cs, interpret=True)
    dev = codec._device_tables()
    raw = np.frombuffer(stream, np.uint8)
    padded = np.zeros(raw.size + (-raw.size) % 4 + 8, np.uint8)
    padded[: raw.size] = raw
    words = padded.view(">u4").astype(np.uint32).view(np.int32)
    ends = np.cumsum(chunk_bits)
    offsets = (ends - chunk_bits).astype(np.int32)
    C = chunk_bits.size
    counts = np.full(C, cs, np.int32)
    counts[-1] = codes.size - cs * (C - 1)
    tables = [jnp.asarray(dev[key]) for key in
              ("lut_count", "lut_bits", "lut_ids", "cw_map", "order",
               "len_sorted")]
    ids_a = ops.huffman_decode_op(
        jnp.asarray(words), jnp.asarray(offsets), jnp.asarray(counts),
        *tables, chunk_size=cs, k=dev["k"], use_pallas=True, interpret=True)
    ids_b = ref.huffman_decode_ref(
        jnp.asarray(words), jnp.asarray(offsets), jnp.asarray(counts),
        *tables, chunk_size=cs, k=dev["k"])
    np.testing.assert_array_equal(np.asarray(ids_a), np.asarray(ids_b))
    flat = np.asarray(ids_a).reshape(-1)[: codes.size]
    np.testing.assert_array_equal(codec.alphabet[flat], codes)


def test_group_hist_matches_grouping_module():
    """Kernel ids must agree with repro.core.grouping (the pipeline contract)."""
    from repro.core import grouping

    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.uniform(0, 100, size=(32, 128)).astype(np.float32))
    edges = grouping.compute_edges(x, 6, "quantile")
    ids_k, _ = ops.group_hist_op(x, edges, n_groups=6, use_pallas=True, interpret=True)
    ids_g = grouping.assign_groups(x, edges)
    np.testing.assert_array_equal(np.asarray(ids_k), np.asarray(ids_g))
