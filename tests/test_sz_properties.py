"""Hypothesis property tests for the SZ substrate (split from test_sz.py so
that module still runs when hypothesis isn't installed)."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.sz import compress
from repro.sz.entropy import HuffmanCodec


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(min_value=-1000, max_value=1000), min_size=1, max_size=400))
def test_huffman_roundtrip_property(vals):
    codes = np.asarray(vals, np.int32)
    codec = HuffmanCodec.fit(codes)
    out = codec.decode(codec.encode(codes), codes.size)
    np.testing.assert_array_equal(codes, out)


@settings(max_examples=20, deadline=None)
@given(
    st.integers(min_value=0, max_value=2**31),
    st.sampled_from([1e-2, 1e-3, 1e-4]),
)
def test_sz_bound_property(seed, reb):
    rng = np.random.default_rng(seed)
    x = jnp.asarray((np.cumsum(rng.normal(size=(12, 12, 12)), axis=0) * 10).astype(np.float32))
    art, recon = compress(x, rel_eb=reb, backend="zlib")
    assert float(jnp.max(jnp.abs(recon - x))) <= art.eb_abs * (1 + 1e-5)