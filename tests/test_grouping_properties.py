"""Hypothesis property tests for group partitioning (split from
test_grouping.py so that module still runs when hypothesis isn't installed)."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import grouping


@settings(max_examples=30, deadline=None)
@given(
    st.lists(st.floats(min_value=-1e6, max_value=1e6, allow_nan=False, width=32),
             min_size=4, max_size=300),
    st.integers(min_value=1, max_value=16),
    st.sampled_from(["quantile", "range"]),
)
def test_assignment_property(vals, n_groups, strategy):
    x = jnp.asarray(np.asarray(vals, np.float32))
    edges = grouping.compute_edges(x, n_groups, strategy)
    ids = grouping.assign_groups(x, edges)
    assert int(ids.min()) >= 0 and int(ids.max()) < n_groups
    # reproducibility: same edges -> same ids (decompression-side contract)
    ids2 = grouping.assign_groups(x, edges)
    assert bool(jnp.all(ids == ids2))