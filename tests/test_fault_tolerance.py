"""Fault-tolerant streaming ingest + container integrity (docs/ROBUSTNESS.md).

Acceptance surface of the robustness PR: per-batch retry with backoff (an
injected transient device/host fault is survived and the output stays
byte-identical), the commit journal + resumable compress (interrupted then
resumed == uninterrupted, byte for byte, for Lorenzo), and end-to-end
integrity (per-lane CRCs in the v3 footer, ``verify=`` open policies,
structured ``CorruptLaneError`` / ``CorruptContainerError``, quarantine
fill with stats accounting)."""
import os

import numpy as np
import pytest

from repro import api, cli
from repro.errors import CorruptContainerError, CorruptLaneError, IntegrityError
from repro.exec import GWTCWriter, journal_path, plan_stream, stream_compress
from repro.runtime.fault import FailureInjector, RetryPolicy
from repro.sz import tiled

GOLDEN = os.path.join(os.path.dirname(__file__), "golden")


@pytest.fixture(scope="module")
def field():
    from repro.data import nyx_like_field

    x = np.asarray(nyx_like_field((24, 24, 24), "temperature", seed=21), np.float32)
    return x / np.float32(np.abs(x).max())


def _stream(field, out, **kw):
    """Small multi-batch stream: 27 tiles, 4 per batch -> 7 batches."""
    kw.setdefault("abs_eb", 1e-3)
    kw.setdefault("tile", (8, 8, 8))
    kw.setdefault("mem_budget", 50_000)
    kw.setdefault("predictor", "lorenzo")
    return stream_compress(field, str(out), **kw)


@pytest.fixture(scope="module")
def clean_bytes(field, tmp_path_factory):
    out = tmp_path_factory.mktemp("clean") / "ref.gwtc"
    rep = _stream(field, out)
    assert rep.n_batches == 7 and rep.retries == 0
    return out.read_bytes()


# ---------------------------------------------------------------------------
# RetryPolicy / FailureInjector units
# ---------------------------------------------------------------------------


def test_retry_policy_survives_transients_then_succeeds():
    calls, waited, seen = [], [], []
    pol = RetryPolicy(max_attempts=3, backoff=0.01)

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise RuntimeError("blip")
        return "ok"

    out = pol.run(flaky, on_retry=lambda e, a: seen.append((str(e), a)),
                  sleep=waited.append)
    assert out == "ok" and len(calls) == 3
    assert [a for _, a in seen] == [0, 1]
    assert waited == [pytest.approx(0.01), pytest.approx(0.02)], \
        "backoff must be exponential in the attempt index"


def test_retry_policy_exhausts_and_raises_last_error():
    pol = RetryPolicy(max_attempts=2, backoff=0.0)
    n = []

    def always():
        n.append(1)
        raise OSError("disk went away")

    with pytest.raises(OSError, match="disk went away"):
        pol.run(always, sleep=lambda _: None)
    assert len(n) == 2, "max_attempts bounds the total tries, not the retries"


def test_retry_policy_only_retries_declared_exceptions():
    n = []

    def bad():
        n.append(1)
        raise ValueError("programming error")

    with pytest.raises(ValueError):
        RetryPolicy(max_attempts=5).run(bad, sleep=lambda _: None)
    assert len(n) == 1, "a non-transient error must propagate on attempt 1"
    with pytest.raises(ValueError, match="max_attempts"):
        RetryPolicy(max_attempts=0)


def test_retry_policy_jitter_bounds():
    pol = RetryPolicy(backoff=0.1, jitter=0.5)
    for attempt in range(3):
        base = 0.1 * 2.0 ** attempt
        for _ in range(16):
            assert base <= pol.delay(attempt) <= base * 1.5 + 1e-12


def test_failure_injector_fires_each_step_n_times():
    inj = FailureInjector({2, 5}, exc=OSError, attempts=2)
    for step in range(7):
        expect = step in (2, 5)
        for attempt in range(3):
            if expect and attempt < 2:
                with pytest.raises(OSError, match=f"step {step}"):
                    inj.maybe_fail(step)
            else:
                inj.maybe_fail(step)
    assert inj.failed == {2: 2, 5: 2}


# ---------------------------------------------------------------------------
# executor: retry over injected device / host faults
# ---------------------------------------------------------------------------


def test_stream_survives_transient_device_fault(tmp_path, field, clean_bytes):
    """An OOM-style RuntimeError in the device transform of one batch is
    retried and the finished container is byte-identical to a clean run."""
    out = tmp_path / "x.gwtc"
    rep = _stream(field, out, injector=FailureInjector({1}),
                  retry=RetryPolicy(max_attempts=3, backoff=0.0))
    assert rep.retries == 1 and rep.failed_batches == (1,)
    assert out.read_bytes() == clean_bytes
    assert not os.path.exists(journal_path(out)), \
        "finalize must remove the commit journal"


def test_stream_survives_transient_append_fault(tmp_path, field, clean_bytes):
    """A transient OSError while appending a mid-batch lane is survived by
    rollback-to-last-commit + retry, with no duplicated or torn lanes."""
    out = tmp_path / "x.gwtc"
    rep = _stream(field, out,
                  write_injector=FailureInjector({9}, exc=OSError),
                  retry=RetryPolicy(max_attempts=3, backoff=0.0))
    assert rep.retries == 1 and rep.failed_batches == (2,), \
        "lane 9 lives in batch 2 (4 tiles per batch)"
    assert out.read_bytes() == clean_bytes
    with api.open(out, verify="full") as vol:
        np.testing.assert_allclose(np.asarray(vol), field, atol=1e-3 * 1.01)


def test_stream_hard_fault_leaves_resumable_partial(tmp_path, field, clean_bytes):
    """Exhausted retries leave the partial container AND its journal on
    disk (instead of unlinking), and ``resume=True`` finishes the stream
    byte-identically to an uninterrupted run."""
    out = tmp_path / "x.gwtc"
    with pytest.raises(RuntimeError, match="injected failure"):
        _stream(field, out, injector=FailureInjector({3}, attempts=5),
                retry=RetryPolicy(max_attempts=2, backoff=0.0))
    assert os.path.exists(out) and os.path.exists(journal_path(out)), \
        "a journaled stream must keep its partial output for resume"

    rep = _stream(field, out, resume=True)
    assert rep.resumed_batches == 3, "batches 0-2 were committed pre-fault"
    assert rep.n_batches == 7
    assert out.read_bytes() == clean_bytes, \
        "interrupted-then-resumed must equal uninterrupted, byte for byte"
    assert not os.path.exists(journal_path(out))
    with api.open(out, verify="full") as vol:
        np.testing.assert_allclose(np.asarray(vol), field, atol=1e-3 * 1.01)


def test_resume_noop_when_nothing_committed(tmp_path, field, clean_bytes):
    """A fault in batch 0 commits nothing; resume still rebuilds the whole
    container from lane 0."""
    out = tmp_path / "x.gwtc"
    with pytest.raises(RuntimeError):
        _stream(field, out, injector=FailureInjector({0}, attempts=9),
                retry=RetryPolicy(max_attempts=2, backoff=0.0))
    rep = _stream(field, out, resume=True)
    assert rep.resumed_batches == 0
    assert out.read_bytes() == clean_bytes


def test_resume_validation_errors(tmp_path, field):
    out = tmp_path / "x.gwtc"
    with pytest.raises(FileNotFoundError, match="journal"):
        _stream(field, out, resume=True)  # nothing to resume
    import io

    with pytest.raises(ValueError, match="path"):
        stream_compress(field, io.BytesIO(), abs_eb=1e-3, tile=(8, 8, 8),
                        mem_budget=50_000, resume=True)
    from repro.core.trainer import GWLZTrainConfig

    with pytest.raises(ValueError, match="enhance"):
        stream_compress(field, str(out), abs_eb=1e-3, tile=(8, 8, 8),
                        mem_budget=50_000, resume=True,
                        enhance=GWLZTrainConfig(n_groups=2, epochs=1))


def test_resume_rejects_tampered_prefix(tmp_path, field):
    out = tmp_path / "x.gwtc"
    with pytest.raises(RuntimeError):
        _stream(field, out, injector=FailureInjector({3}, attempts=9),
                retry=RetryPolicy(max_attempts=2, backoff=0.0))
    blob = bytearray(out.read_bytes())
    blob[tiled._HDR_V3.size + 2] ^= 0xFF  # corrupt a shape dim on disk
    out.write_bytes(bytes(blob))
    with pytest.raises(CorruptContainerError, match="prefix"):
        GWTCWriter.resume(out)


def test_plan_resume_point_rounds_down():
    plan = plan_stream((24, 24, 24), (8, 8, 8), mem_budget=50_000,
                       predictor="lorenzo", devices=1)
    assert plan.batch_tiles == 4 and plan.n_tiles == 27
    assert plan.resume_point(0) == 0
    assert plan.resume_point(4) == 4
    assert plan.resume_point(9) == 8, "mid-batch commits surrender the tail"
    assert plan.resume_point(999) == 24, "clamped to the tile count"
    ids = [i for run in plan.batches(8) for i in run]
    assert ids == list(range(8, 27))
    with pytest.raises(ValueError, match="aligned"):
        list(plan.batches(3))  # generator: the guard fires on iteration


def test_writer_commit_journal_roundtrip(tmp_path):
    """Writer-level journal protocol: abort keeps the (partial, journal)
    pair, resume truncates uncommitted bytes and replays the commit state."""
    path = tmp_path / "w.gwtc"
    w = GWTCWriter(path, shape=(16, 16, 16), tile=(8, 8, 8), eb_abs=1e-3)
    for blob in (b"aaaa", b"bb"):
        w.append_lane(blob)
    w.commit()
    w.append_lane(b"cccccc")  # never committed
    w.abort()
    assert os.path.exists(journal_path(path))

    w2 = GWTCWriter.resume(path)
    assert w2.committed_lanes == 2 and w2.can_rollback
    for blob in (b"cccccc", *[b"dd"] * 5):
        w2.append_lane(blob)
    w2.commit()
    w2.finalize()
    art = tiled.TiledCompressed.from_bytes(path.read_bytes())
    assert [bytes(b) for b in art.tile_blobs] == \
        [b"aaaa", b"bb", b"cccccc"] + [b"dd"] * 5
    assert art.lane_crcs is not None and len(art.lane_crcs) == 8


def test_writer_torn_journal_block_falls_back_to_previous_commit(tmp_path):
    path = tmp_path / "w.gwtc"
    w = GWTCWriter(path, shape=(16, 16, 16), tile=(8, 8, 8), eb_abs=1e-3)
    w.append_lane(b"aaaa")
    w.commit()
    w.append_lane(b"bbbb")
    w.commit()
    w.abort()
    jp = journal_path(path)
    with open(jp, "r+b") as f:  # tear the tail of the last commit block
        f.truncate(os.path.getsize(jp) - 3)
    w2 = GWTCWriter.resume(path)
    assert w2.committed_lanes == 1, "a torn block must yield to the prior commit"


# ---------------------------------------------------------------------------
# integrity: CRC policies, quarantine, structured corruption errors
# ---------------------------------------------------------------------------


def _flip(path, tmp_path, byte, name="bad.gwtc"):
    blob = bytearray(path.read_bytes())
    blob[byte] ^= 0x10
    bad = tmp_path / name
    bad.write_bytes(bytes(blob))
    return bad


@pytest.fixture()
def container(tmp_path, field):
    out = tmp_path / "v.gwtc"
    vol = api.compress(field, abs_eb=1e-3, tiled=True, tile=(8, 8, 8),
                       predictor="lorenzo")
    api.save(out, vol)
    return out, np.asarray(vol)


def test_lazy_verify_detects_lane_flip(tmp_path, container):
    """Acceptance: a bit-flipped lane is detected on first decode under the
    default ``verify="lazy"`` — a structured error naming the tile and the
    damaged byte range, never silent wrong data."""
    out, _ = container
    lanes_start = tiled._HDR_V3.size + 16 * 3
    bad = _flip(out, tmp_path, lanes_start + 11)
    with api.open(bad) as vol:
        with pytest.raises(CorruptLaneError) as ei:
            np.asarray(vol)
    err = ei.value
    assert err.tile_id == 0 and err.lane_offset == lanes_start
    assert err.expected_crc != err.actual_crc
    assert isinstance(err, IntegrityError) and isinstance(err, ValueError)
    assert "quarantine" in str(err), "the message must point at the escape hatch"


def test_full_verify_fails_fast_at_open(tmp_path, container):
    out, _ = container
    art = tiled.TiledCompressed.from_bytes(out.read_bytes())
    last = tiled.lane_offset(art, art.n_tiles - 1)
    bad = _flip(out, tmp_path, last + 5)
    with pytest.raises(CorruptLaneError) as ei:
        api.open(bad, verify="full")
    assert ei.value.tile_id == art.n_tiles - 1, \
        "full verify must scan every lane before any decode"


def test_quarantine_fills_and_counts(tmp_path, container):
    """Acceptance: under ``on_corrupt="quarantine"`` a corrupt lane decodes
    to the fill value — region reads stay ROI-shaped — and the handle's
    stats count the quarantined tile."""
    out, ref = container
    lanes_start = tiled._HDR_V3.size + 16 * 3
    bad = _flip(out, tmp_path, lanes_start + 7)
    with api.open(bad, on_corrupt="quarantine", fill_value=-1.0) as vol:
        roi = (slice(0, 12), slice(0, 12), slice(0, 12))
        got = vol[roi]
        assert got.shape == (12, 12, 12)
        assert np.all(got[:8, :8, :8] == -1.0), "tile 0 must be fill-valued"
        np.testing.assert_array_equal(got[8:, :, :], ref[roi][8:, :, :]), \
            "healthy tiles must decode normally"
        assert vol.stats.quarantined == 1
        assert "quarantined" in repr(vol.stats)


def test_verify_none_skips_checksums(tmp_path, container):
    """The opt-out: CRCs are never consulted, so a flip deep in a lane's
    payload decodes to (wrong) data instead of raising CorruptLaneError."""
    out, ref = container
    lanes_start = tiled._HDR_V3.size + 16 * 3
    bad = _flip(out, tmp_path, lanes_start + 11)
    with api.open(bad, verify="none") as vol:
        try:
            got = np.asarray(vol)
        except CorruptLaneError:  # pragma: no cover - the asserted failure
            pytest.fail("verify='none' must not run CRC checks")
        except Exception:
            return  # the entropy parser may reject the garbage — also fine
        assert not np.array_equal(got, ref), "the damage must surface somewhere"


def test_metadata_flip_raises_corrupt_container(tmp_path, container):
    out, _ = container
    bad = _flip(out, tmp_path, tiled._HDR_V3.size + 1)  # a shape byte
    with pytest.raises(CorruptContainerError):
        api.open(bad)


def test_verify_policy_validation(container):
    out, _ = container
    with pytest.raises(ValueError, match="verify"):
        api.open(out, verify="paranoid")
    with pytest.raises(ValueError, match="on_corrupt"):
        api.open(out, on_corrupt="ignore")


def test_corrupt_container_zero_length_garbage_truncated(tmp_path, container):
    out, _ = container
    zero = tmp_path / "zero.gwtc"
    zero.write_bytes(b"")
    with pytest.raises(CorruptContainerError, match="magic"):
        api.open(zero)
    garbage = tmp_path / "garbage.gwtc"
    garbage.write_bytes(b"NOPE" + bytes(100))
    with pytest.raises(CorruptContainerError) as ei:
        api.open(garbage)
    assert ei.value.offset == 0, "a bad magic is located at byte 0"
    trunc = tmp_path / "trunc.gwtc"
    trunc.write_bytes(out.read_bytes()[:-7])
    with pytest.raises(CorruptContainerError, match="footer"):
        api.open(trunc)


def test_verify_lanes_full_scan_clean_and_legacy(container):
    out, _ = container
    art = tiled.TiledCompressed.from_bytes(out.read_bytes())
    assert tiled.verify_lanes(art) == []
    legacy = tiled.TiledCompressed.from_bytes(
        open(os.path.join(GOLDEN, "gwtc_v1.bin"), "rb").read())
    assert legacy.lane_crcs is None
    assert tiled.verify_lanes(legacy) == [], \
        "checksum-free legacy containers skip verification"


# ---------------------------------------------------------------------------
# CLI: --resume / --retries / verify
# ---------------------------------------------------------------------------


def test_cli_resume_requires_stream(tmp_path, field):
    src = tmp_path / "x.npy"
    np.save(src, field)
    with pytest.raises(SystemExit):
        cli.main(["compress", str(src), str(tmp_path / "x.gwtc"),
                  "--abs-eb", "1e-3", "--resume"])


def test_cli_verify_good_and_corrupt(tmp_path, container):
    out, _ = container
    assert cli.main(["verify", str(out)]) == 0
    lanes_start = tiled._HDR_V3.size + 16 * 3
    bad = _flip(out, tmp_path, lanes_start + 11)
    # corrupt container: integrity exit code 1 (normalized CLI contract)
    with pytest.raises(SystemExit) as ei:
        cli.main(["verify", str(bad)])
    assert ei.value.code == 1
